"""Seeded deterministic fault injection + bounded retries.

The paper's third contribution extends DCAFE to RTP programs that may
throw: AFE may move *where* a join happens, never *whether* an exception
surfaces.  Proving that needs faults on demand — this module is the
chaos harness the executors, checkpointer, batcher, and EP round consult
at their emit sites, plus the :class:`RetryPolicy` those surfaces use to
absorb transient failures.

Design rules:

* **Default-off costs one module-global read.**  Every hook site calls
  :func:`active` first; with no plan installed that is a single ``None``
  check — the same discipline as ``repro.obs.trace._ENABLED``.
* **Deterministic by construction.**  A :class:`FaultPlan` is seeded;
  ``every=N`` specs fire on exact poke counts (thread interleaving moves
  *which* item a fault hits, never *how many* fire over M pokes — the
  conservation gates depend only on counts), and ``rate`` specs draw
  from per-spec seeded RNGs under the plan lock.
* **Injection is accounted exactly.**  ``plan.injected`` counts every
  fired fault per ``(site, kind)`` so benches and tests can gate
  ``injected == collected`` with zero tolerance.

Sites wired in this repo (see docs/sched.md):

=================  =====================================================
``sched.item``     every loop item both executors run (raise / slow)
``sched.worker``   worker loop top (worker_death — the thread exits)
``ckpt.shard``     one checkpoint shard write attempt (raise / slow)
``serve.request``  one decode step of one request slot (raise / slow)
``ep.round``       one EP dispatch round (shard_loss)
=================  =====================================================
"""

from __future__ import annotations

import random
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence, Tuple, Type

from ..obs import trace as obs


class InjectedFault(RuntimeError):
    """Raised by :meth:`FaultPlan.poke` at a matching ``raise`` spec."""


class WorkerDeath(Exception):
    """Internal signal: a worker thread was told to die (never escapes
    the executor — the worker unwinds after re-queueing its work)."""


class ShardLossError(RuntimeError):
    """An EP shard became unreachable mid-round."""

    def __init__(self, shard: int):
        super().__init__(f"ep shard {shard} lost")
        self.shard = shard


KINDS = ("raise", "slow", "worker_death", "shard_loss")


@dataclass(frozen=True)
class FaultSpec:
    """One fault source: at ``site``, inject ``kind`` either every
    ``every``-th poke (exact, interleaving-independent counts) or with
    probability ``rate`` per poke (seeded), at most ``max_injections``
    times.  ``delay_s`` is the stall for ``slow``; ``shard`` the victim
    for ``shard_loss``."""

    site: str
    kind: str = "raise"
    every: int = 0
    rate: float = 0.0
    delay_s: float = 0.0
    shard: int = 0
    max_injections: Optional[int] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.every <= 0 and self.rate <= 0.0:
            raise ValueError("FaultSpec needs every>0 or rate>0")


class FaultPlan:
    """A seeded set of :class:`FaultSpec`\\ s plus exact injection
    accounting.  All decisions happen under one lock (poke sites are
    failure paths or per-item hooks, not per-token hot loops)."""

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._seq: Dict[str, int] = {}          # site -> pokes seen
        #: fired faults per (site, kind) — the bench's "injected" side
        self.injected: Dict[Tuple[str, str], int] = {}
        self._rngs = [random.Random((self.seed << 8) ^ (i * 0x9E3779B9))
                      for i in range(len(self.specs))]

    def _fire(self, site: str, kinds: Tuple[str, ...]):
        """Under the lock: advance the site's poke counter and return the
        specs that fire this poke (in declaration order)."""
        fired = []
        with self._lock:
            seq = self._seq.get(site, 0) + 1
            self._seq[site] = seq
            for i, spec in enumerate(self.specs):
                if spec.site != site or spec.kind not in kinds:
                    continue
                key = (site, spec.kind)
                if (spec.max_injections is not None
                        and self.injected.get(key, 0) >= spec.max_injections):
                    continue
                hit = (spec.every > 0 and seq % spec.every == 0) or (
                    spec.rate > 0.0 and self._rngs[i].random() < spec.rate)
                if hit:
                    self.injected[key] = self.injected.get(key, 0) + 1
                    fired.append(spec)
        return fired

    # -- hook entry points ---------------------------------------------------

    def poke(self, site: str):
        """Item-level hook: may sleep (``slow``) and/or raise
        :class:`InjectedFault` (``raise``)."""
        fired = self._fire(site, ("raise", "slow"))
        if not fired:
            return
        boom = False
        for spec in fired:
            if spec.kind == "slow" and spec.delay_s > 0:
                time.sleep(spec.delay_s)
            elif spec.kind == "raise":
                boom = True
        if boom:
            obs.instant("sched", "fault", args={"site": site})
            raise InjectedFault(f"injected fault at {site}")

    def should_die(self, site: str = "sched.worker") -> bool:
        """Worker-loop hook: True when a ``worker_death`` spec fires."""
        fired = self._fire(site, ("worker_death",))
        if fired:
            obs.instant("sched", "fault", args={"site": site,
                                                "kind": "worker_death"})
            return True
        return False

    def lost_shard(self, site: str = "ep.round") -> Optional[int]:
        """EP-round hook: the victim shard index when a ``shard_loss``
        spec fires, else None."""
        fired = self._fire(site, ("shard_loss",))
        if fired:
            shard = fired[0].shard
            obs.instant("sched", "fault", args={"site": site, "shard": shard})
            return shard
        return None

    # -- accounting ----------------------------------------------------------

    def injected_total(self, site: Optional[str] = None,
                       kind: Optional[str] = None) -> int:
        with self._lock:
            return sum(c for (s, k), c in self.injected.items()
                       if (site is None or s == site)
                       and (kind is None or k == kind))

    def summary(self) -> Dict[str, int]:
        with self._lock:
            return {f"{s}/{k}": c for (s, k), c in sorted(self.injected.items())}


# -- process-wide hook (default off) -----------------------------------------

_PLAN: Optional[FaultPlan] = None


def active() -> Optional[FaultPlan]:
    """The installed plan, or None.  Hook sites read this exactly once
    per poke; None is the (default) free path."""
    return _PLAN


def install(plan: FaultPlan):
    global _PLAN
    _PLAN = plan


def uninstall():
    global _PLAN
    _PLAN = None


@contextmanager
def injected_faults(plan: FaultPlan):
    """``with faults.injected_faults(FaultPlan([...], seed=0)) as plan:``
    — installs the plan for the block, uninstalls on exit (also on
    raise), and yields it for injection accounting."""
    install(plan)
    try:
        yield plan
    finally:
        uninstall()


# -- retries -----------------------------------------------------------------

@dataclass(frozen=True)
class RetryPolicy:
    """Bounded attempts with exponential backoff and deterministic
    seeded jitter.  Jitter keys must be *stable integers* (shard index,
    slot index) — never ``hash(str)``, which is salted per process and
    would unseed the schedule."""

    attempts: int = 3
    base_delay_s: float = 0.0     # 0 = no sleeping (test/bench default)
    max_delay_s: float = 0.05
    backoff: float = 2.0
    jitter: float = 0.25          # fraction of the delay, uniform
    seed: int = 0

    def __post_init__(self):
        if self.attempts < 1:
            raise ValueError("RetryPolicy.attempts must be >= 1")

    def delay_s(self, attempt: int, key: int = 0) -> float:
        """Backoff before retry number ``attempt`` (0-based) of the task
        keyed ``key``.  Deterministic: same (seed, key, attempt) → same
        delay."""
        if self.base_delay_s <= 0:
            return 0.0
        d = min(self.base_delay_s * (self.backoff ** attempt),
                self.max_delay_s)
        rng = random.Random((self.seed << 24) ^ (int(key) << 8) ^ attempt)
        return d * (1.0 + self.jitter * rng.random())

    def run(self, fn: Callable[[], "object"], *, key: int = 0,
            site: str = "retry", telemetry=None,
            retry_on: Tuple[Type[BaseException], ...] = (Exception,),
            sleep: Callable[[float], None] = time.sleep):
        """Call ``fn`` up to ``attempts`` times.  Each retry bumps
        ``telemetry.retries`` (via :meth:`record_retry`) and emits a
        ``sched.retry`` instant — emit-where-you-bump, so the obs
        conservation gate covers retries too.  The final failure
        propagates unwrapped."""
        last: Optional[BaseException] = None
        for attempt in range(self.attempts):
            try:
                return fn()
            except retry_on as e:
                last = e
                if attempt + 1 >= self.attempts:
                    raise
                if telemetry is not None:
                    telemetry.record_retry(site)
                obs.instant("sched", "retry", args={"site": site})
                d = self.delay_s(attempt, key)
                if d > 0:
                    sleep(d)
        raise last  # unreachable; keeps type-checkers honest
