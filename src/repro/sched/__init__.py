"""repro.sched — the unified DLBC/DCAFE scheduling-policy engine.

The paper's core contribution is a *runtime policy*, not a compiler pass:
read the idle-worker count, chunk the remaining work equally among
``idle + 1`` workers with the remainder spread one-per-chunk from the
front and the smallest chunk kept by the caller, and fall back to a
serial block that re-probes after every iteration (Fig. 6, §3.2).

This package makes that policy a first-class, pluggable engine shared by
every execution surface in the repo:

* :mod:`repro.sched.policy` — ``SchedPolicy`` implementations (``Serial``,
  ``LC``, ``DLBC``, ``DCAFE``) driven by one canonical ``chunk_plan``
  that owns the Fig. 6 remainder-spread arithmetic;
* :mod:`repro.sched.capacity` — ``CapacityProvider`` abstractions over
  "idle workers": simulated workers, host threads, device decode slots;
* :mod:`repro.sched.executors` — ``ThreadExecutor`` (host thread pool,
  with a work-stealing variant) and ``SlotExecutor`` (device-slot
  admission for the serving batcher);
* :mod:`repro.sched.tenancy` — multi-tenant admission: per-tenant
  queues (``TenantRegistry``) and weighted deficit-round-robin refill
  (``WeightedRefillPolicy``, ``"wdlbc"``) over one slot executor;
* :mod:`repro.sched.faults` — seeded deterministic fault injection
  (``FaultPlan``: raise / slow / worker-death / shard-loss) behind a
  default-off hook, and bounded retries with deterministic backoff
  (``RetryPolicy``) — the paper's exception extension made testable;
* :mod:`repro.sched.telemetry` — Fig. 10-style spawn/join counters plus
  latency distributions (p50/p99) emitted as JSON for the benchmarks.

Consumers: ``repro.core.dlbc``/``repro.core.lc`` (IR codegen chunk
arithmetic), ``repro.core.runtime`` (simulated-worker capacity and
counters), ``repro.data.pool`` (host pool), ``repro.serve.batcher``
(slot refill).  See ``docs/sched.md``.
"""

from .capacity import (  # noqa: F401
    CapacityProvider, ExpertCapacityProvider, FixedCapacity, PoolCapacity,
    SimWorkerCapacity, SlotCapacity,
)
from .policy import (  # noqa: F401
    DCAFE, DLBC, LC, POLICIES, ChunkPlan, Decision, GrainController,
    GrainPlan, SchedPolicy, Serial, chunk_plan, fig6_chunk_end, fig6_eq,
    fig6_next, fig6_rem0, fig6_tot, get_policy, static_chunk_size,
    static_plan,
)
from .tenancy import (  # noqa: F401
    TenantQueue, TenantRegistry, WeightedRefillPolicy, ensure_weighted,
)
from .executors import (  # noqa: F401
    CancelToken, FinishScope, JoinOutcome, MultipleExceptions, RangeLatch,
    RangeTask, SlotExecutor, TaskError, TaskEvent, ThreadExecutor,
    WorkStealingExecutor,
)
from .faults import (  # noqa: F401
    FaultPlan, FaultSpec, InjectedFault, RetryPolicy, ShardLossError,
    WorkerDeath, injected_faults,
)
from .telemetry import (  # noqa: F401
    ExchangeCounters, LogHistogram, SchedCounters, SchedTelemetry,
    diff_counters, percentile,
)
