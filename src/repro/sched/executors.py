"""Executors: substrates that run work under a pluggable SchedPolicy.

* :class:`ThreadExecutor` — host thread pool (FIFO task queue), the
  generalisation of the old ``repro.data.pool.DLBCPool``.  ``run_loop``
  is the paper's three-block structure (chunked / parent / serial) with
  the *policy* deciding which arm to take at each step.
* :class:`WorkStealingExecutor` — per-worker deques under per-deque
  locks, with **lazy steal-driven splitting**: tasks carry ``(lo, hi)``
  ranges, the owner claims items off the front one at a time, a thief
  steals the back half of the largest stealable range, and the split
  recurses — grain adapts to observed imbalance with zero tuning.  Same
  ``run_loop``.
* :class:`FinishScope` — DCAFE on the host: spawned chunks escape their
  per-loop join to one outer scope (one join for many loops).
* :class:`SlotExecutor` — admission scheduling over fixed device decode
  slots for the continuous batcher (requests are single tasks; capacity
  is the idle-slot count).
"""

from __future__ import annotations

import queue
import random
import threading
import time
from collections import deque
from itertools import count
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from ..obs import trace as obs
from .capacity import PoolCapacity, SlotCapacity
from .policy import GrainPlan, SchedPolicy, get_policy
from .telemetry import SchedTelemetry
from .tenancy import TenantRegistry, ensure_weighted

# Tracing contract (repro.obs): an instant event is emitted at every
# site that bumps a SchedTelemetry counter — same name, same integer
# weight — so the exporter can re-derive spawns/joins/steals/splits/
# completions/errors from the trace and CI can assert they agree
# (docs/obs.md).  Worker busy time is spans with cat="worker"; stalls
# (join_stall, park, steal latency) are cat="sched".  Every emit is a
# single module-flag read when tracing is disabled.


class RangeLatch:
    """Countdown latch for one submitted range: fires once every item of
    ``[lo, hi)`` has executed, across however many steal-splits the range
    underwent.  Event-compatible (``wait``/``is_set``) so
    :class:`FinishScope` and ``run_loop`` joins treat it exactly like the
    per-task :class:`threading.Event` it coalesces — one waitable per
    submitted range instead of one per item, so DCAFE joins stay
    O(ranges)."""

    __slots__ = ("_remaining", "_lock", "_event")

    def __init__(self, n_items: int):
        self._remaining = n_items
        self._lock = threading.Lock()
        self._event = threading.Event()
        if n_items <= 0:
            self._event.set()

    def discharge(self, n: int):
        """Credit ``n`` executed items (workers call this once per drain
        session, not once per item)."""
        if n <= 0:
            return
        with self._lock:
            self._remaining -= n
            if self._remaining <= 0:
                self._event.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def is_set(self) -> bool:
        return self._event.is_set()


class RangeTask:
    """A stealable slice of one loop: run ``fn(items[j])`` for ``j`` in
    ``[lo, hi)``.  ``lo``/``hi`` are only ever mutated under the owning
    worker's deque lock: the owner claims ``lo`` forward one item at a
    time, a thief truncates ``hi`` to steal the back half.  All splits of
    a submitted range share one :class:`RangeLatch`."""

    __slots__ = ("items", "fn", "lo", "hi", "latch", "split_min", "active")

    def __init__(self, items: Sequence, fn: Callable, lo: int, hi: int,
                 latch: RangeLatch, split_min: int = 2):
        self.items = items
        self.fn = fn
        self.lo = lo
        self.hi = hi
        self.latch = latch
        self.split_min = max(2, split_min)
        #: True while an owning worker's drain session holds this task
        #: (set/read only under the holding deque's lock).  A helper may
        #: take the last item of — and remove — only *inactive* tasks;
        #: an active task's last item belongs to its already-awake owner.
        self.active = False

    def run(self, j: int):
        fn = self.fn
        if self.items is None:  # single-callable submit() wrapper
            fn()
        else:
            fn(self.items[j])


class FinishScope:
    """Collects escaped joins (DCAFE): ``with executor.finish() as f:``
    runs many loops but performs ONE join at scope exit.  Holds any
    waitable with Event semantics — per-task events from the FIFO pool,
    per-range :class:`RangeLatch`\\ es from the work-stealing pool."""

    def __init__(self, telemetry: Optional[SchedTelemetry] = None):
        self._events: List[Any] = []
        self.telemetry = telemetry

    def add(self, events: Sequence[Any]):
        self._events.extend(events)

    def join(self):
        with obs.trace_span("sched", "join_stall"):
            for ev in self._events:
                ev.wait()
        self._events.clear()
        if self.telemetry is not None:
            with self.telemetry.lock:
                self.telemetry.joins += 1
            obs.instant("sched", "join")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.join()
        return False


class ThreadExecutor:
    """DLBC worker pool — the paper's runtime policy on real host threads.

    Host-side work in a TPU stack (data shard preparation, checkpoint I/O,
    request batching) is CPU task-parallelism, so DCAFE applies literally:
    the idle count is read without a lock (the benign race, §3.2.1), the
    policy decides between the chunked/parent arms and the re-probing
    serial arm, and telemetry mirrors Fig. 10 (spawns/joins).
    """

    #: Max items per spawned task; ``None`` = one task per planned chunk.
    #: The work-stealing variant narrows this so thieves have something
    #: to steal when cost skew piles up in one chunk.
    chunk_grain: Optional[int] = None

    def __init__(self, n_workers: int = 4,
                 telemetry: Optional[SchedTelemetry] = None):
        self.n_workers = n_workers
        self._q: "queue.Queue" = queue.Queue()
        self._idle = n_workers  # racy read by design (paper §3.2.1)
        self._idle_lock = threading.Lock()
        #: by-name policy resolutions, cached per executor so policy
        #: state — the DLBC grain controller's steal-feedback baseline —
        #: persists across run_loop calls instead of dying with a fresh
        #: instance every loop (racy insert is benign: one winner stays)
        self._policy_cache: Dict[str, SchedPolicy] = {}
        self.telemetry = telemetry or SchedTelemetry()
        self.capacity = PoolCapacity(self)
        self._threads = [
            # named threads: the trace exporter shows one track per
            # worker, labelled by executor class and worker index
            threading.Thread(target=self._worker, daemon=True,
                             name=f"{type(self).__name__}-w{i}")
            for i in range(n_workers)
        ]
        for t in self._threads:
            t.start()

    # -- worker loop ---------------------------------------------------------

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, done = item
            with self._idle_lock:
                self._idle -= 1
            try:
                with obs.trace_span("worker", "task"):
                    fn()
            except Exception:
                # Contain task exceptions: the worker thread survives, the
                # done event still fires, so joins (and FinishScope) never
                # hang on a raising task.  Uncontained, the exception would
                # silently kill the thread and shrink the pool forever.
                with self.telemetry.lock:
                    self.telemetry.errors += 1
                obs.instant("sched", "error")
            finally:
                with self._idle_lock:
                    self._idle += 1
                with self.telemetry.lock:
                    self.telemetry.completions += 1
                obs.instant("sched", "complete")
                done.set()

    def _submit(self, fn: Callable[[], None]) -> threading.Event:
        ev = threading.Event()
        with self.telemetry.lock:
            self.telemetry.spawns += 1
        obs.instant("sched", "spawn")
        self._q.put((fn, ev))
        return ev

    def submit(self, fn: Callable[[], None]) -> threading.Event:
        """Public single-task entry point (dispatches through the
        subclass's ``_submit``); same spawn accounting as ``run_loop``."""
        return self._submit(fn)

    def idle_workers(self) -> int:
        return self._idle  # intentionally unlocked read

    def shutdown(self):
        for _ in self._threads:
            self._q.put(None)

    def finish(self) -> FinishScope:
        """Open a DCAFE finish scope for escaped joins."""
        return FinishScope(self.telemetry)

    # -- grain: how a planned chunk becomes spawned tasks --------------------

    def _grain_plan(self, n: int, policy: SchedPolicy) -> GrainPlan:
        """An explicit ``chunk_grain`` wins; the FIFO pool otherwise keeps
        one task per planned chunk (nothing to steal from a shared queue,
        so pre-splitting only adds overhead)."""
        return GrainPlan(initial=self.chunk_grain)

    def _spawn_range(self, items: Sequence, fn: Callable, lo: int, hi: int,
                     grain: GrainPlan) -> List[Any]:
        """Spawn ``[lo, hi)`` as tasks of at most ``grain.initial`` items;
        returns the waitables the join (or finish scope) collects."""
        t = self.telemetry
        step = grain.initial or (hi - lo)
        events = []
        for a in range(lo, hi, step):
            b = min(a + step, hi)

            def task(a=a, b=b):
                for j in range(a, b):
                    t0 = time.perf_counter()
                    try:
                        fn(items[j])
                    except Exception:
                        with t.lock:
                            t.errors += 1
                        obs.instant("sched", "error")
                    finally:
                        t.record_latency(time.perf_counter() - t0)

            events.append(self._submit(task))
        return events

    def _join(self, events: Sequence[Any]) -> None:
        """Wait for every spawned task of one loop (the per-loop join)."""
        for ev in events:
            ev.wait()

    # -- policy-driven loop execution ----------------------------------------

    def run_loop(self, items: Sequence, fn: Callable,
                 policy: Union[str, SchedPolicy, None] = None,
                 scope: Optional[FinishScope] = None) -> None:
        """Execute ``fn(item)`` for every item under the given policy.

        This is the paper's three-block loop: the policy's ``decide``
        picks the parallel arm (spawn the planned chunks, run the caller
        chunk here, join — or escape the join into ``scope`` for DCAFE)
        or the serial arm (one item at a time, re-probing capacity).

        Exception contract: every SPAWNED item is attempted — an item
        whose ``fn`` raises is counted in ``telemetry.errors`` and the
        rest of its chunk still runs (without per-item containment a
        raise would silently drop the chunk's remaining items).  Items
        executed on the CALLING thread (the caller's chunk, the serial
        block) propagate like a plain ``for`` loop.
        """
        if policy is None or isinstance(policy, str):
            key = policy or "dlbc"
            cached = self._policy_cache.get(key)
            if cached is None:
                cached = self._policy_cache[key] = get_policy(key)
            policy = cached
        else:
            policy = get_policy(policy)
        t = self.telemetry
        n = len(items)
        i = 0

        def run_item(j: int, serial: bool):
            t0 = time.perf_counter()
            fn(items[j])
            t.record_latency(time.perf_counter() - t0)
            with t.lock:
                if serial:
                    t.serial_items += 1
                else:
                    t.parallel_items += 1

        while i < n:
            decision = policy.decide(i, n, self.capacity)
            if decision.plan is not None:
                plan = decision.plan
                grain = self._grain_plan(n - i, policy)
                events = []
                for lo, hi in plan.spawned:
                    events.extend(self._spawn_range(items, fn, lo, hi, grain))
                    with t.lock:
                        t.parallel_items += hi - lo
                # parent block: the caller's (smallest) chunk.  Caller
                # items propagate like a plain for loop (see docstring),
                # so the per-item telemetry is batched outside the lock.
                ca, cb = plan.caller
                if cb > ca:
                    with obs.trace_span("worker", "caller"):
                        for j in range(ca, cb):
                            t0 = time.perf_counter()
                            fn(items[j])
                            t.record_latency(time.perf_counter() - t0)
                    with t.lock:
                        t.parallel_items += cb - ca
                if policy.escape_join and scope is not None:
                    scope.add(events)  # DCAFE: join escapes to the scope
                else:
                    with obs.trace_span("sched", "join_stall"):
                        self._join(events)
                    with t.lock:
                        t.joins += 1
                    obs.instant("sched", "join")
                return
            # serial block with periodic capacity re-probe (cadence counts
            # items processed in THIS block, not the absolute index)
            resumed = False
            every = decision.recheck_every
            done_in_block = 0
            with obs.trace_span("worker", "serial"):
                while i < n:
                    run_item(i, serial=True)
                    i += 1
                    done_in_block += 1
                    if (every > 0 and (done_in_block % every == 0)
                            and self.capacity.idle() > 0 and (n - i) >= 2):
                        resumed = True
                        break
            if not resumed:
                return


#: Failed steal scans before a worker parks.  The backoff is a
#: ``sched_yield`` (``time.sleep(0)``): microseconds, not the old 0.1 s
#: global-lock poll, so a worker re-probes a few times while work is
#: still being submitted and only then pays for a real park.
_SPIN_TRIES = 4
#: Parked-worker wait backstop, seconds.  The wakeup protocol (register →
#: re-check → wait; producers push *then* unpark) makes a lost wakeup
#: impossible, so this only bounds the damage of a protocol bug.
_PARK_TIMEOUT = 0.1
#: How long a joining caller waits before it starts helping (claiming
#: items itself).  0 = help immediately: on loops too small to cover the
#: workers' wakeup latency the caller drains stragglers' ranges itself,
#: degrading gracefully toward serial speed instead of sleeping.
_HELP_GRACE = 0.0
#: Items a helper claims per lock acquisition when recent item costs
#: look uniform (batch amortisation); skewed costs force batch = 1.
_HELP_BATCH = 8


class WorkStealingExecutor(ThreadExecutor):
    """Per-worker deques, per-deque locks, lazy steal-driven splitting.

    Tasks carry ``(lo, hi)`` ranges (:class:`RangeTask`) instead of
    single items.  The **owner** claims items off the front of its front
    task one at a time (one uncontended lock acquisition per item — no
    queue round-trip, no per-item event).  A **thief** with an empty
    deque scans victims from a randomised start, picks the largest range
    with at least ``split_min`` items left, and steals its *back half*
    by truncating ``hi`` — the stolen half lands on the thief's own
    deque, where it is itself stealable, so the split recurses and grain
    adapts to observed imbalance with zero tuning.  When only
    single-item tasks remain, the back task is stolen whole (classic
    Arora–Blumofe–Plotkin).

    Synchronisation: one lock per deque (owner claim and thief split of
    the same range serialise on the *victim's* lock; disjoint deques
    never contend) plus a parked-worker protocol — an out-of-work worker
    backs off briefly, registers itself parked, re-checks every deque,
    and sleeps on its own event until a producer pushes work — replacing
    the old single global condition variable and its 0.1 s poll.  Joins:
    every submitted range gets ONE :class:`RangeLatch` shared by all its
    splits, so a DCAFE :class:`FinishScope` holds O(ranges) waitables,
    not O(items).

    Counter contract (all bumps under ``telemetry.lock``): ``spawns``
    counts task creations (submits + splits), ``completions`` counts
    tasks drained to exhaustion — ``spawns == completions`` at
    quiescence; ``steals`` counts successful steals (``splits`` of them
    split a range; ``steal_victims`` histograms who they hit).
    """

    #: ``None`` = adaptive: ranges are carved per the policy's
    #: ``grain_plan`` (ceil(n / (k·workers)) items each) and re-split on
    #: steal.  Set an int (e.g. 1) to force a fixed grain — the
    #: benchmark baselines do.
    chunk_grain: Optional[int] = None

    def __init__(self, n_workers: int = 4,
                 telemetry: Optional[SchedTelemetry] = None):
        self._locks = [threading.Lock() for _ in range(n_workers)]
        self._deques: List[deque] = [deque() for _ in range(n_workers)]
        self._stop = False
        self._rr = count()
        self._park_lock = threading.Lock()
        self._park_events = [threading.Event() for _ in range(n_workers)]
        self._parked: set = set()
        super().__init__(n_workers, telemetry)

    # -- submission ----------------------------------------------------------

    def _place(self, task: RangeTask):
        """Round-robin a task onto a worker deque and wake someone —
        preferably that deque's owner, so work does not sit in a parked
        worker's deque until another worker happens to scan it."""
        v = next(self._rr) % self.n_workers
        with self._locks[v]:
            self._deques[v].append(task)
        self._unpark(prefer=v)

    def _submit(self, fn: Callable[[], None]) -> RangeLatch:
        """Single-callable entry point (``submit``/base helpers): a
        one-item range."""
        latch = RangeLatch(1)
        with self.telemetry.lock:
            self.telemetry.spawns += 1
        obs.instant("sched", "spawn")
        self._place(RangeTask(None, fn, 0, 1, latch))
        return latch

    def _grain_plan(self, n: int, policy: SchedPolicy) -> GrainPlan:
        if self.chunk_grain:
            return GrainPlan(initial=self.chunk_grain)
        return policy.grain_plan(n, self.capacity, self.telemetry)

    def _spawn_range(self, items, fn, lo, hi, grain: GrainPlan):
        """Carve ``[lo, hi)`` into initial ranges and place them in one
        wave: one spawn-counter bump, one deque push per range, then one
        unpark sweep — the submit path is O(ranges), not O(items)."""
        step = grain.initial or (hi - lo)
        tasks = []
        for a in range(lo, hi, step):
            b = min(a + step, hi)
            tasks.append(RangeTask(items, fn, a, b, RangeLatch(b - a),
                                   grain.split_min))
        with self.telemetry.lock:
            self.telemetry.spawns += len(tasks)
        obs.instant("sched", "spawn", n=len(tasks))
        owners = set()
        for task in tasks:
            v = next(self._rr) % self.n_workers
            with self._locks[v]:
                self._deques[v].append(task)
            owners.add(v)
        for v in owners:
            self._unpark(prefer=v)
        return [task.latch for task in tasks]

    # -- worker loop ---------------------------------------------------------

    def _worker(self):
        w = self._threads.index(threading.current_thread())
        rng = random.Random(0x5EED ^ (w * 0x9E3779B9))
        attempts = 0
        while True:
            if self._drain_own(w):
                attempts = 0
                continue
            if self._try_steal(w, rng):
                attempts = 0
                continue
            if self._stop:
                # Drain semantics matching ThreadExecutor's sentinel
                # queue: exit only once no work is visible anywhere, so
                # already-submitted tasks still run and their latches
                # fire (a FinishScope.join never hangs).
                return
            attempts += 1
            if attempts <= _SPIN_TRIES:
                time.sleep(0)  # sched_yield: bounded, near-free backoff
            else:
                self._park(w)

    def _drain_own(self, w: int) -> bool:
        """Run every task on our own deque to exhaustion.  Returns True
        if any work was found (the caller then re-scans immediately)."""
        lock, dq = self._locks[w], self._deques[w]
        if not dq:  # racy peek: cheap fast path past empty deques
            return False
        with self._idle_lock:
            self._idle -= 1
        worked = False
        try:
            while True:
                with lock:
                    if not dq:
                        return worked
                    task = dq[0]
                    task.active = True  # helpers now leave the pop to us
                worked = True
                self._drain_task(w, task)
        finally:
            with self._idle_lock:
                self._idle += 1

    def _drain_task(self, w: int, task: RangeTask):
        """One drain session: claim items off the front of ``task`` (our
        deque's front, which only we ever pop) until it is exhausted —
        naturally or by thieves truncating ``hi`` — then pop it and
        credit its latch once with everything we ran."""
        lock, dq = self._locks[w], self._deques[w]
        ran = 0
        try:
            with obs.trace_span("worker", "drain"):
                while True:
                    with lock:
                        if task.lo >= task.hi:
                            dq.popleft()  # ours: helpers skip active
                            return        # tasks' last items, thieves
                            #               never pop front
                        j = task.lo
                        task.lo = j + 1
                    self._run_item(task, j)
                    ran += 1
        finally:
            # completions before the latch: a joiner woken by the final
            # discharge must already observe spawns == completions
            with self.telemetry.lock:
                self.telemetry.completions += 1
            obs.instant("sched", "complete")
            task.latch.discharge(ran)

    def _run_item(self, task: RangeTask, j: int):
        t = self.telemetry
        t0 = time.perf_counter()
        try:
            task.run(j)
        except Exception:
            # same containment contract as ThreadExecutor._worker: the
            # worker survives, the claimed item still counts, the latch
            # still fires
            with t.lock:
                t.errors += 1
            obs.instant("sched", "error")
        finally:
            t.record_latency(time.perf_counter() - t0)

    # -- helping join --------------------------------------------------------

    def _join(self, events: Sequence[Any]) -> None:
        """Join by *helping*: the caller claims items off the largest
        visible range until every latch fires.  This is what ranges buy
        over per-item tasks — a joiner can contribute to exactly the
        range that is behind, so a heavy head never strands on one worker
        while the caller sleeps, and a loop too small to cover the
        workers' wakeup latency degrades gracefully toward serial speed
        (the helper takes over owner-less tasks entirely, see
        :meth:`_help_one`).  An optional grace period (``_HELP_GRACE``)
        can keep the caller off the deque locks on loops expected to
        join immediately."""
        pending = [ev for ev in events if not ev.is_set()]
        if not pending:
            return
        if _HELP_GRACE > 0:
            deadline = time.perf_counter() + _HELP_GRACE
            for ev in pending:
                left = deadline - time.perf_counter()
                if left <= 0 or not ev.wait(timeout=left):
                    break
            pending = [ev for ev in pending if not ev.is_set()]
        # Helper claim granularity from the same feedback signal the
        # grain controller uses: uniform recent item costs → batch claims
        # (amortise the lock over several items); skewed costs → one item
        # at a time, so the helper never walks off with a heavy head.
        batch = _HELP_BATCH if self.telemetry.recent_skew() < 2.0 else 1
        idle_rounds = 0
        while pending:
            if self._help_one(batch):
                idle_rounds = 0
            elif idle_rounds < _SPIN_TRIES:
                # nothing claimable but latches unset: the owners hold
                # only their final items — yield them the core instead
                # of oversleeping a futex quantum
                idle_rounds += 1
                time.sleep(0)
            else:
                pending[0].wait(timeout=5e-4)
            pending = [ev for ev in pending if not ev.is_set()]

    def _help_one(self, batch: int = 1) -> bool:
        """Claim and run up to ``batch`` items from the largest helpable
        range.  Find and claim happen under one hold of that deque's
        lock — a task's range is only ever mutated under the lock of the
        deque currently holding it.  An *active* task (an owner session
        holds it) is helpable down to its last item, which stays with
        the owner; an *inactive* task (its owner is parked or busy
        elsewhere) can be taken over entirely — claiming its last item
        removes it, so a join never stalls on a wakeup for microseconds
        of work."""
        for v in range(self.n_workers):
            if not self._deques[v]:  # racy peek
                continue
            lock, dq = self._locks[v], self._deques[v]
            with lock:
                best, best_sz = None, 0
                for task in dq:
                    sz = task.hi - task.lo
                    if sz > best_sz and (sz >= 2 or not task.active):
                        best, best_sz = task, sz
                if best is None:
                    continue
                take = min(batch, best_sz - 1 if best.active else best_sz)
                j = best.lo
                best.lo = j + take
                removed = best.lo >= best.hi and not best.active
                if removed:
                    dq.remove(best)
            for jj in range(j, j + take):
                self._run_item(best, jj)
            if removed:
                with self.telemetry.lock:
                    self.telemetry.completions += 1
                obs.instant("sched", "complete")
            best.latch.discharge(take)
            return True
        return False

    # -- stealing ------------------------------------------------------------

    def _try_steal(self, w: int, rng: random.Random) -> bool:
        """Scan victims from a randomised start (no worker-0 hotspot) and
        take the first steal that lands; the loot goes to the front of
        our own deque, where it is immediately drainable — and itself
        stealable, so splitting recurses."""
        n = self.n_workers
        # clock read only when tracing: steal latency = scan start →
        # loot landed; failed scans (idle spinning) emit nothing
        t0 = obs.perf_counter_ns() if obs.enabled() else 0
        start = rng.randrange(n)
        for d in range(n):
            v = (start + d) % n
            if v == w:
                continue
            loot = self._steal_from(v)
            if loot is None:
                continue
            task, split = loot
            with self._locks[w]:
                self._deques[w].appendleft(task)
            t = self.telemetry
            with t.lock:
                t.steals += 1
                t.steal_victims[v] = t.steal_victims.get(v, 0) + 1
                if split:
                    t.splits += 1
                    t.spawns += 1  # a split mints a new task
            if obs.enabled():
                obs.complete_span("sched", "steal", t0, {"victim": v})
                obs.instant("sched", "steal", args={"victim": v})
                if split:
                    obs.instant("sched", "split")
                    obs.instant("sched", "spawn")  # the minted task
            return True
        return False

    def _steal_from(self, v: int) -> Optional[Tuple[RangeTask, bool]]:
        """Under the victim's deque lock: split the largest splittable
        range (steal its back half), else pop a whole queued task off the
        back.  The front task is never popped by a thief — its owner may
        be mid-claim — but it *is* splittable, because a split only
        truncates ``hi`` above the owner's claim cursor."""
        lock, dq = self._locks[v], self._deques[v]
        if not dq:  # racy peek, see _drain_own
            return None
        with lock:
            if not dq:
                return None
            best = None
            for task in dq:
                size = task.hi - task.lo
                if size >= task.split_min and (
                        best is None or size > best.hi - best.lo):
                    best = task
            if best is not None:
                # back half to the thief, the odd item stays with the
                # owner (who is already consuming lo forward)
                mid = best.lo + (best.hi - best.lo + 1) // 2
                stolen = RangeTask(best.items, best.fn, mid, best.hi,
                                   best.latch, best.split_min)
                best.hi = mid
                return stolen, True
            if len(dq) >= 2:
                return dq.pop(), False
            return None

    # -- parking -------------------------------------------------------------

    def _unpark(self, prefer: Optional[int] = None, all_workers: bool = False):
        with self._park_lock:
            if all_workers:
                woken, self._parked = set(self._parked), set()
            elif prefer is not None and prefer in self._parked:
                self._parked.discard(prefer)
                woken = {prefer}
            elif self._parked:
                woken = {self._parked.pop()}
            else:
                return
        for v in woken:
            self._park_events[v].set()

    def _park(self, w: int):
        """Register parked, re-check for work, then sleep until a
        producer's unpark (or the backstop timeout).  The register-then-
        re-check order pairs with the producers' push-then-unpark order:
        any push racing our scan either lands before the scan reads that
        deque (we see it) or unparks us afterwards (we are registered)."""
        ev = self._park_events[w]
        with self._park_lock:
            ev.clear()
            self._parked.add(w)
        # Re-check only our own deque: cross-deque work is covered by the
        # producers' push-then-unpark order, and re-checking every deque
        # here would busy-spin whenever the only remaining work is an
        # unstealable front task some owner is already draining.
        if self._stop or self._deques[w]:
            with self._park_lock:
                self._parked.discard(w)
            return
        with obs.trace_span("sched", "park"):
            ev.wait(timeout=_PARK_TIMEOUT)
        with self._park_lock:
            self._parked.discard(w)

    def shutdown(self):
        self._stop = True
        self._unpark(all_workers=True)


class SlotExecutor:
    """Admission scheduling over fixed device slots (continuous batching).

    A queued request is one task; an idle slot is an idle worker.  The
    policy's ``admit`` applies the paper's spawn rule: DLBC admits into
    every idle slot at every decode step (per-iteration re-check), LC
    waits for a full batch of free slots (static chunking of requests).
    Refills are FIFO with oldest request → lowest slot index — the
    remainder-spread priority of Fig. 6.

    ``refill`` accepts either a plain FIFO list (the single-queue serving
    path, unchanged) or a :class:`~repro.sched.tenancy.TenantRegistry`:
    the policy still decides *how many* requests the idle slots admit,
    and the weighted deficit-round-robin decides *which tenant* each
    admission comes from.  The executor keeps per-tenant occupancy
    (``slot_tenant``) so slot-share accounting and the per-tenant
    spawn/join telemetry stay with the one object that owns the slots.
    """

    def __init__(self, n_slots: int,
                 policy: Union[str, SchedPolicy, None] = "dlbc",
                 telemetry: Optional[SchedTelemetry] = None):
        self.n_slots = n_slots
        self.policy = get_policy(policy)
        self.telemetry = telemetry or SchedTelemetry()
        #: which tenant occupies each slot (None = idle / anonymous)
        self.slot_tenant: List[Optional[str]] = [None] * n_slots
        self._weighted: Optional[Any] = None  # lazily wrapped policy

    def _admit_count(self, n_idle: int, n_queued: int) -> int:
        # clamp: a custom policy may over-admit; never index past the idle
        # slots or pop an empty queue
        return min(self.policy.admit(n_idle, n_queued, self.n_slots),
                   n_idle, n_queued)

    def refill(self, slots: Sequence[Optional[Any]],
               queue: Union[List, TenantRegistry]) -> List[Tuple[int, Any]]:
        """Pop up to ``policy.admit(...)`` requests and pair them with idle
        slots (oldest request → lowest slot).  Mutates ``queue``."""
        if isinstance(queue, TenantRegistry):
            return self.refill_tenants(slots, queue)
        cap = SlotCapacity(list(slots))
        idle = cap.idle_indices()
        k = self._admit_count(len(idle), len(queue))
        placements = [(idle[j], queue.pop(0)) for j in range(k)]
        with self.telemetry.lock:
            self.telemetry.spawns += len(placements)
        if placements:
            obs.instant("sched", "spawn", n=len(placements))
            obs.instant("serve", "admit", n=len(placements))
        return placements

    def weighted_policy(self):
        """Resolve (and cache) the cross-tenant refill policy.  Raises
        for escape-join bases (DCAFE) — call at configuration time to
        fail fast rather than on the first mid-run refill."""
        if self._weighted is None:
            self._weighted = ensure_weighted(self.policy)
        return self._weighted

    def refill_tenants(self, slots: Sequence[Optional[Any]],
                       registry: TenantRegistry) -> List[Tuple[int, Any]]:
        """Tenant-aware refill: the base policy's idle-slot arithmetic
        sizes the admission, the deficit round-robin picks the tenants.
        Returns ``(slot, request)`` pairs; ``slot_tenant`` and the
        per-tenant spawn counters record who got each slot."""
        pol = self.weighted_policy()
        cap = SlotCapacity(list(slots))
        idle = cap.idle_indices()
        k = self._admit_count(len(idle), registry.total_queued())
        placements: List[Tuple[int, Any]] = []
        for j, (tenant, req) in enumerate(pol.pick(registry, k)):
            slot = idle[j]
            self.slot_tenant[slot] = tenant.name
            self.telemetry.tenant(tenant.name).spawns += 1
            placements.append((slot, req))
        with self.telemetry.lock:
            self.telemetry.spawns += len(placements)
        if placements:
            obs.instant("sched", "spawn", n=len(placements))
            obs.instant("serve", "admit", n=len(placements))
        return placements

    def prefill(self, slot: int, ntokens: int):
        """One prefill chunk of ``ntokens`` prompt tokens executed
        in-place in ``slot`` (DLBC worksharing: the chunk runs on the
        slot that owns the request, no task is created for it).

        Counted in the dedicated ``prefill_chunks``/``prefill_tokens``
        counters — deliberately NOT in spawns/joins: the serving AFE
        contract is one FinishScope join per REQUEST, and chunk
        accounting must never disturb the ``spawns == joins``
        quiescence invariant the CI gates replay.  Emits a
        ``serve.prefill_chunk`` instant so the trace shows every chunk
        without inflating the conservation-gated spawn/join events."""
        with self.telemetry.lock:
            self.telemetry.prefill_chunks += 1
            self.telemetry.prefill_tokens += int(ntokens)
        name = self.slot_tenant[slot]
        if name is not None:
            bucket = self.telemetry.tenant(name)
            bucket.prefill_chunks += 1
            bucket.prefill_tokens += int(ntokens)
        obs.instant("serve", "prefill_chunk", n=int(ntokens))

    def tenant_busy_slots(self) -> Dict[str, int]:
        """Occupied-slot count per tenant right now (slot-share
        accounting: the serving stats integrate this every step)."""
        out: Dict[str, int] = {}
        for name in self.slot_tenant:
            if name is not None:
                out[name] = out.get(name, 0) + 1
        return out

    def complete(self, latency_steps: Optional[float] = None,
                 slot: Optional[int] = None):
        """A sequence finished: count the join (finish analogue); with a
        ``slot`` the tenant occupancy is released and the join lands on
        that tenant's counters too."""
        with self.telemetry.lock:
            self.telemetry.joins += 1
        obs.instant("sched", "join")
        if slot is not None:
            name = self.slot_tenant[slot]
            if name is not None:
                self.telemetry.tenant(name).joins += 1
            self.slot_tenant[slot] = None
        if latency_steps is not None:
            self.telemetry.record_latency(latency_steps)
