"""Executors: substrates that run work under a pluggable SchedPolicy.

* :class:`ThreadExecutor` — host thread pool (FIFO task queue), the
  generalisation of the old ``repro.data.pool.DLBCPool``.  ``run_loop``
  is the paper's three-block structure (chunked / parent / serial) with
  the *policy* deciding which arm to take at each step.
* :class:`WorkStealingExecutor` — per-worker deques; an idle worker
  steals from the back of a victim's deque.  Same ``run_loop``.
* :class:`FinishScope` — DCAFE on the host: spawned chunks escape their
  per-loop join to one outer scope (one join for many loops).
* :class:`SlotExecutor` — admission scheduling over fixed device decode
  slots for the continuous batcher (requests are single tasks; capacity
  is the idle-slot count).
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Any, Callable, List, Optional, Sequence, Tuple, Union

from .capacity import PoolCapacity, SlotCapacity
from .policy import SchedPolicy, get_policy
from .telemetry import SchedTelemetry


class FinishScope:
    """Collects escaped joins (DCAFE): ``with executor.finish() as f:``
    runs many loops but performs ONE join at scope exit."""

    def __init__(self, telemetry: Optional[SchedTelemetry] = None):
        self._events: List[threading.Event] = []
        self.telemetry = telemetry

    def add(self, events: Sequence[threading.Event]):
        self._events.extend(events)

    def join(self):
        for ev in self._events:
            ev.wait()
        self._events.clear()
        if self.telemetry is not None:
            self.telemetry.joins += 1

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.join()
        return False


class ThreadExecutor:
    """DLBC worker pool — the paper's runtime policy on real host threads.

    Host-side work in a TPU stack (data shard preparation, checkpoint I/O,
    request batching) is CPU task-parallelism, so DCAFE applies literally:
    the idle count is read without a lock (the benign race, §3.2.1), the
    policy decides between the chunked/parent arms and the re-probing
    serial arm, and telemetry mirrors Fig. 10 (spawns/joins).
    """

    #: Max items per spawned task; ``None`` = one task per planned chunk.
    #: The work-stealing variant narrows this so thieves have something
    #: to steal when cost skew piles up in one chunk.
    chunk_grain: Optional[int] = None

    def __init__(self, n_workers: int = 4,
                 telemetry: Optional[SchedTelemetry] = None):
        self.n_workers = n_workers
        self._q: "queue.Queue" = queue.Queue()
        self._idle = n_workers  # racy read by design (paper §3.2.1)
        self._idle_lock = threading.Lock()
        self.telemetry = telemetry or SchedTelemetry()
        self.capacity = PoolCapacity(self)
        self._threads = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(n_workers)
        ]
        for t in self._threads:
            t.start()

    # -- worker loop ---------------------------------------------------------

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, done = item
            with self._idle_lock:
                self._idle -= 1
            try:
                fn()
            finally:
                with self._idle_lock:
                    self._idle += 1
                done.set()

    def _submit(self, fn: Callable[[], None]) -> threading.Event:
        ev = threading.Event()
        self._q.put((fn, ev))
        return ev

    def idle_workers(self) -> int:
        return self._idle  # intentionally unlocked read

    def shutdown(self):
        for _ in self._threads:
            self._q.put(None)

    def finish(self) -> FinishScope:
        """Open a DCAFE finish scope for escaped joins."""
        return FinishScope(self.telemetry)

    # -- policy-driven loop execution ----------------------------------------

    def run_loop(self, items: Sequence, fn: Callable,
                 policy: Union[str, SchedPolicy, None] = None,
                 scope: Optional[FinishScope] = None) -> None:
        """Execute ``fn(item)`` for every item under the given policy.

        This is the paper's three-block loop: the policy's ``decide``
        picks the parallel arm (spawn the planned chunks, run the caller
        chunk here, join — or escape the join into ``scope`` for DCAFE)
        or the serial arm (one item at a time, re-probing capacity).
        """
        policy = get_policy(policy, default="dlbc")
        t = self.telemetry
        n = len(items)
        i = 0

        def run_item(j: int, serial: bool):
            t0 = time.perf_counter()
            fn(items[j])
            t.record_latency(time.perf_counter() - t0)
            if serial:
                t.serial_items += 1
            else:
                t.parallel_items += 1

        while i < n:
            decision = policy.decide(i, n, self.capacity)
            if decision.plan is not None:
                plan = decision.plan
                events = []
                for lo, hi in plan.spawned:
                    grain = self.chunk_grain or (hi - lo)
                    for a in range(lo, hi, grain):
                        b = min(a + grain, hi)

                        def task(a=a, b=b):
                            for j in range(a, b):
                                t0 = time.perf_counter()
                                fn(items[j])
                                t.record_latency(time.perf_counter() - t0)

                        events.append(self._submit(task))
                        t.spawns += 1
                        t.parallel_items += b - a
                # parent block: the caller's (smallest) chunk
                for j in range(*plan.caller):
                    run_item(j, serial=False)
                if policy.escape_join and scope is not None:
                    scope.add(events)  # DCAFE: join escapes to the scope
                else:
                    for ev in events:
                        ev.wait()
                    t.joins += 1
                return
            # serial block with periodic capacity re-probe (cadence counts
            # items processed in THIS block, not the absolute index)
            resumed = False
            every = decision.recheck_every
            done_in_block = 0
            while i < n:
                run_item(i, serial=True)
                i += 1
                done_in_block += 1
                if (every > 0 and (done_in_block % every == 0)
                        and self.capacity.idle() > 0 and (n - i) >= 2):
                    resumed = True
                    break
            if not resumed:
                return


class WorkStealingExecutor(ThreadExecutor):
    """Per-worker deques with back-end stealing.

    The owner pushes/pops its own deque at the front; an idle worker
    steals from the *back* of the first non-empty victim deque (classic
    Arora-Blumofe-Plotkin discipline), so contiguous cost skew spreads
    across workers even after the chunk plan is committed.  Tasks are
    per-item (``chunk_grain = 1``): a committed chunk stays stealable.
    """

    chunk_grain = 1

    def __init__(self, n_workers: int = 4,
                 telemetry: Optional[SchedTelemetry] = None):
        self._deques: List[deque] = [deque() for _ in range(n_workers)]
        self._cv = threading.Condition()
        self._stop = False
        self._rr = 0
        super().__init__(n_workers, telemetry)

    def _worker_index(self) -> int:
        me = threading.current_thread()
        return self._threads.index(me)

    def _worker(self):
        w = self._worker_index()
        while True:
            item = None
            with self._cv:
                while True:
                    if self._deques[w]:
                        item = self._deques[w].popleft()
                        break
                    stolen = False
                    for v in range(self.n_workers):
                        if v != w and self._deques[v]:
                            item = self._deques[v].pop()  # steal from back
                            self.telemetry.steals += 1
                            stolen = True
                            break
                    if stolen:
                        break
                    # Drain semantics matching ThreadExecutor's sentinel
                    # queue: stop only once every deque is empty, so
                    # already-submitted tasks still run and their done
                    # events fire (a FinishScope.join never hangs).
                    if self._stop:
                        return
                    self._cv.wait(timeout=0.1)
                self._idle -= 1
            fn, done = item
            try:
                fn()
            finally:
                with self._cv:
                    self._idle += 1
                done.set()

    def _submit(self, fn: Callable[[], None]) -> threading.Event:
        ev = threading.Event()
        with self._cv:
            self._deques[self._rr % self.n_workers].append((fn, ev))
            self._rr += 1
            self._cv.notify_all()
        return ev

    def shutdown(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()


class SlotExecutor:
    """Admission scheduling over fixed device slots (continuous batching).

    A queued request is one task; an idle slot is an idle worker.  The
    policy's ``admit`` applies the paper's spawn rule: DLBC admits into
    every idle slot at every decode step (per-iteration re-check), LC
    waits for a full batch of free slots (static chunking of requests).
    Refills are FIFO with oldest request → lowest slot index — the
    remainder-spread priority of Fig. 6.
    """

    def __init__(self, n_slots: int,
                 policy: Union[str, SchedPolicy, None] = "dlbc",
                 telemetry: Optional[SchedTelemetry] = None):
        self.n_slots = n_slots
        self.policy = get_policy(policy)
        self.telemetry = telemetry or SchedTelemetry()

    def refill(self, slots: Sequence[Optional[Any]],
               queue: List) -> List[Tuple[int, Any]]:
        """Pop up to ``policy.admit(...)`` requests and pair them with idle
        slots (oldest request → lowest slot).  Mutates ``queue``."""
        cap = SlotCapacity(list(slots))
        idle = cap.idle_indices()
        # clamp: a custom policy may over-admit; never index past the idle
        # slots or pop an empty queue
        k = min(self.policy.admit(len(idle), len(queue), self.n_slots),
                len(idle), len(queue))
        placements = [(idle[j], queue.pop(0)) for j in range(k)]
        self.telemetry.spawns += len(placements)
        return placements

    def complete(self, latency_steps: Optional[float] = None):
        """A sequence finished: count the join (finish analogue)."""
        self.telemetry.joins += 1
        if latency_steps is not None:
            self.telemetry.record_latency(latency_steps)
