"""Executors: substrates that run work under a pluggable SchedPolicy.

* :class:`ThreadExecutor` — host thread pool (FIFO task queue), the
  generalisation of the old ``repro.data.pool.DLBCPool``.  ``run_loop``
  is the paper's three-block structure (chunked / parent / serial) with
  the *policy* deciding which arm to take at each step.
* :class:`WorkStealingExecutor` — per-worker deques under per-deque
  locks, with **lazy steal-driven splitting**: tasks carry ``(lo, hi)``
  ranges, the owner claims items off the front one at a time, a thief
  steals the back half of the largest stealable range, and the split
  recurses — grain adapts to observed imbalance with zero tuning.  Same
  ``run_loop``.
* :class:`FinishScope` — DCAFE on the host: spawned chunks escape their
  per-loop join to one outer scope (one join for many loops).
* :class:`SlotExecutor` — admission scheduling over fixed device decode
  slots for the continuous batcher (requests are single tasks; capacity
  is the idle-slot count).
"""

from __future__ import annotations

import queue
import random
import threading
import time
import traceback
from collections import deque
from dataclasses import dataclass
from itertools import count
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from ..obs import metrics as obs_metrics
from ..obs import monitor as obs_monitor
from ..obs import trace as obs
from . import faults
from .capacity import PoolCapacity, SlotCapacity
from .policy import GrainPlan, SchedPolicy, get_policy
from .telemetry import SchedTelemetry
from .tenancy import TenantRegistry, ensure_weighted

# Tracing contract (repro.obs): an instant event is emitted at every
# site that bumps a SchedTelemetry counter — same name, same integer
# weight — so the exporter can re-derive spawns/joins/steals/splits/
# completions/errors from the trace and CI can assert they agree
# (docs/obs.md).  Worker busy time is spans with cat="worker"; stalls
# (join_stall, park, steal latency) are cat="sched".  Every emit is a
# single module-flag read when tracing is disabled.


#: Always-on metrics plane (repro.obs.metrics): handles are looked up
#: once here, then bumped per LOOP (never per item) — the same
#: scheduling-edge granularity that keeps tracing inside its 5% budget.
_MX_LOOPS = obs_metrics.counter("sched.loops")
_MX_ITEMS = obs_metrics.counter("sched.items")
_MX_LOOP_S = obs_metrics.histogram("sched.loop_s")

#: Max TaskErrors *stored* per waitable (latch / task event).  Counts
#: stay exact past the cap — ``MultipleExceptions.count`` and the
#: injected == collected gates never saturate — only the retained
#: exemplar objects are bounded, so an error storm cannot OOM the join.
_ERROR_CAP = 256


@dataclass
class TaskError:
    """One collected task/item failure: the cause plus where it ran —
    the per-task record a :class:`MultipleExceptions` aggregates (X10
    finish semantics: every finish knows *which* asyncs failed)."""

    exc: BaseException
    site: str = "sched.item"
    worker: Optional[int] = None
    lo: int = -1
    hi: int = -1
    tb: str = ""

    def summary(self) -> str:
        where = f"[{self.lo},{self.hi})" if self.lo >= 0 else "?"
        w = f"w{self.worker}" if self.worker is not None else "caller"
        return (f"{type(self.exc).__name__}({self.exc}) at {self.site} "
                f"{where} on {w}")


class MultipleExceptions(RuntimeError):
    """The aggregate a finish rethrows (X10 ``MultipleExceptions``):
    every exception of every transitively spawned task — across helped,
    stolen, and split ranges — with per-task cause, chunk range, and
    worker id.  ``count`` is exact even when the stored ``errors`` list
    was capped at ``_ERROR_CAP``."""

    def __init__(self, errors: Sequence[TaskError],
                 count: Optional[int] = None):
        self.errors: List[TaskError] = list(errors)
        self.count = int(count) if count is not None else len(self.errors)
        first = self.errors[0].summary() if self.errors else "?"
        super().__init__(f"{self.count} task exception(s); first: {first}")
        if self.errors:
            self.__cause__ = self.errors[0].exc


class TaskCancelled(Exception):
    """Internal unwind signal: a running chunk observed its scope's
    :class:`CancelToken` and stopped early.  Never escapes the executor
    — the worker counts the task cancelled, not completed."""


class CancelToken:
    """Cooperative cancellation flag threaded through chunk execution
    (``fail_fast``): the first collected error trips it, sibling chunks
    observe it at their next item boundary and skip the rest."""

    __slots__ = ("_event",)

    def __init__(self):
        self._event = threading.Event()

    def cancel(self):
        self._event.set()

    def cancelled(self) -> bool:
        return self._event.is_set()


def _collect_errors(events: Sequence[Any]) -> Tuple[List[TaskError], int]:
    """Gather collected TaskErrors (exact count, capped storage) from a
    set of joined waitables."""
    errors: List[TaskError] = []
    total = 0
    for ev in events:
        errs = getattr(ev, "errors", None)
        if errs:
            errors.extend(errs)
            total += getattr(ev, "error_count", len(errs))
    return errors[:_ERROR_CAP], total


class TaskEvent(threading.Event):
    """Per-task done event that also carries the task's collected
    errors.  A task runs on exactly one worker, so recording needs no
    lock beyond the GIL."""

    def __init__(self):
        super().__init__()
        self.errors: List[TaskError] = []
        self.error_count = 0

    def record_error(self, err: TaskError):
        self.error_count += 1
        if len(self.errors) < _ERROR_CAP:
            self.errors.append(err)


class RangeLatch:
    """Countdown latch for one submitted range: fires once every item of
    ``[lo, hi)`` has executed, across however many steal-splits the range
    underwent.  Event-compatible (``wait``/``is_set``) so
    :class:`FinishScope` and ``run_loop`` joins treat it exactly like the
    per-task :class:`TaskEvent` it coalesces — one waitable per
    submitted range instead of one per item, so DCAFE joins stay
    O(ranges).  Also the range's error sink: owner, thieves, and helpers
    all record raising items here, so the join sees every failure no
    matter which worker ran the item."""

    __slots__ = ("_remaining", "_lock", "_event", "errors", "error_count")

    def __init__(self, n_items: int):
        self._remaining = n_items
        self._lock = threading.Lock()
        self._event = threading.Event()
        self.errors: List[TaskError] = []
        self.error_count = 0
        if n_items <= 0:
            self._event.set()

    def record_error(self, err: TaskError):
        with self._lock:
            self.error_count += 1
            if len(self.errors) < _ERROR_CAP:
                self.errors.append(err)

    def discharge(self, n: int):
        """Credit ``n`` executed items (workers call this once per drain
        session, not once per item)."""
        if n <= 0:
            return
        with self._lock:
            self._remaining -= n
            if self._remaining <= 0:
                self._event.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def is_set(self) -> bool:
        return self._event.is_set()


class RangeTask:
    """A stealable slice of one loop: run ``fn(items[j])`` for ``j`` in
    ``[lo, hi)``.  ``lo``/``hi`` are only ever mutated under the owning
    worker's deque lock: the owner claims ``lo`` forward one item at a
    time, a thief truncates ``hi`` to steal the back half.  All splits of
    a submitted range share one :class:`RangeLatch`."""

    __slots__ = ("items", "fn", "lo", "hi", "latch", "split_min", "active",
                 "token")

    def __init__(self, items: Sequence, fn: Callable, lo: int, hi: int,
                 latch: RangeLatch, split_min: int = 2,
                 token: Optional[CancelToken] = None):
        self.items = items
        self.fn = fn
        self.lo = lo
        self.hi = hi
        self.latch = latch
        self.split_min = max(2, split_min)
        #: the owning scope's fail_fast cancel token (None = run to
        #: completion); splits inherit it with the latch
        self.token = token
        #: True while an owning worker's drain session holds this task
        #: (set/read only under the holding deque's lock).  A helper may
        #: take the last item of — and remove — only *inactive* tasks;
        #: an active task's last item belongs to its already-awake owner.
        self.active = False

    def run(self, j: int):
        fn = self.fn
        if self.items is None:  # single-callable submit() wrapper
            fn()
        else:
            fn(self.items[j])


@dataclass(frozen=True)
class JoinOutcome:
    """Typed result of :meth:`FinishScope.wait`: distinguishes "timed
    out" (work still in flight — the scope is NOT discharged, re-wait or
    abandon explicitly) from "done with failures" (every task finished,
    some raised) from a clean finish."""

    status: str  # "done" | "failed" | "timeout"
    errors: Tuple[TaskError, ...] = ()
    error_count: int = 0
    pending: int = 0  # unfired waitables (timeout only)

    @property
    def done(self) -> bool:
        return self.status == "done"

    @property
    def failed(self) -> bool:
        return self.status == "failed"

    @property
    def timed_out(self) -> bool:
        return self.status == "timeout"

    def raise_if_failed(self) -> "JoinOutcome":
        if self.status == "failed":
            raise MultipleExceptions(list(self.errors), self.error_count)
        if self.status == "timeout":
            raise TimeoutError(
                f"finish scope timed out with {self.pending} waitable(s) "
                "still pending")
        return self


#: FinishScope failure semantics (the paper's exception extension):
#: ``run_to_completion`` attempts every spawned item and aggregates all
#: failures at the join; ``fail_fast`` trips a CancelToken on the first
#: failure so sibling chunks skip their remaining items (skipped work is
#: accounted: spawns == completions + cancelled).
FAIL_MODES = ("run_to_completion", "fail_fast")


class FinishScope:
    """Collects escaped joins (DCAFE): ``with executor.finish() as f:``
    runs many loops but performs ONE join at scope exit.  Holds any
    waitable with Event semantics — per-task :class:`TaskEvent`\\ s from
    the FIFO pool, per-range :class:`RangeLatch`\\ es from the
    work-stealing pool.

    Exception contract (X10 finish semantics): the scope collects the
    exceptions of ALL transitively spawned tasks — including helped,
    stolen, and split ranges — and :meth:`join` rethrows them as ONE
    :class:`MultipleExceptions`.  AFE may move *where* the join happens;
    it never changes *whether* an exception surfaces."""

    def __init__(self, telemetry: Optional[SchedTelemetry] = None,
                 fail_mode: str = "run_to_completion"):
        if fail_mode not in FAIL_MODES:
            raise ValueError(f"fail_mode {fail_mode!r} not in {FAIL_MODES}")
        self._events: List[Any] = []
        self.telemetry = telemetry
        self.fail_mode = fail_mode
        #: fail_fast: the token sibling chunks poll; the first recorded
        #: error cancels it.  None in run_to_completion mode.
        self.token: Optional[CancelToken] = (
            CancelToken() if fail_mode == "fail_fast" else None)

    def add(self, events: Sequence[Any]):
        self._events.extend(events)

    def pending(self) -> int:
        """Non-blocking probe: waitables added but not yet fired.  The
        stall watchdog (repro.obs.monitor) polls this from its own
        thread, so a scope wedged with no one in ``wait()`` is still
        observable from outside."""
        return sum(1 for e in self._events if not e.is_set())

    def wait(self, timeout: Optional[float] = None) -> JoinOutcome:
        """Join with a deadline and a typed outcome.  On timeout the
        scope keeps its events (nothing is discharged, no join is
        counted) so the caller can re-wait, cancel, or abandon with full
        knowledge; on completion the join is counted once and any
        collected task errors are returned (not raised — that is
        :meth:`join`)."""
        deadline = (time.perf_counter() + timeout
                    if timeout is not None else None)
        with obs.trace_span("sched", "join_stall"):
            for ev in self._events:
                if deadline is None:
                    ev.wait()
                else:
                    left = deadline - time.perf_counter()
                    if left <= 0 or not ev.wait(max(0.0, left)):
                        pending = sum(1 for e in self._events
                                      if not e.is_set())
                        obs_monitor.on_join_timeout(self, pending,
                                                    timeout or 0.0)
                        return JoinOutcome("timeout", pending=pending)
        errors, total = _collect_errors(self._events)
        self._events.clear()
        if self.telemetry is not None:
            with self.telemetry.lock:
                self.telemetry.joins += 1
            obs.instant("sched", "join")
        if total:
            obs_monitor.on_join_failed(self, total)
            return JoinOutcome("failed", tuple(errors), total)
        return JoinOutcome("done")

    def join(self):
        """The finish: wait for everything, then rethrow collected task
        exceptions as one :class:`MultipleExceptions`."""
        self.wait().raise_if_failed()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            # an exception is already in flight: still quiesce (tasks
            # must not outlive the scope) but never mask the original
            self.wait()
            return False
        self.join()
        return False


class ThreadExecutor:
    """DLBC worker pool — the paper's runtime policy on real host threads.

    Host-side work in a TPU stack (data shard preparation, checkpoint I/O,
    request batching) is CPU task-parallelism, so DCAFE applies literally:
    the idle count is read without a lock (the benign race, §3.2.1), the
    policy decides between the chunked/parent arms and the re-probing
    serial arm, and telemetry mirrors Fig. 10 (spawns/joins).
    """

    #: Max items per spawned task; ``None`` = one task per planned chunk.
    #: The work-stealing variant narrows this so thieves have something
    #: to steal when cost skew piles up in one chunk.
    chunk_grain: Optional[int] = None

    def __init__(self, n_workers: int = 4,
                 telemetry: Optional[SchedTelemetry] = None):
        self.n_workers = n_workers
        self._q: "queue.Queue" = queue.Queue()
        self._idle = n_workers  # racy read by design (paper §3.2.1)
        self._idle_lock = threading.Lock()
        #: by-name policy resolutions, cached per executor so policy
        #: state — the DLBC grain controller's steal-feedback baseline —
        #: persists across run_loop calls instead of dying with a fresh
        #: instance every loop (racy insert is benign: one winner stays)
        self._policy_cache: Dict[str, SchedPolicy] = {}
        self.telemetry = telemetry or SchedTelemetry()
        self.capacity = PoolCapacity(self)
        self._threads = [
            # named threads: the trace exporter shows one track per
            # worker, labelled by executor class and worker index
            threading.Thread(target=self._worker, daemon=True,
                             name=f"{type(self).__name__}-w{i}")
            for i in range(n_workers)
        ]
        for t in self._threads:
            t.start()

    # -- error / fault plumbing ----------------------------------------------

    def _record_error(self, exc: BaseException, sink: Optional[Any], *,
                      site: str = "sched.item",
                      worker: Optional[int] = None,
                      lo: int = -1, hi: int = -1,
                      token: Optional[CancelToken] = None):
        """One raising item/task: collect it into the joining waitable
        (``sink.record_error``) so the finish rethrows it, count it in
        telemetry (with the per-site breakdown and the first traceback),
        emit the matching ``sched.error`` instant, and — fail_fast —
        trip the scope's cancel token."""
        tb = traceback.format_exc()
        if sink is not None:
            sink.record_error(TaskError(exc=exc, site=site, worker=worker,
                                        lo=lo, hi=hi, tb=tb))
        self.telemetry.record_error(site, tb)
        obs.instant("sched", "error", args={"site": site})
        if token is not None:
            token.cancel()

    def _on_death(self):
        """A worker thread was told to die (fault injection): the pool
        shrinks permanently — idle accounting loses the seat, telemetry
        counts the death.  The shared FIFO queue means no work is lost:
        peers drain whatever the dead worker would have run."""
        with self._idle_lock:
            self._idle -= 1
        with self.telemetry.lock:
            self.telemetry.worker_deaths += 1
        obs.instant("sched", "worker_death")

    # -- worker loop ---------------------------------------------------------

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            plan = faults.active()
            if plan is not None and plan.should_die("sched.worker"):
                self._q.put(item)  # re-queue: the claimed task is not lost
                self._on_death()
                return
            # legacy producers (tests, external pokes) enqueue (fn, done);
            # _submit adds the scope's cancel token as a third element
            fn, done, *rest = item
            token = rest[0] if rest else None
            outcome = "complete"
            with self._idle_lock:
                self._idle -= 1
            try:
                with obs.trace_span("worker", "task"):
                    fn()
            except TaskCancelled:
                # the chunk observed its scope's token and stopped early
                # (item accounting happened at the observation site)
                outcome = "cancel"
            except Exception as e:
                # Contain task exceptions: the worker thread survives, the
                # done event still fires, so joins (and FinishScope) never
                # hang on a raising task — and the error is COLLECTED into
                # the task's event, so the join rethrows it (X10 finish
                # semantics), never swallows it.
                self._record_error(e, done, site="sched.task", token=token)
            finally:
                with self._idle_lock:
                    self._idle += 1
                with self.telemetry.lock:
                    if outcome == "cancel":
                        self.telemetry.cancelled += 1
                    else:
                        self.telemetry.completions += 1
                obs.instant("sched", outcome)
                done.set()

    def _submit(self, fn: Callable[[], None],
                token: Optional[CancelToken] = None,
                ev: Optional[TaskEvent] = None) -> TaskEvent:
        ev = ev if ev is not None else TaskEvent()
        with self.telemetry.lock:
            self.telemetry.spawns += 1
        obs.instant("sched", "spawn")
        self._q.put((fn, ev, token))
        return ev

    def submit(self, fn: Callable[[], None]) -> TaskEvent:
        """Public single-task entry point (dispatches through the
        subclass's ``_submit``); same spawn accounting as ``run_loop``."""
        return self._submit(fn)

    def idle_workers(self) -> int:
        return self._idle  # intentionally unlocked read

    def shutdown(self):
        for _ in self._threads:
            self._q.put(None)

    def finish(self, fail_mode: str = "run_to_completion") -> FinishScope:
        """Open a DCAFE finish scope for escaped joins.  ``fail_mode``
        picks the exception semantics: aggregate everything at the join
        (default) or cancel sibling chunks on first failure."""
        return FinishScope(self.telemetry, fail_mode=fail_mode)

    # -- grain: how a planned chunk becomes spawned tasks --------------------

    def _grain_plan(self, n: int, policy: SchedPolicy) -> GrainPlan:
        """An explicit ``chunk_grain`` wins; the FIFO pool otherwise keeps
        one task per planned chunk (nothing to steal from a shared queue,
        so pre-splitting only adds overhead)."""
        return GrainPlan(initial=self.chunk_grain)

    def _spawn_range(self, items: Sequence, fn: Callable, lo: int, hi: int,
                     grain: GrainPlan,
                     token: Optional[CancelToken] = None) -> List[Any]:
        """Spawn ``[lo, hi)`` as tasks of at most ``grain.initial`` items;
        returns the waitables the join (or finish scope) collects.  A
        raising item is recorded into its task's event (the join
        rethrows it); the rest of the chunk still runs unless ``token``
        trips, in which case the remaining items are skipped and counted
        cancelled."""
        t = self.telemetry
        step = grain.initial or (hi - lo)
        events = []
        for a in range(lo, hi, step):
            b = min(a + step, hi)
            ev = TaskEvent()

            def task(a=a, b=b, ev=ev):
                plan = faults.active()
                for j in range(a, b):
                    if token is not None and token.cancelled():
                        t.record_cancelled(items=b - j)
                        raise TaskCancelled()
                    t0 = time.perf_counter()
                    try:
                        if plan is not None:
                            plan.poke("sched.item")
                        fn(items[j])
                    except Exception as e:
                        self._record_error(e, ev, site="sched.item",
                                           lo=j, hi=j + 1, token=token)
                    finally:
                        t.record_latency(time.perf_counter() - t0)

            events.append(self._submit(task, token=token, ev=ev))
        return events

    def _join(self, events: Sequence[Any]) -> None:
        """Wait for every spawned task of one loop (the per-loop join)."""
        for ev in events:
            ev.wait()

    # -- policy-driven loop execution ----------------------------------------

    def run_loop(self, items: Sequence, fn: Callable,
                 policy: Union[str, SchedPolicy, None] = None,
                 scope: Optional[FinishScope] = None) -> None:
        """Timed entry point — see :meth:`_run_loop` for the policy
        semantics.  The always-on metrics plane records one bump set
        per loop (count, item volume, wall time), never per item."""
        _MX_LOOPS.inc()
        _MX_ITEMS.inc(len(items))
        mt0 = time.perf_counter()
        try:
            self._run_loop(items, fn, policy, scope)
        finally:
            _MX_LOOP_S.observe(time.perf_counter() - mt0)

    def _run_loop(self, items: Sequence, fn: Callable,
                  policy: Union[str, SchedPolicy, None] = None,
                  scope: Optional[FinishScope] = None) -> None:
        """Execute ``fn(item)`` for every item under the given policy.

        This is the paper's three-block loop: the policy's ``decide``
        picks the parallel arm (spawn the planned chunks, run the caller
        chunk here, join — or escape the join into ``scope`` for DCAFE)
        or the serial arm (one item at a time, re-probing capacity).

        Exception contract (the paper's exception extension): every
        SPAWNED item is attempted — an item whose ``fn`` raises is
        counted in ``telemetry.errors``, COLLECTED into its task's
        waitable, and the rest of its chunk still runs (without per-item
        containment a raise would silently drop the chunk's remaining
        items); the per-loop join then rethrows everything as one
        :class:`MultipleExceptions`, or — DCAFE — the failures travel
        with the escaped join and surface at ``scope.join()``.  A
        ``fail_fast`` scope's :class:`CancelToken` makes sibling chunks
        (and the caller/serial arms) skip their remaining items instead,
        counted in ``cancelled_items``.  Items executed on the CALLING
        thread (the caller's chunk, the serial block) propagate like a
        plain ``for`` loop.
        """
        if policy is None or isinstance(policy, str):
            key = policy or "dlbc"
            cached = self._policy_cache.get(key)
            if cached is None:
                cached = self._policy_cache[key] = get_policy(key)
            policy = cached
        else:
            policy = get_policy(policy)
        t = self.telemetry
        token = scope.token if scope is not None else None
        n = len(items)
        i = 0

        def run_item(j: int, serial: bool):
            t0 = time.perf_counter()
            fn(items[j])
            t.record_latency(time.perf_counter() - t0)
            with t.lock:
                if serial:
                    t.serial_items += 1
                else:
                    t.parallel_items += 1

        while i < n:
            decision = policy.decide(i, n, self.capacity)
            if decision.plan is not None:
                plan = decision.plan
                grain = self._grain_plan(n - i, policy)
                events = []
                for lo, hi in plan.spawned:
                    events.extend(self._spawn_range(items, fn, lo, hi,
                                                    grain, token=token))
                    with t.lock:
                        t.parallel_items += hi - lo
                # parent block: the caller's (smallest) chunk.  Caller
                # items propagate like a plain for loop (see docstring),
                # so the per-item telemetry is batched outside the lock.
                ca, cb = plan.caller
                if cb > ca:
                    ran = 0
                    with obs.trace_span("worker", "caller"):
                        for j in range(ca, cb):
                            if token is not None and token.cancelled():
                                t.record_cancelled(items=cb - j)
                                break
                            t0 = time.perf_counter()
                            fn(items[j])
                            t.record_latency(time.perf_counter() - t0)
                            ran += 1
                    with t.lock:
                        t.parallel_items += ran
                if policy.escape_join and scope is not None:
                    scope.add(events)  # DCAFE: join escapes to the scope
                else:
                    with obs.trace_span("sched", "join_stall"):
                        self._join(events)
                    with t.lock:
                        t.joins += 1
                    obs.instant("sched", "join")
                    errors, total = _collect_errors(events)
                    if total:  # the per-loop finish rethrows (X10)
                        obs_monitor.on_join_failed(self, total,
                                                   site="sched.loop")
                        raise MultipleExceptions(errors, total)
                return
            # serial block with periodic capacity re-probe (cadence counts
            # items processed in THIS block, not the absolute index)
            resumed = False
            every = decision.recheck_every
            done_in_block = 0
            with obs.trace_span("worker", "serial"):
                while i < n:
                    if token is not None and token.cancelled():
                        t.record_cancelled(items=n - i)
                        return
                    run_item(i, serial=True)
                    i += 1
                    done_in_block += 1
                    if (every > 0 and (done_in_block % every == 0)
                            and self.capacity.idle() > 0 and (n - i) >= 2):
                        resumed = True
                        break
            if not resumed:
                return


#: Failed steal scans before a worker parks.  The backoff is a
#: ``sched_yield`` (``time.sleep(0)``): microseconds, not the old 0.1 s
#: global-lock poll, so a worker re-probes a few times while work is
#: still being submitted and only then pays for a real park.
_SPIN_TRIES = 4
#: Parked-worker wait backstop, seconds.  The wakeup protocol (register →
#: re-check → wait; producers push *then* unpark) makes a lost wakeup
#: impossible, so this only bounds the damage of a protocol bug.
_PARK_TIMEOUT = 0.1
#: How long a joining caller waits before it starts helping (claiming
#: items itself).  0 = help immediately: on loops too small to cover the
#: workers' wakeup latency the caller drains stragglers' ranges itself,
#: degrading gracefully toward serial speed instead of sleeping.
_HELP_GRACE = 0.0
#: Items a helper claims per lock acquisition when recent item costs
#: look uniform (batch amortisation); skewed costs force batch = 1.
_HELP_BATCH = 8


class WorkStealingExecutor(ThreadExecutor):
    """Per-worker deques, per-deque locks, lazy steal-driven splitting.

    Tasks carry ``(lo, hi)`` ranges (:class:`RangeTask`) instead of
    single items.  The **owner** claims items off the front of its front
    task one at a time (one uncontended lock acquisition per item — no
    queue round-trip, no per-item event).  A **thief** with an empty
    deque scans victims from a randomised start, picks the largest range
    with at least ``split_min`` items left, and steals its *back half*
    by truncating ``hi`` — the stolen half lands on the thief's own
    deque, where it is itself stealable, so the split recurses and grain
    adapts to observed imbalance with zero tuning.  When only
    single-item tasks remain, the back task is stolen whole (classic
    Arora–Blumofe–Plotkin).

    Synchronisation: one lock per deque (owner claim and thief split of
    the same range serialise on the *victim's* lock; disjoint deques
    never contend) plus a parked-worker protocol — an out-of-work worker
    backs off briefly, registers itself parked, re-checks every deque,
    and sleeps on its own event until a producer pushes work — replacing
    the old single global condition variable and its 0.1 s poll.  Joins:
    every submitted range gets ONE :class:`RangeLatch` shared by all its
    splits, so a DCAFE :class:`FinishScope` holds O(ranges) waitables,
    not O(items).

    Counter contract (all bumps under ``telemetry.lock``): ``spawns``
    counts task creations (submits + splits), ``completions`` counts
    tasks drained to exhaustion, ``cancelled`` counts tasks whose
    remainder was skipped by a fail_fast token — ``spawns ==
    completions + cancelled`` at quiescence; ``steals`` counts
    successful steals (``splits`` of them split a range;
    ``steal_victims`` histograms who they hit).
    """

    #: ``None`` = adaptive: ranges are carved per the policy's
    #: ``grain_plan`` (ceil(n / (k·workers)) items each) and re-split on
    #: steal.  Set an int (e.g. 1) to force a fixed grain — the
    #: benchmark baselines do.
    chunk_grain: Optional[int] = None

    def __init__(self, n_workers: int = 4,
                 telemetry: Optional[SchedTelemetry] = None):
        self._locks = [threading.Lock() for _ in range(n_workers)]
        self._deques: List[deque] = [deque() for _ in range(n_workers)]
        self._stop = False
        self._rr = count()
        self._park_lock = threading.Lock()
        self._park_events = [threading.Event() for _ in range(n_workers)]
        self._parked: set = set()
        #: workers that died (fault injection).  A worker adds itself
        #: under its OWN deque lock before sweeping orphans, and
        #: placement checks membership under that same lock — so a task
        #: either lands before the sweep (and is swept to a live deque)
        #: or sees the death and picks another victim.  Never stranded.
        self._dead: set = set()
        super().__init__(n_workers, telemetry)

    # -- submission ----------------------------------------------------------

    def _place_on(self, task: RangeTask) -> int:
        """Round-robin the task onto a LIVE worker's deque (no wakeup —
        the caller batches unparks); returns the chosen worker."""
        for _ in range(2 * self.n_workers):
            v = next(self._rr) % self.n_workers
            with self._locks[v]:
                if v in self._dead:
                    continue
                self._deques[v].append(task)
                return v
        raise RuntimeError("no live workers left to place work on")

    def _place(self, task: RangeTask):
        """Place a task and wake someone — preferably that deque's
        owner, so work does not sit in a parked worker's deque until
        another worker happens to scan it."""
        v = self._place_on(task)
        self._unpark(prefer=v)

    def _submit(self, fn: Callable[[], None],
                token: Optional[CancelToken] = None,
                ev: Optional[TaskEvent] = None) -> RangeLatch:
        """Single-callable entry point (``submit``/base helpers): a
        one-item range.  (``ev`` is the FIFO pool's premade-event hook;
        ranges collect errors in their latch instead, so it is unused.)"""
        latch = RangeLatch(1)
        with self.telemetry.lock:
            self.telemetry.spawns += 1
        obs.instant("sched", "spawn")
        self._place(RangeTask(None, fn, 0, 1, latch, token=token))
        return latch

    def _grain_plan(self, n: int, policy: SchedPolicy) -> GrainPlan:
        if self.chunk_grain:
            return GrainPlan(initial=self.chunk_grain)
        return policy.grain_plan(n, self.capacity, self.telemetry)

    def _spawn_range(self, items, fn, lo, hi, grain: GrainPlan,
                     token: Optional[CancelToken] = None):
        """Carve ``[lo, hi)`` into initial ranges and place them in one
        wave: one spawn-counter bump, one deque push per range, then one
        unpark sweep — the submit path is O(ranges), not O(items)."""
        step = grain.initial or (hi - lo)
        tasks = []
        for a in range(lo, hi, step):
            b = min(a + step, hi)
            tasks.append(RangeTask(items, fn, a, b, RangeLatch(b - a),
                                   grain.split_min, token=token))
        with self.telemetry.lock:
            self.telemetry.spawns += len(tasks)
        obs.instant("sched", "spawn", n=len(tasks))
        owners = set()
        for task in tasks:
            owners.add(self._place_on(task))
        for v in owners:
            self._unpark(prefer=v)
        return [task.latch for task in tasks]

    # -- worker loop ---------------------------------------------------------

    def _on_death(self, w: int):
        """This worker dies (fault injection): mark the deque dead under
        its own lock (closing the placement race — see ``_dead``), sweep
        any queued tasks to live deques, release the idle seat, and wake
        everyone so the swept work is picked up."""
        lock, dq = self._locks[w], self._deques[w]
        with lock:
            self._dead.add(w)
            orphans = list(dq)
            dq.clear()
        with self._idle_lock:
            self._idle -= 1
        with self.telemetry.lock:
            self.telemetry.worker_deaths += 1
        obs.instant("sched", "worker_death")
        for task in orphans:
            self._place_on(task)
        self._unpark(all_workers=True)

    def _worker(self):
        w = self._threads.index(threading.current_thread())
        rng = random.Random(0x5EED ^ (w * 0x9E3779B9))
        attempts = 0
        while True:
            plan = faults.active()
            if plan is not None and plan.should_die("sched.worker"):
                self._on_death(w)
                return
            if self._drain_own(w):
                attempts = 0
                continue
            if self._try_steal(w, rng):
                attempts = 0
                continue
            if self._stop:
                # Drain semantics matching ThreadExecutor's sentinel
                # queue: exit only once no work is visible anywhere, so
                # already-submitted tasks still run and their latches
                # fire (a FinishScope.join never hangs).
                return
            attempts += 1
            if attempts <= _SPIN_TRIES:
                time.sleep(0)  # sched_yield: bounded, near-free backoff
            else:
                self._park(w)

    def _drain_own(self, w: int) -> bool:
        """Run every task on our own deque to exhaustion.  Returns True
        if any work was found (the caller then re-scans immediately)."""
        lock, dq = self._locks[w], self._deques[w]
        if not dq:  # racy peek: cheap fast path past empty deques
            return False
        with self._idle_lock:
            self._idle -= 1
        worked = False
        try:
            while True:
                with lock:
                    if not dq:
                        return worked
                    task = dq[0]
                    task.active = True  # helpers now leave the pop to us
                worked = True
                self._drain_task(w, task)
        finally:
            with self._idle_lock:
                self._idle += 1

    def _drain_task(self, w: int, task: RangeTask):
        """One drain session: claim items off the front of ``task`` (our
        deque's front, which only we ever pop) until it is exhausted —
        naturally, by thieves truncating ``hi``, or by its scope's
        cancel token tripping (the remainder is skipped and counted
        cancelled) — then pop it and credit its latch once with
        everything we ran or skipped."""
        lock, dq = self._locks[w], self._deques[w]
        token = task.token
        ran = 0
        skipped = 0
        try:
            with obs.trace_span("worker", "drain"):
                while True:
                    with lock:
                        if (token is not None and token.cancelled()
                                and task.lo < task.hi):
                            skipped = task.hi - task.lo
                            task.lo = task.hi
                        if task.lo >= task.hi:
                            dq.popleft()  # ours: helpers skip active
                            return        # tasks' last items, thieves
                            #               never pop front
                        j = task.lo
                        task.lo = j + 1
                    self._run_item(task, j, w)
                    ran += 1
        finally:
            # completions before the latch: a joiner woken by the final
            # discharge must already observe spawns == completions +
            # cancelled
            with self.telemetry.lock:
                if skipped:
                    self.telemetry.cancelled += 1
                    self.telemetry.cancelled_items += skipped
                else:
                    self.telemetry.completions += 1
            obs.instant("sched", "cancel" if skipped else "complete")
            task.latch.discharge(ran + skipped)

    def _run_item(self, task: RangeTask, j: int, w: Optional[int] = None):
        t = self.telemetry
        t0 = time.perf_counter()
        try:
            if task.items is not None:
                plan = faults.active()
                if plan is not None:
                    plan.poke("sched.item")
            task.run(j)
        except Exception as e:
            # same containment contract as ThreadExecutor._worker: the
            # worker survives, the claimed item still counts, the latch
            # still fires — and carries the error to the join, wherever
            # the item ran (owner, thief, or helper)
            self._record_error(e, task.latch, site="sched.item",
                               worker=w, lo=j, hi=j + 1, token=task.token)
        finally:
            t.record_latency(time.perf_counter() - t0)

    # -- helping join --------------------------------------------------------

    def _join(self, events: Sequence[Any]) -> None:
        """Join by *helping*: the caller claims items off the largest
        visible range until every latch fires.  This is what ranges buy
        over per-item tasks — a joiner can contribute to exactly the
        range that is behind, so a heavy head never strands on one worker
        while the caller sleeps, and a loop too small to cover the
        workers' wakeup latency degrades gracefully toward serial speed
        (the helper takes over owner-less tasks entirely, see
        :meth:`_help_one`).  An optional grace period (``_HELP_GRACE``)
        can keep the caller off the deque locks on loops expected to
        join immediately."""
        pending = [ev for ev in events if not ev.is_set()]
        if not pending:
            return
        if _HELP_GRACE > 0:
            deadline = time.perf_counter() + _HELP_GRACE
            for ev in pending:
                left = deadline - time.perf_counter()
                if left <= 0 or not ev.wait(timeout=left):
                    break
            pending = [ev for ev in pending if not ev.is_set()]
        # Helper claim granularity from the same feedback signal the
        # grain controller uses: uniform recent item costs → batch claims
        # (amortise the lock over several items); skewed costs → one item
        # at a time, so the helper never walks off with a heavy head.
        batch = _HELP_BATCH if self.telemetry.recent_skew() < 2.0 else 1
        idle_rounds = 0
        while pending:
            if self._help_one(batch):
                idle_rounds = 0
            elif idle_rounds < _SPIN_TRIES:
                # nothing claimable but latches unset: the owners hold
                # only their final items — yield them the core instead
                # of oversleeping a futex quantum
                idle_rounds += 1
                time.sleep(0)
            else:
                pending[0].wait(timeout=5e-4)
            pending = [ev for ev in pending if not ev.is_set()]

    def _help_one(self, batch: int = 1) -> bool:
        """Claim and run up to ``batch`` items from the largest helpable
        range.  Find and claim happen under one hold of that deque's
        lock — a task's range is only ever mutated under the lock of the
        deque currently holding it.  An *active* task (an owner session
        holds it) is helpable down to its last item, which stays with
        the owner; an *inactive* task (its owner is parked or busy
        elsewhere) can be taken over entirely — claiming its last item
        removes it, so a join never stalls on a wakeup for microseconds
        of work."""
        for v in range(self.n_workers):
            if not self._deques[v]:  # racy peek
                continue
            lock, dq = self._locks[v], self._deques[v]
            cancel_claim = None
            with lock:
                best, best_sz = None, 0
                for task in dq:
                    tok = task.token
                    if (tok is not None and tok.cancelled()
                            and task.hi > task.lo):
                        # fail_fast: consume the whole remainder as
                        # cancelled so a join never stalls on work
                        # nobody should run (a parked owner's inactive
                        # cancelled task would otherwise sit forever)
                        skipped = task.hi - task.lo
                        task.lo = task.hi
                        removed = not task.active
                        if removed:
                            dq.remove(task)
                        cancel_claim = (task, skipped, removed)
                        break
                    sz = task.hi - task.lo
                    if sz > best_sz and (sz >= 2 or not task.active):
                        best, best_sz = task, sz
                if cancel_claim is None and best is None:
                    continue
                if cancel_claim is None:
                    take = min(batch,
                               best_sz - 1 if best.active else best_sz)
                    j = best.lo
                    best.lo = j + take
                    removed = best.lo >= best.hi and not best.active
                    if removed:
                        dq.remove(best)
            if cancel_claim is not None:
                task, skipped, removed = cancel_claim
                with self.telemetry.lock:
                    self.telemetry.cancelled_items += skipped
                    if removed:
                        # the task dies here; an active task's owner
                        # session still counts it (as a completion of
                        # its emptied range)
                        self.telemetry.cancelled += 1
                if removed:
                    obs.instant("sched", "cancel")
                task.latch.discharge(skipped)
                return True
            for jj in range(j, j + take):
                self._run_item(best, jj)
            if removed:
                with self.telemetry.lock:
                    self.telemetry.completions += 1
                obs.instant("sched", "complete")
            best.latch.discharge(take)
            return True
        return False

    # -- stealing ------------------------------------------------------------

    def _try_steal(self, w: int, rng: random.Random) -> bool:
        """Scan victims from a randomised start (no worker-0 hotspot) and
        take the first steal that lands; the loot goes to the front of
        our own deque, where it is immediately drainable — and itself
        stealable, so splitting recurses."""
        n = self.n_workers
        # clock read only when tracing: steal latency = scan start →
        # loot landed; failed scans (idle spinning) emit nothing
        t0 = obs.perf_counter_ns() if obs.enabled() else 0
        start = rng.randrange(n)
        for d in range(n):
            v = (start + d) % n
            if v == w:
                continue
            loot = self._steal_from(v)
            if loot is None:
                continue
            task, split = loot
            with self._locks[w]:
                self._deques[w].appendleft(task)
            t = self.telemetry
            with t.lock:
                t.steals += 1
                t.steal_victims[v] = t.steal_victims.get(v, 0) + 1
                if split:
                    t.splits += 1
                    t.spawns += 1  # a split mints a new task
            if obs.enabled():
                obs.complete_span("sched", "steal", t0, {"victim": v})
                obs.instant("sched", "steal", args={"victim": v})
                if split:
                    obs.instant("sched", "split")
                    obs.instant("sched", "spawn")  # the minted task
            return True
        return False

    def _steal_from(self, v: int) -> Optional[Tuple[RangeTask, bool]]:
        """Under the victim's deque lock: split the largest splittable
        range (steal its back half), else pop a whole queued task off the
        back.  The front task is never popped by a thief — its owner may
        be mid-claim — but it *is* splittable, because a split only
        truncates ``hi`` above the owner's claim cursor."""
        lock, dq = self._locks[v], self._deques[v]
        if not dq:  # racy peek, see _drain_own
            return None
        with lock:
            if not dq:
                return None
            best = None
            for task in dq:
                size = task.hi - task.lo
                if size >= task.split_min and (
                        best is None or size > best.hi - best.lo):
                    best = task
            if best is not None:
                # back half to the thief, the odd item stays with the
                # owner (who is already consuming lo forward)
                mid = best.lo + (best.hi - best.lo + 1) // 2
                stolen = RangeTask(best.items, best.fn, mid, best.hi,
                                   best.latch, best.split_min,
                                   token=best.token)
                best.hi = mid
                return stolen, True
            if len(dq) >= 2:
                return dq.pop(), False
            return None

    # -- parking -------------------------------------------------------------

    def _unpark(self, prefer: Optional[int] = None, all_workers: bool = False):
        with self._park_lock:
            if all_workers:
                woken, self._parked = set(self._parked), set()
            elif prefer is not None and prefer in self._parked:
                self._parked.discard(prefer)
                woken = {prefer}
            elif self._parked:
                woken = {self._parked.pop()}
            else:
                return
        for v in woken:
            self._park_events[v].set()

    def _park(self, w: int):
        """Register parked, re-check for work, then sleep until a
        producer's unpark (or the backstop timeout).  The register-then-
        re-check order pairs with the producers' push-then-unpark order:
        any push racing our scan either lands before the scan reads that
        deque (we see it) or unparks us afterwards (we are registered)."""
        ev = self._park_events[w]
        with self._park_lock:
            ev.clear()
            self._parked.add(w)
        # Re-check only our own deque: cross-deque work is covered by the
        # producers' push-then-unpark order, and re-checking every deque
        # here would busy-spin whenever the only remaining work is an
        # unstealable front task some owner is already draining.
        if self._stop or self._deques[w]:
            with self._park_lock:
                self._parked.discard(w)
            return
        with obs.trace_span("sched", "park"):
            ev.wait(timeout=_PARK_TIMEOUT)
        with self._park_lock:
            self._parked.discard(w)

    def shutdown(self):
        self._stop = True
        self._unpark(all_workers=True)


class SlotExecutor:
    """Admission scheduling over fixed device slots (continuous batching).

    A queued request is one task; an idle slot is an idle worker.  The
    policy's ``admit`` applies the paper's spawn rule: DLBC admits into
    every idle slot at every decode step (per-iteration re-check), LC
    waits for a full batch of free slots (static chunking of requests).
    Refills are FIFO with oldest request → lowest slot index — the
    remainder-spread priority of Fig. 6.

    ``refill`` accepts either a plain FIFO list (the single-queue serving
    path, unchanged) or a :class:`~repro.sched.tenancy.TenantRegistry`:
    the policy still decides *how many* requests the idle slots admit,
    and the weighted deficit-round-robin decides *which tenant* each
    admission comes from.  The executor keeps per-tenant occupancy
    (``slot_tenant``) so slot-share accounting and the per-tenant
    spawn/join telemetry stay with the one object that owns the slots.
    """

    def __init__(self, n_slots: int,
                 policy: Union[str, SchedPolicy, None] = "dlbc",
                 telemetry: Optional[SchedTelemetry] = None):
        self.n_slots = n_slots
        self.policy = get_policy(policy)
        self.telemetry = telemetry or SchedTelemetry()
        #: which tenant occupies each slot (None = idle / anonymous)
        self.slot_tenant: List[Optional[str]] = [None] * n_slots
        self._weighted: Optional[Any] = None  # lazily wrapped policy

    def _admit_count(self, n_idle: int, n_queued: int) -> int:
        # clamp: a custom policy may over-admit; never index past the idle
        # slots or pop an empty queue
        return min(self.policy.admit(n_idle, n_queued, self.n_slots),
                   n_idle, n_queued)

    def refill(self, slots: Sequence[Optional[Any]],
               queue: Union[List, TenantRegistry]) -> List[Tuple[int, Any]]:
        """Pop up to ``policy.admit(...)`` requests and pair them with idle
        slots (oldest request → lowest slot).  Mutates ``queue``."""
        if isinstance(queue, TenantRegistry):
            return self.refill_tenants(slots, queue)
        cap = SlotCapacity(list(slots))
        idle = cap.idle_indices()
        k = self._admit_count(len(idle), len(queue))
        placements = [(idle[j], queue.pop(0)) for j in range(k)]
        with self.telemetry.lock:
            self.telemetry.spawns += len(placements)
        if placements:
            obs.instant("sched", "spawn", n=len(placements))
            obs.instant("serve", "admit", n=len(placements))
        return placements

    def weighted_policy(self):
        """Resolve (and cache) the cross-tenant refill policy.  Raises
        for escape-join bases (DCAFE) — call at configuration time to
        fail fast rather than on the first mid-run refill."""
        if self._weighted is None:
            self._weighted = ensure_weighted(self.policy)
        return self._weighted

    def refill_tenants(self, slots: Sequence[Optional[Any]],
                       registry: TenantRegistry) -> List[Tuple[int, Any]]:
        """Tenant-aware refill: the base policy's idle-slot arithmetic
        sizes the admission, the deficit round-robin picks the tenants.
        Returns ``(slot, request)`` pairs; ``slot_tenant`` and the
        per-tenant spawn counters record who got each slot."""
        pol = self.weighted_policy()
        cap = SlotCapacity(list(slots))
        idle = cap.idle_indices()
        k = self._admit_count(len(idle), registry.total_queued())
        placements: List[Tuple[int, Any]] = []
        for j, (tenant, req) in enumerate(pol.pick(registry, k)):
            slot = idle[j]
            self.slot_tenant[slot] = tenant.name
            self.telemetry.tenant(tenant.name).spawns += 1
            placements.append((slot, req))
        with self.telemetry.lock:
            self.telemetry.spawns += len(placements)
        if placements:
            obs.instant("sched", "spawn", n=len(placements))
            obs.instant("serve", "admit", n=len(placements))
        return placements

    def prefill(self, slot: int, ntokens: int):
        """One prefill chunk of ``ntokens`` prompt tokens executed
        in-place in ``slot`` (DLBC worksharing: the chunk runs on the
        slot that owns the request, no task is created for it).

        Counted in the dedicated ``prefill_chunks``/``prefill_tokens``
        counters — deliberately NOT in spawns/joins: the serving AFE
        contract is one FinishScope join per REQUEST, and chunk
        accounting must never disturb the ``spawns == joins``
        quiescence invariant the CI gates replay.  Emits a
        ``serve.prefill_chunk`` instant so the trace shows every chunk
        without inflating the conservation-gated spawn/join events."""
        with self.telemetry.lock:
            self.telemetry.prefill_chunks += 1
            self.telemetry.prefill_tokens += int(ntokens)
        name = self.slot_tenant[slot]
        if name is not None:
            bucket = self.telemetry.tenant(name)
            bucket.prefill_chunks += 1
            bucket.prefill_tokens += int(ntokens)
        obs.instant("serve", "prefill_chunk", n=int(ntokens))

    def tenant_busy_slots(self) -> Dict[str, int]:
        """Occupied-slot count per tenant right now (slot-share
        accounting: the serving stats integrate this every step)."""
        out: Dict[str, int] = {}
        for name in self.slot_tenant:
            if name is not None:
                out[name] = out.get(name, 0) + 1
        return out

    def complete(self, latency_steps: Optional[float] = None,
                 slot: Optional[int] = None):
        """A sequence finished: count the join (finish analogue); with a
        ``slot`` the tenant occupancy is released and the join lands on
        that tenant's counters too."""
        with self.telemetry.lock:
            self.telemetry.joins += 1
        obs.instant("sched", "join")
        if slot is not None:
            name = self.slot_tenant[slot]
            if name is not None:
                self.telemetry.tenant(name).joins += 1
            self.slot_tenant[slot] = None
        if latency_steps is not None:
            self.telemetry.record_latency(latency_steps)
