"""Executors: substrates that run work under a pluggable SchedPolicy.

* :class:`ThreadExecutor` — host thread pool (FIFO task queue), the
  generalisation of the old ``repro.data.pool.DLBCPool``.  ``run_loop``
  is the paper's three-block structure (chunked / parent / serial) with
  the *policy* deciding which arm to take at each step.
* :class:`WorkStealingExecutor` — per-worker deques; an idle worker
  steals from the back of a victim's deque.  Same ``run_loop``.
* :class:`FinishScope` — DCAFE on the host: spawned chunks escape their
  per-loop join to one outer scope (one join for many loops).
* :class:`SlotExecutor` — admission scheduling over fixed device decode
  slots for the continuous batcher (requests are single tasks; capacity
  is the idle-slot count).
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .capacity import PoolCapacity, SlotCapacity
from .policy import SchedPolicy, get_policy
from .telemetry import SchedTelemetry
from .tenancy import TenantRegistry, ensure_weighted


class FinishScope:
    """Collects escaped joins (DCAFE): ``with executor.finish() as f:``
    runs many loops but performs ONE join at scope exit."""

    def __init__(self, telemetry: Optional[SchedTelemetry] = None):
        self._events: List[threading.Event] = []
        self.telemetry = telemetry

    def add(self, events: Sequence[threading.Event]):
        self._events.extend(events)

    def join(self):
        for ev in self._events:
            ev.wait()
        self._events.clear()
        if self.telemetry is not None:
            with self.telemetry.lock:
                self.telemetry.joins += 1

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.join()
        return False


class ThreadExecutor:
    """DLBC worker pool — the paper's runtime policy on real host threads.

    Host-side work in a TPU stack (data shard preparation, checkpoint I/O,
    request batching) is CPU task-parallelism, so DCAFE applies literally:
    the idle count is read without a lock (the benign race, §3.2.1), the
    policy decides between the chunked/parent arms and the re-probing
    serial arm, and telemetry mirrors Fig. 10 (spawns/joins).
    """

    #: Max items per spawned task; ``None`` = one task per planned chunk.
    #: The work-stealing variant narrows this so thieves have something
    #: to steal when cost skew piles up in one chunk.
    chunk_grain: Optional[int] = None

    def __init__(self, n_workers: int = 4,
                 telemetry: Optional[SchedTelemetry] = None):
        self.n_workers = n_workers
        self._q: "queue.Queue" = queue.Queue()
        self._idle = n_workers  # racy read by design (paper §3.2.1)
        self._idle_lock = threading.Lock()
        self.telemetry = telemetry or SchedTelemetry()
        self.capacity = PoolCapacity(self)
        self._threads = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(n_workers)
        ]
        for t in self._threads:
            t.start()

    # -- worker loop ---------------------------------------------------------

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, done = item
            with self._idle_lock:
                self._idle -= 1
            try:
                fn()
            except Exception:
                # Contain task exceptions: the worker thread survives, the
                # done event still fires, so joins (and FinishScope) never
                # hang on a raising task.  Uncontained, the exception would
                # silently kill the thread and shrink the pool forever.
                with self.telemetry.lock:
                    self.telemetry.errors += 1
            finally:
                with self._idle_lock:
                    self._idle += 1
                with self.telemetry.lock:
                    self.telemetry.completions += 1
                done.set()

    def _submit(self, fn: Callable[[], None]) -> threading.Event:
        ev = threading.Event()
        with self.telemetry.lock:
            self.telemetry.spawns += 1
        self._q.put((fn, ev))
        return ev

    def submit(self, fn: Callable[[], None]) -> threading.Event:
        """Public single-task entry point (dispatches through the
        subclass's ``_submit``); same spawn accounting as ``run_loop``."""
        return self._submit(fn)

    def idle_workers(self) -> int:
        return self._idle  # intentionally unlocked read

    def shutdown(self):
        for _ in self._threads:
            self._q.put(None)

    def finish(self) -> FinishScope:
        """Open a DCAFE finish scope for escaped joins."""
        return FinishScope(self.telemetry)

    # -- policy-driven loop execution ----------------------------------------

    def run_loop(self, items: Sequence, fn: Callable,
                 policy: Union[str, SchedPolicy, None] = None,
                 scope: Optional[FinishScope] = None) -> None:
        """Execute ``fn(item)`` for every item under the given policy.

        This is the paper's three-block loop: the policy's ``decide``
        picks the parallel arm (spawn the planned chunks, run the caller
        chunk here, join — or escape the join into ``scope`` for DCAFE)
        or the serial arm (one item at a time, re-probing capacity).

        Exception contract: every SPAWNED item is attempted — an item
        whose ``fn`` raises is counted in ``telemetry.errors`` and the
        rest of its chunk still runs (without per-item containment a
        raise would silently drop the chunk's remaining items).  Items
        executed on the CALLING thread (the caller's chunk, the serial
        block) propagate like a plain ``for`` loop.
        """
        policy = get_policy(policy, default="dlbc")
        t = self.telemetry
        n = len(items)
        i = 0

        def run_item(j: int, serial: bool):
            t0 = time.perf_counter()
            fn(items[j])
            t.record_latency(time.perf_counter() - t0)
            with t.lock:
                if serial:
                    t.serial_items += 1
                else:
                    t.parallel_items += 1

        while i < n:
            decision = policy.decide(i, n, self.capacity)
            if decision.plan is not None:
                plan = decision.plan
                events = []
                for lo, hi in plan.spawned:
                    grain = self.chunk_grain or (hi - lo)
                    for a in range(lo, hi, grain):
                        b = min(a + grain, hi)

                        def task(a=a, b=b):
                            for j in range(a, b):
                                t0 = time.perf_counter()
                                try:
                                    fn(items[j])
                                except Exception:
                                    with t.lock:
                                        t.errors += 1
                                finally:
                                    t.record_latency(
                                        time.perf_counter() - t0)

                        events.append(self._submit(task))
                        with t.lock:
                            t.parallel_items += b - a
                # parent block: the caller's (smallest) chunk
                for j in range(*plan.caller):
                    run_item(j, serial=False)
                if policy.escape_join and scope is not None:
                    scope.add(events)  # DCAFE: join escapes to the scope
                else:
                    for ev in events:
                        ev.wait()
                    with t.lock:
                        t.joins += 1
                return
            # serial block with periodic capacity re-probe (cadence counts
            # items processed in THIS block, not the absolute index)
            resumed = False
            every = decision.recheck_every
            done_in_block = 0
            while i < n:
                run_item(i, serial=True)
                i += 1
                done_in_block += 1
                if (every > 0 and (done_in_block % every == 0)
                        and self.capacity.idle() > 0 and (n - i) >= 2):
                    resumed = True
                    break
            if not resumed:
                return


class WorkStealingExecutor(ThreadExecutor):
    """Per-worker deques with back-end stealing.

    The owner pushes/pops its own deque at the front; an idle worker
    steals from the *back* of the first non-empty victim deque (classic
    Arora-Blumofe-Plotkin discipline), so contiguous cost skew spreads
    across workers even after the chunk plan is committed.  Tasks are
    per-item (``chunk_grain = 1``): a committed chunk stays stealable.
    """

    chunk_grain = 1

    def __init__(self, n_workers: int = 4,
                 telemetry: Optional[SchedTelemetry] = None):
        self._deques: List[deque] = [deque() for _ in range(n_workers)]
        self._cv = threading.Condition()
        self._stop = False
        self._rr = 0
        super().__init__(n_workers, telemetry)

    def _worker_index(self) -> int:
        me = threading.current_thread()
        return self._threads.index(me)

    def _worker(self):
        w = self._worker_index()
        while True:
            item = None
            with self._cv:
                while True:
                    if self._deques[w]:
                        item = self._deques[w].popleft()
                        break
                    stolen = False
                    for v in range(self.n_workers):
                        if v != w and self._deques[v]:
                            item = self._deques[v].pop()  # steal from back
                            self.telemetry.steals += 1
                            stolen = True
                            break
                    if stolen:
                        break
                    # Drain semantics matching ThreadExecutor's sentinel
                    # queue: stop only once every deque is empty, so
                    # already-submitted tasks still run and their done
                    # events fire (a FinishScope.join never hangs).
                    if self._stop:
                        return
                    self._cv.wait(timeout=0.1)
                self._idle -= 1
            fn, done = item
            try:
                fn()
            except Exception:
                # same containment contract as ThreadExecutor._worker
                with self.telemetry.lock:
                    self.telemetry.errors += 1
            finally:
                with self._cv:
                    self._idle += 1
                with self.telemetry.lock:
                    self.telemetry.completions += 1
                done.set()

    def _submit(self, fn: Callable[[], None]) -> threading.Event:
        ev = threading.Event()
        with self.telemetry.lock:
            self.telemetry.spawns += 1
        with self._cv:
            self._deques[self._rr % self.n_workers].append((fn, ev))
            self._rr += 1
            self._cv.notify_all()
        return ev

    def shutdown(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()


class SlotExecutor:
    """Admission scheduling over fixed device slots (continuous batching).

    A queued request is one task; an idle slot is an idle worker.  The
    policy's ``admit`` applies the paper's spawn rule: DLBC admits into
    every idle slot at every decode step (per-iteration re-check), LC
    waits for a full batch of free slots (static chunking of requests).
    Refills are FIFO with oldest request → lowest slot index — the
    remainder-spread priority of Fig. 6.

    ``refill`` accepts either a plain FIFO list (the single-queue serving
    path, unchanged) or a :class:`~repro.sched.tenancy.TenantRegistry`:
    the policy still decides *how many* requests the idle slots admit,
    and the weighted deficit-round-robin decides *which tenant* each
    admission comes from.  The executor keeps per-tenant occupancy
    (``slot_tenant``) so slot-share accounting and the per-tenant
    spawn/join telemetry stay with the one object that owns the slots.
    """

    def __init__(self, n_slots: int,
                 policy: Union[str, SchedPolicy, None] = "dlbc",
                 telemetry: Optional[SchedTelemetry] = None):
        self.n_slots = n_slots
        self.policy = get_policy(policy)
        self.telemetry = telemetry or SchedTelemetry()
        #: which tenant occupies each slot (None = idle / anonymous)
        self.slot_tenant: List[Optional[str]] = [None] * n_slots
        self._weighted: Optional[Any] = None  # lazily wrapped policy

    def _admit_count(self, n_idle: int, n_queued: int) -> int:
        # clamp: a custom policy may over-admit; never index past the idle
        # slots or pop an empty queue
        return min(self.policy.admit(n_idle, n_queued, self.n_slots),
                   n_idle, n_queued)

    def refill(self, slots: Sequence[Optional[Any]],
               queue: Union[List, TenantRegistry]) -> List[Tuple[int, Any]]:
        """Pop up to ``policy.admit(...)`` requests and pair them with idle
        slots (oldest request → lowest slot).  Mutates ``queue``."""
        if isinstance(queue, TenantRegistry):
            return self.refill_tenants(slots, queue)
        cap = SlotCapacity(list(slots))
        idle = cap.idle_indices()
        k = self._admit_count(len(idle), len(queue))
        placements = [(idle[j], queue.pop(0)) for j in range(k)]
        with self.telemetry.lock:
            self.telemetry.spawns += len(placements)
        return placements

    def weighted_policy(self):
        """Resolve (and cache) the cross-tenant refill policy.  Raises
        for escape-join bases (DCAFE) — call at configuration time to
        fail fast rather than on the first mid-run refill."""
        if self._weighted is None:
            self._weighted = ensure_weighted(self.policy)
        return self._weighted

    def refill_tenants(self, slots: Sequence[Optional[Any]],
                       registry: TenantRegistry) -> List[Tuple[int, Any]]:
        """Tenant-aware refill: the base policy's idle-slot arithmetic
        sizes the admission, the deficit round-robin picks the tenants.
        Returns ``(slot, request)`` pairs; ``slot_tenant`` and the
        per-tenant spawn counters record who got each slot."""
        pol = self.weighted_policy()
        cap = SlotCapacity(list(slots))
        idle = cap.idle_indices()
        k = self._admit_count(len(idle), registry.total_queued())
        placements: List[Tuple[int, Any]] = []
        for j, (tenant, req) in enumerate(pol.pick(registry, k)):
            slot = idle[j]
            self.slot_tenant[slot] = tenant.name
            self.telemetry.tenant(tenant.name).spawns += 1
            placements.append((slot, req))
        with self.telemetry.lock:
            self.telemetry.spawns += len(placements)
        return placements

    def tenant_busy_slots(self) -> Dict[str, int]:
        """Occupied-slot count per tenant right now (slot-share
        accounting: the serving stats integrate this every step)."""
        out: Dict[str, int] = {}
        for name in self.slot_tenant:
            if name is not None:
                out[name] = out.get(name, 0) + 1
        return out

    def complete(self, latency_steps: Optional[float] = None,
                 slot: Optional[int] = None):
        """A sequence finished: count the join (finish analogue); with a
        ``slot`` the tenant occupancy is released and the join lands on
        that tenant's counters too."""
        with self.telemetry.lock:
            self.telemetry.joins += 1
        if slot is not None:
            name = self.slot_tenant[slot]
            if name is not None:
                self.telemetry.tenant(name).joins += 1
            self.slot_tenant[slot] = None
        if latency_steps is not None:
            self.telemetry.record_latency(latency_steps)
