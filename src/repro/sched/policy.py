"""Scheduling policies and the canonical Fig. 6 chunk arithmetic.

This module is the ONLY place in the repo that knows how DLBC splits a
half-open iteration range among workers.  Every other surface — the IR
codegen in :mod:`repro.core.dlbc`, the host thread pool in
:mod:`repro.sched.executors`, the serving batcher's slot refill — calls
into these functions instead of re-deriving the arithmetic.

The Fig. 6 recurrence (paper §3.2, lines 7–16), for ``actualn`` remaining
iterations and ``idle`` idle workers:

    totWorkers = idle + 1                 # idle workers + the caller
    eqChunk    = actualn // totWorkers
    chunkEnd   = ii + actualn - eqChunk   # spawned chunks cover [ii, chunkEnd)
    rem        = actualn % totWorkers + idle
    while ii < chunkEnd:
        kx  = ii + eqChunk + rem // totWorkers
        spawn chunk [ii, kx); rem -= 1; ii = kx
    # caller executes [chunkEnd, hi) — the smallest chunk — then joins

which yields ``actualn % totWorkers`` front chunks of size ``eqChunk+1``,
the rest of size ``eqChunk``, and the caller keeping exactly ``eqChunk``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Type, Union

from .capacity import CapacityProvider

# ---------------------------------------------------------------------------
# Fig. 6 scalar steps (consumed by the IR codegen in repro.core.dlbc)
# ---------------------------------------------------------------------------


def fig6_tot(idle: int) -> int:
    """Fig. 6 line 7: ``totWorkers = idleWorkers + 1`` (caller included)."""
    return idle + 1


def fig6_eq(actualn: int, tot: int) -> int:
    """Fig. 6 line 8: ``eqChunk = actualn / totWorkers``."""
    return actualn // tot


def fig6_chunk_end(ii: int, actualn: int, eq: int) -> int:
    """Fig. 6 line 9: spawned chunks end where the caller's chunk starts."""
    return ii + actualn - eq


def fig6_rem0(actualn: int, tot: int, idle: int) -> int:
    """Fig. 6 line 9: ``rem = actualn % totWorkers + workers`` — the counter
    whose integer division spreads the remainder one-per-chunk from the
    front."""
    return actualn % tot + idle


def fig6_next(ii: int, eq: int, rem: int, tot: int) -> int:
    """Fig. 6 line 10: ``kx = ii + eqChunk + rem / totWorkers``."""
    return ii + eq + rem // tot


# ---------------------------------------------------------------------------
# Chunk plans
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChunkPlan:
    """A concrete partition of ``[lo, hi)`` into spawned chunks plus the
    chunk the calling worker keeps for itself."""

    lo: int
    hi: int
    spawned: Tuple[Tuple[int, int], ...]
    caller: Tuple[int, int]

    @property
    def chunks(self) -> List[Tuple[int, int]]:
        """All chunks in range order (spawned first, caller last)."""
        return [*self.spawned, self.caller]

    @property
    def sizes(self) -> List[int]:
        return [b - a for a, b in self.chunks]


def chunk_plan(lo: int, hi: int, idle: int,
               caller_keeps_smallest: bool = True) -> ChunkPlan:
    """The canonical DLBC split of ``[lo, hi)`` given ``idle`` idle workers.

    With ``caller_keeps_smallest`` (the paper's parent block, Fig. 6 lines
    21–24) the caller executes the final, smallest chunk itself; with it
    disabled every chunk is spawned (LC-style: the parent only joins).
    """
    actualn = hi - lo
    tot = fig6_tot(idle)
    eq = fig6_eq(actualn, tot)
    chunk_end = fig6_chunk_end(lo, actualn, eq)
    rem = fig6_rem0(actualn, tot, idle)
    spawned: List[Tuple[int, int]] = []
    ii = lo
    while ii < chunk_end:
        kx = fig6_next(ii, eq, rem, tot)
        spawned.append((ii, kx))
        rem -= 1
        ii = kx
    caller = (chunk_end, hi)
    if not caller_keeps_smallest and chunk_end < hi:
        spawned.append(caller)
        caller = (hi, hi)
    return ChunkPlan(lo=lo, hi=hi, spawned=tuple(spawned), caller=caller)


def static_chunk_size(total: int, nchunks: int) -> int:
    """LC's static chunk size: ``ceil(total / nchunks)``, at least 1
    (Nandivada et al. loop chunking, paper Fig. 1(b))."""
    return max(1, -(-total // nchunks))


def static_plan(lo: int, hi: int, nchunks: int) -> ChunkPlan:
    """LC static chunking: ``nchunks`` contiguous ceil-sized chunks, all
    spawned; the caller only joins (paper Fig. 1(b) / Fig. 7(b))."""
    csize = static_chunk_size(hi - lo, nchunks)
    spawned = tuple((i, min(i + csize, hi)) for i in range(lo, hi, csize))
    return ChunkPlan(lo=lo, hi=hi, spawned=spawned, caller=(hi, hi))


# ---------------------------------------------------------------------------
# Grain plans (adaptive work stealing)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GrainPlan:
    """How a spawned chunk is carved into *stealable ranges*.

    ``initial`` is the items-per-range a chunk is pre-split into before
    any steal happens (``None`` = one range per chunk — fully lazy);
    ``split_min`` is the smallest range a thief is allowed to split (the
    re-split threshold): a range of fewer items is stolen whole or left
    alone, so splitting terminates and single items never churn.
    """

    initial: Optional[int] = None
    split_min: int = 2


class GrainController:
    """Closes the DLBC loop with runtime feedback: grain from steals.

    DLBC decides chunk sizes from *available workers* at spawn time; this
    controller decides how divisible those chunks stay afterwards.  Start
    coarse — ``initial = ceil(n / (k · workers))``, so each worker's
    chunk lands as ~``k`` ranges and per-task overhead is amortised over
    many items — and let runtime feedback prove imbalance: between loops
    the steal delta read off :class:`~repro.sched.telemetry.SchedTelemetry`
    says *someone went hungry*, and the recent latency spread
    (``recent_skew``) disambiguates why.  Steals with skewed item costs
    mean a coarser grain stranded a heavy head — halve the grain (double
    ``k``, up to ``k_max``).  Steals with uniform costs are end-of-loop
    churn (thieves passing tail scraps around) — treating them as
    imbalance would spiral the grain down to per-item tasks, so ``k``
    instead relaxes back toward ``k0``.  Both reads are unsynchronised
    by design — grain is a performance hint, and the benign-race
    discipline of the paper's idle-count probe (§3.2.1) applies
    verbatim.
    """

    def __init__(self, k: int = 1, k_max: int = 8, min_grain: int = 1,
                 split_min: int = 2, skew_ratio: float = 2.0):
        if k < 1 or k_max < k or min_grain < 1:
            raise ValueError(f"bad grain controller ({k=}, {k_max=}, "
                             f"{min_grain=})")
        self.k0 = self.k = k
        self.k_max = k_max
        self.min_grain = min_grain
        self.split_min = split_min
        #: p90/p50 item-latency ratio above which steals count as cost
        #: imbalance rather than churn
        self.skew_ratio = skew_ratio
        self._last_steals: Optional[int] = None

    def plan(self, n: int, workers: int, telemetry=None) -> GrainPlan:
        """Initial grain for an ``n``-item loop over ``workers`` workers,
        adapting ``k`` from the steal delta since the previous plan."""
        if telemetry is not None:
            steals = telemetry.steals  # benign racy read (advisory)
            if self._last_steals is not None:
                delta = steals - self._last_steals
                if delta > 0 and telemetry.recent_skew() >= self.skew_ratio:
                    if delta > workers:
                        self.k = min(self.k * 2, self.k_max)
                elif self.k > self.k0:
                    self.k -= 1  # churn or quiet: relax toward coarse
            self._last_steals = steals
        if n <= 0 or workers <= 0:
            return GrainPlan(None, self.split_min)
        initial = max(self.min_grain, -(-n // (self.k * workers)))
        return GrainPlan(initial, self.split_min)

    def __repr__(self):  # pragma: no cover - debug aid
        return (f"GrainController(k={self.k}, k_max={self.k_max}, "
                f"split_min={self.split_min})")


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Decision:
    """One policy decision for the remaining range.

    ``plan is not None`` → take the parallel arm: spawn ``plan.spawned``,
    run ``plan.caller`` on the calling worker, then join (unless the
    policy escapes the join to an outer finish scope — DCAFE).

    ``plan is None`` → take the serial arm: run items one at a time,
    re-probing capacity every ``recheck_every`` items (0 = never re-probe,
    i.e. fully serial).
    """

    plan: Optional[ChunkPlan] = None
    recheck_every: int = 1


class SchedPolicy:
    """Protocol base for scheduling policies.

    ``decide`` drives range execution (pools, codegen); ``admit`` drives
    slot admission (the serving batcher), where each queued request is a
    single task and capacity is the idle-slot count.
    """

    name: str = "base"
    #: DCAFE: spawned tasks escape the per-loop join to one outer finish.
    escape_join: bool = False

    def decide(self, pos: int, end: int,
               capacity: CapacityProvider) -> Decision:
        raise NotImplementedError

    def admit(self, idle: int, queued: int, total_slots: int) -> int:
        """How many queued requests to place into idle slots right now."""
        raise NotImplementedError

    def grain_plan(self, n: int, capacity: CapacityProvider,
                   telemetry=None) -> GrainPlan:
        """How stealable ranges are carved from this policy's chunks on a
        work-stealing substrate (items per initial range + the re-split
        threshold).  The default keeps each chunk as one lazily-split
        range; DLBC-family policies route through their
        :class:`GrainController` so grain adapts to observed steals."""
        return GrainPlan()

    def prefill_chunk_len(self, remaining: int, busy: int, cap: int) -> int:
        """How many prompt tokens a prefilling slot should push through
        the model this step (chunked prefill in the serving batcher).

        ``remaining`` is the slot's unwritten prompt suffix, ``busy`` the
        number of slots currently decoding (the latency-sensitive work a
        long chunk would stall — the serving analogue of Fig. 6's idle
        probe, re-checked every step), ``cap`` the static width of the
        batched prefill launch buffer.  The base/static behaviour just
        fills the buffer; DLBC resizes against ``busy``."""
        if remaining <= 0:
            return 0
        return max(1, min(remaining, cap))

    def __repr__(self):  # pragma: no cover - debug aid
        return f"{type(self).__name__}()"


class Serial(SchedPolicy):
    """No parallelism: run everything on the caller, never re-probe."""

    name = "serial"

    def decide(self, pos, end, capacity):
        return Decision(plan=None, recheck_every=0)

    def admit(self, idle, queued, total_slots):
        # one request at a time: admit only into a fully idle slot array
        return 1 if idle == total_slots and queued else 0


class LC(SchedPolicy):
    """Static loop chunking: split into ``capacity.total()`` ceil-sized
    chunks regardless of idleness; the caller only joins (Fig. 1(b))."""

    name = "lc"

    def decide(self, pos, end, capacity):
        return Decision(plan=static_plan(pos, end, capacity.total()))

    def admit(self, idle, queued, total_slots):
        # fixed batching: start only when a full batch of slots is free
        return min(idle, queued) if idle == total_slots else 0


class DLBC(SchedPolicy):
    """The paper's dynamic load-balanced chunking (Fig. 6):

    * idle workers present → ``chunk_plan`` over ``idle + 1`` shares, the
      caller keeping the smallest chunk;
    * none idle → serial block, re-probing every ``serial_check_every``
      items (§6(b) design alternative) and resuming the parallel arm when
      a worker frees up and ≥2 items remain.
    """

    name = "dlbc"

    def __init__(self, serial_check_every: int = 1,
                 caller_keeps_smallest: bool = True,
                 grain: Optional[GrainController] = None):
        self.serial_check_every = serial_check_every
        self.caller_keeps_smallest = caller_keeps_smallest
        #: per-policy-instance adaptive grain state (steal feedback is
        #: surface-local, like the rest of the policy's tuning knobs)
        self.grain = grain or GrainController()

    def decide(self, pos, end, capacity):
        idle = capacity.idle()
        if idle > 0:
            return Decision(plan=chunk_plan(
                pos, end, idle,
                caller_keeps_smallest=self.caller_keeps_smallest))
        return Decision(plan=None, recheck_every=self.serial_check_every)

    def admit(self, idle, queued, total_slots):
        # continuous batching: spawn only into idle slots, every step
        return min(idle, queued)

    def grain_plan(self, n, capacity, telemetry=None):
        return self.grain.plan(n, capacity.total(), telemetry)

    def prefill_chunk_len(self, remaining, busy, cap):
        # Fig. 6 applied to prompt tokens: with ``busy`` decoding slots
        # contending for the step, split the remaining prompt into
        # busy + 1 shares and push one share's worth this step — a long
        # prompt never holds latency-sensitive decodes hostage for more
        # than its fair chunk.  Re-probed every step (the serial-block
        # re-check), so the chunk grows back as decodes drain.  With no
        # decodes in flight, fill the launch buffer.
        if remaining <= 0:
            return 0
        if busy <= 0:
            return max(1, min(remaining, cap))
        plan = chunk_plan(0, remaining, busy,
                          caller_keeps_smallest=self.caller_keeps_smallest)
        first = plan.spawned[0] if plan.spawned else plan.caller
        share = max(1, first[1] - first[0])
        return max(1, min(share, remaining, cap))


class DCAFE(DLBC):
    """DLBC + aggressive finish elimination: identical chunking, but the
    spawned tasks escape the per-loop join to a single outer finish scope
    (the "1 finish, ~1000× fewer tasks" composition)."""

    name = "dcafe"
    escape_join = True


POLICIES: Dict[str, Type[SchedPolicy]] = {
    "serial": Serial,
    "lc": LC,
    "dlbc": DLBC,
    "dcafe": DCAFE,
}


def get_policy(policy: Union[str, SchedPolicy, None],
               default: str = "dlbc") -> SchedPolicy:
    """Resolve a policy name (or pass an instance through)."""
    if policy is None:
        policy = default
    if isinstance(policy, SchedPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown policy {policy!r}; choose from {sorted(POLICIES)}")
