"""Fig. 10-style scheduling telemetry: spawn/join counters plus latency
distributions, JSON-emittable for the benchmarks.

``SchedCounters`` is the shared counter core — the simulator's Fig. 10
counters (:class:`repro.core.runtime.Counters`) subclass it, so the IR
simulator, the host pools, and the serving batcher all report through
one counter vocabulary: *spawns* (``async`` analogue) and *joins*
(``finish`` analogue).

Distributions are reported two ways: point percentiles (p50/p90/p99,
back-compat) and a log-bucketed :class:`LogHistogram` with a tail
ratio (p99/p50) — most perf papers never report variance at all (see
ROADMAP, oracle-first harness), so every gated surface carries the
full shape, not just a median.  The histogram is built at ``summary()``
time from the bounded sample window: nothing new on the record path.
"""

from __future__ import annotations

import json
import math
import threading
from collections import deque
from dataclasses import dataclass, field
from itertools import islice
from typing import Deque, Dict, Iterable, List, Optional

#: Sliding window for latency samples: long-lived pools (the global data
#: pool runs for the whole training job) must not grow memory per item.
LATENCY_WINDOW = 8192


def diff_counters(new: Dict, old: Dict) -> Dict:
    """Windowed counter delta between two :meth:`counters_snapshot`
    dicts (``new`` taken after ``old``).  Nested dicts
    (``errors_by_site``, ``exchange``) subtract per key; keys whose
    delta is zero are dropped from nested maps so an incident window
    only reports the sites that moved *inside* it.  Raises if any
    monotone counter would go backwards — that means the snapshots are
    from different telemetry objects (or one was reset mid-window)."""
    out: Dict = {}
    for key, nv in new.items():
        ov = old.get(key)
        if isinstance(nv, dict):
            sub = {k: v - (ov or {}).get(k, 0) for k, v in nv.items()}
            if any(v < 0 for v in sub.values()):
                raise ValueError(
                    f"diff_counters: {key} went backwards ({sub})")
            sub = {k: v for k, v in sub.items() if v}
            if sub:
                out[key] = sub
        else:
            d = nv - (ov or 0)
            if d < 0:
                raise ValueError(
                    f"diff_counters: {key} went backwards "
                    f"({nv} < {ov})")
            out[key] = d
    return out


def percentile(data: Iterable[float], p: float) -> float:
    """Linear-interpolated percentile (numpy-compatible, dependency-free)."""
    data = list(data)
    if not data:
        return 0.0
    s = sorted(data)
    k = (len(s) - 1) * (p / 100.0)
    f = math.floor(k)
    c = math.ceil(k)
    if f == c:
        return float(s[int(k)])
    return s[f] + (s[c] - s[f]) * (k - f)


#: LogHistogram bucket geometry: bucket ``k`` holds samples in
#: ``(HIST_BASE_S * 2**(k-1), HIST_BASE_S * 2**k]`` seconds — 1 µs
#: resolution at the bottom, ~2.6 hours at the top (64 buckets).
HIST_BASE_S = 1e-6
HIST_BUCKETS = 64


class LogHistogram:
    """Log2-bucketed latency histogram: O(1) add, mergeable across
    repeats, percentile estimates within one bucket (≤ 2×) of exact.

    Point percentiles from a bounded sample window stay the precise
    numbers; the histogram is what survives aggregation — bucket counts
    from every repeat/worker merge exactly, where percentiles of
    percentiles are meaningless.
    """

    __slots__ = ("counts", "n", "total", "min", "max")

    def __init__(self):
        self.counts = [0] * HIST_BUCKETS
        self.n = 0
        self.total = 0.0
        self.min = math.inf
        self.max = 0.0

    @staticmethod
    def bucket_of(seconds: float) -> int:
        if seconds <= HIST_BASE_S:
            return 0
        return min(HIST_BUCKETS - 1,
                   max(0, math.ceil(math.log2(seconds / HIST_BASE_S))))

    @staticmethod
    def bucket_edge_s(k: int) -> float:
        """Upper edge of bucket ``k`` in seconds."""
        return HIST_BASE_S * (2.0 ** k)

    def add(self, seconds: float):
        self.counts[self.bucket_of(seconds)] += 1
        self.n += 1
        self.total += seconds
        if seconds < self.min:
            self.min = seconds
        if seconds > self.max:
            self.max = seconds

    def extend(self, samples: Iterable[float]):
        for s in samples:
            self.add(s)
        return self

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        if len(other.counts) != len(self.counts):
            raise ValueError(
                f"LogHistogram bucket-count mismatch: cannot merge "
                f"{len(other.counts)} buckets into {len(self.counts)}")
        for k, c in enumerate(other.counts):
            self.counts[k] += c
        self.n += other.n
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def copy(self) -> "LogHistogram":
        """Snapshot for windowed diffing (``new.diff(old)``)."""
        out = LogHistogram()
        out.counts = list(self.counts)
        out.n = self.n
        out.total = self.total
        out.min = self.min
        out.max = self.max
        return out

    def diff(self, older: "LogHistogram") -> "LogHistogram":
        """The per-interval histogram between two cumulative snapshots:
        ``newer.diff(older)`` subtracts bucket counts, so windowed
        p50/p99 come from snapshot diffing — never from resetting a
        live histogram under its writers.  Raises if ``older`` is not
        actually an earlier snapshot of the same cumulative series
        (negative bucket counts) or bucket geometries differ.

        The window's exact min/max are not recoverable from cumulative
        state; the diff bounds them by its own nonzero buckets, clamped
        by the cumulative extrema — percentiles keep the usual
        upper-bucket-edge contract."""
        if len(older.counts) != len(self.counts):
            raise ValueError(
                f"LogHistogram bucket-count mismatch: cannot diff "
                f"{len(self.counts)} buckets against {len(older.counts)}")
        out = LogHistogram()
        lo_k = hi_k = None
        for k, (a, b) in enumerate(zip(self.counts, older.counts)):
            d = a - b
            if d < 0:
                raise ValueError(
                    f"LogHistogram.diff: bucket {k} would go negative "
                    f"({a} - {b}) — 'older' is not an earlier snapshot")
            out.counts[k] = d
            if d:
                lo_k = k if lo_k is None else lo_k
                hi_k = k
        out.n = self.n - older.n
        out.total = self.total - older.total
        if out.n:
            lo_edge = self.bucket_edge_s(lo_k - 1) if lo_k > 0 else 0.0
            out.min = max(lo_edge, self.min if self.min != math.inf else 0.0)
            out.max = min(self.bucket_edge_s(hi_k), self.max)
        return out

    def percentile(self, p: float) -> float:
        """Upper bucket edge at percentile ``p`` (a ≤2× overestimate —
        consistent, so ratios of histogram percentiles are meaningful)."""
        if self.n == 0:
            return 0.0
        rank = (p / 100.0) * self.n
        seen = 0
        for k, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return min(self.bucket_edge_s(k), self.max)
        return self.max

    def tail_ratio(self, hi: float = 99.0, lo: float = 50.0) -> float:
        """Distribution-shape gate: histogram p``hi`` / p``lo`` (1.0 when
        empty or degenerate).  Bucket-edge ratios quantise to powers of
        two, which is exactly the robustness a CI gate wants."""
        denom = self.percentile(lo)
        return self.percentile(hi) / denom if denom > 0 else 1.0

    def summary(self) -> Dict:
        """Nonzero buckets keyed by upper edge in µs, plus the moments —
        the JSON shape the benchmark artifacts carry."""
        return dict(
            n=self.n,
            mean_ms=round((self.total / self.n) * 1e3, 4) if self.n else 0.0,
            min_ms=round(self.min * 1e3, 4) if self.n else 0.0,
            max_ms=round(self.max * 1e3, 4),
            p50_ms=round(self.percentile(50) * 1e3, 4),
            p90_ms=round(self.percentile(90) * 1e3, 4),
            p99_ms=round(self.percentile(99) * 1e3, 4),
            tail_p99_p50=round(self.tail_ratio(), 3),
            buckets_us={str(int(self.bucket_edge_s(k) * 1e6)): c
                        for k, c in enumerate(self.counts) if c},
        )


@dataclass
class SchedCounters:
    """The Fig. 10 dynamic counts, substrate-neutral."""

    spawns: int = 0      # tasks spawned (#async)
    joins: int = 0       # joins performed (#finish)
    barriers: int = 0
    steps: int = 0
    work: float = 0.0
    #: chunked-prefill accounting (serving batcher).  Deliberately NOT
    #: folded into spawns/joins: the serving AFE contract is one join
    #: per REQUEST, so chunk counts must never disturb the
    #: ``spawns == joins`` quiescence invariant the CI gates replay.
    prefill_chunks: int = 0   # prefill chunk launches executed in-place
    prefill_tokens: int = 0   # prompt tokens written through those chunks


@dataclass
class ExchangeCounters:
    """Per-round expert-parallel all-to-all accounting (``repro.ep``):
    how many (token, choice) pairs crossed the exchange, how many the
    DLBC plan *reassigned* to an idle expert shard before the collective
    (instead of dropping per-shard), and how many were dropped anyway.

    Rounds are counted at both edges: ``posted`` when a round's
    collectives are launched, ``completed`` when its single barrier
    lands.  Today every round blocks before the next, so
    ``posted == completed`` at quiescence — the double-buffered overlap
    (ROADMAP) will hold ``posted - completed`` in-flight rounds, and the
    obs spans for EP rounds emit both edges already.  The AFE invariant
    gated in CI is ``joins == completed`` on the owning telemetry — ONE
    FinishScope join per round, not one per expert or per shard."""

    sent: int = 0         # (token, choice) pairs sent into the all-to-all
    received: int = 0     # pairs received across all shards (== sent)
    reassigned: int = 0   # overflow pairs re-planned to an idle shard
    dropped: int = 0      # pairs no shard had capacity for
    posted: int = 0       # rounds whose collectives were launched
    completed: int = 0    # rounds whose barrier landed (each = one join)
    degraded_rounds: int = 0  # rounds that ran with >= 1 dead shard
    #                           (its lanes rerouted to live shards)

    @property
    def rounds(self) -> int:
        """Back-compat: completed rounds (the pre-split meaning — every
        round used to be counted only once its barrier landed)."""
        return self.completed

    @property
    def in_flight(self) -> int:
        return self.posted - self.completed

    def summary(self) -> Dict[str, int]:
        out = dict(sent=self.sent, received=self.received,
                   reassigned=self.reassigned, dropped=self.dropped,
                   posted=self.posted, completed=self.completed,
                   rounds=self.rounds)
        if self.degraded_rounds:
            out["degraded_rounds"] = self.degraded_rounds
        return out


@dataclass
class SchedTelemetry(SchedCounters):
    """Counters + item accounting + latency distributions.

    The record path is lock-free: ``deque.append`` on a bounded deque is
    GIL-atomic, so worker threads record without contention (counter
    increments likewise stay plain adds — they are only ever bumped from
    the scheduling thread, matching the old pool).  Readers snapshot the
    deque, retrying the rare copy-during-append race."""

    serial_items: int = 0     # items run in the serial fallback block
    parallel_items: int = 0   # items run inside spawned/caller chunks
    steals: int = 0           # work-stealing executor only (whole + split)
    splits: int = 0           # steals that split a range (adaptive grain):
    #                           the thief took the back half of a stealable
    #                           range; steals - splits = whole-task steals
    #: which worker each steal victimised (work-stealing executor only);
    #: sum of the histogram == steals at quiescence, and a rotating/
    #: randomised victim scan spreads the keys instead of hammering
    #: worker 0.  Bumped under ``lock`` like every cross-thread counter.
    steal_victims: Dict[int, int] = field(default_factory=dict)
    completions: int = 0      # spawned tasks that finished (quiescence:
    #                           spawns == completions + cancelled once every
    #                           join fired)
    errors: int = 0           # items/tasks that raised (collected into the
    #                           joining scope's MultipleExceptions — the
    #                           worker thread survives, the done event still
    #                           fires, the join never hangs)
    #: tasks skipped whole because their scope's CancelToken fired
    #: (fail_fast) — spawns == completions + cancelled at quiescence.
    cancelled: int = 0
    #: individual loop items skipped by cancellation (the item-level
    #: conservation side: intended items == executed + cancelled_items).
    cancelled_items: int = 0
    #: retry attempts consumed by a RetryPolicy (ckpt shards, serving
    #: requests, EP rounds) — bumped via :meth:`record_retry`.
    retries: int = 0
    #: worker threads that died (fault injection / crash containment);
    #: the executor redistributes the dead worker's queued work.
    worker_deaths: int = 0
    #: error counts keyed by emit site ("sched.item", "ckpt.shard",
    #: "serve.request", ...) — sums to ``errors``, so the obs crosscheck
    #: can gate error-instant conservation per site.
    errors_by_site: Dict[str, int] = field(default_factory=dict)
    #: first traceback string seen (the silent-swallow fix: one exemplar
    #: survives even where the raise site only counted before).
    first_error: Optional[str] = None
    #: per-tenant spawn/join counters (multi-tenant serving); keys are
    #: tenant names, values share the Fig. 10 counter vocabulary.  The
    #: conservation invariant — sum of per-tenant spawns/joins equals the
    #: global counters — is gated in CI (bench_tenants).
    tenants: Dict[str, SchedCounters] = field(default_factory=dict)
    #: expert-parallel all-to-all accounting (``repro.ep``); only EP
    #: dispatch surfaces grow it.  Bumped via :meth:`record_exchange`
    #: under ``lock`` like every cross-thread counter.
    exchange: ExchangeCounters = field(default_factory=ExchangeCounters)
    #: most recent samples only (bounded window — see LATENCY_WINDOW)
    latencies: Deque[float] = field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW))
    #: guards counter increments that can race (several producer threads
    #: sharing one executor — the stress tests drive exactly that).  The
    #: latency path stays lock-free; single-threaded surfaces never
    #: contend on it.
    lock: threading.Lock = field(default_factory=threading.Lock,
                                 repr=False, compare=False)

    # Back-compat aliases for the pre-sched ``PoolStats`` field names.
    @property
    def tasks_spawned(self) -> int:
        return self.spawns

    @tasks_spawned.setter
    def tasks_spawned(self, v: int):
        self.spawns = v

    def tenant(self, name: str) -> SchedCounters:
        """The per-tenant counter bucket for ``name`` (created on first
        use).  Only ever touched from the scheduling thread, like the
        global counters."""
        bucket = self.tenants.get(name)
        if bucket is None:
            bucket = self.tenants[name] = SchedCounters()
        return bucket

    def tenant_totals(self) -> Dict[str, int]:
        """Sums of the per-tenant counters — CI gates these against the
        globals (telemetry conservation)."""
        return dict(
            spawns=sum(c.spawns for c in self.tenants.values()),
            joins=sum(c.joins for c in self.tenants.values()),
            prefill_chunks=sum(c.prefill_chunks
                               for c in self.tenants.values()),
            prefill_tokens=sum(c.prefill_tokens
                               for c in self.tenants.values()),
        )

    def record_exchange(self, *, sent: int = 0, received: int = 0,
                        reassigned: int = 0, dropped: int = 0,
                        posted: int = 0, completed: int = 0,
                        degraded: int = 0,
                        rounds: Optional[int] = None):
        """Fold EP exchange counts in.  ``posted``/``completed`` are the
        round edges (a blocking round bumps both at once; the overlap
        path will bump ``posted`` at launch and ``completed`` at the
        barrier).  ``rounds=n`` is the legacy spelling of
        ``posted=n, completed=n``.  The caller is responsible for the
        matching join (``repro.ep.dispatch`` runs each round under a
        ``FinishScope``, so ``joins`` advances by exactly one per
        completed round — the AFE invariant CI gates)."""
        if rounds is not None:
            posted += int(rounds)
            completed += int(rounds)
        with self.lock:
            ex = self.exchange
            ex.sent += int(sent)
            ex.received += int(received)
            ex.reassigned += int(reassigned)
            ex.dropped += int(dropped)
            ex.posted += int(posted)
            ex.completed += int(completed)
            ex.degraded_rounds += int(degraded)

    def record_error(self, site: str, tb: Optional[str] = None):
        """One raising item/task at ``site``: bumps ``errors`` and the
        per-site breakdown under the lock, and keeps the FIRST traceback
        (the silent-swallow fix — an exemplar always survives).  The
        caller emits the matching ``sched.error`` instant (with
        ``args={"site": ...}``) so trace == telemetry holds per site."""
        with self.lock:
            self.errors += 1
            self.errors_by_site[site] = self.errors_by_site.get(site, 0) + 1
            if self.first_error is None and tb:
                self.first_error = tb

    def record_retry(self, site: str):
        """One retry attempt at ``site`` (the RetryPolicy calls this and
        emits the matching ``sched.retry`` instant)."""
        with self.lock:
            self.retries += 1

    def record_cancelled(self, tasks: int = 0, items: int = 0):
        """Tasks skipped whole / items skipped inside a partially-run
        chunk because the scope's CancelToken fired.  The caller emits
        the matching ``sched.cancel`` instant (weight = tasks)."""
        with self.lock:
            self.cancelled += int(tasks)
            self.cancelled_items += int(items)

    def record_latency(self, seconds: float):
        self.latencies.append(seconds)  # GIL-atomic, no lock on the hot path

    def _lat_snapshot(self) -> List[float]:
        while True:
            try:
                return list(self.latencies)
            except RuntimeError:  # deque mutated during copy; retry
                continue

    def recent_skew(self, n: int = 64, p: float = 90.0) -> float:
        """Cost-skew estimate over the most recent ``n`` latency samples:
        ``p``-th percentile / median (≥ 1.0 in practice; 1.0 when there
        are too few samples to judge).  O(n) — the grain controller reads
        this per loop, so it must not sort the whole window.  p90 rather
        than p99: a single OS-preempted item must not make a uniform
        loop look cost-skewed."""
        while True:
            try:
                recent = list(islice(reversed(self.latencies), n))
                break
            except RuntimeError:  # deque mutated during copy; retry
                continue
        if len(recent) < 8:
            return 1.0
        p50 = percentile(recent, 50)
        return percentile(recent, p) / p50 if p50 > 0 else 1.0

    def p50(self) -> float:
        return percentile(self._lat_snapshot(), 50)

    def p99(self) -> float:
        return percentile(self._lat_snapshot(), 99)

    def latency_histogram(self) -> LogHistogram:
        """Log-bucketed histogram of the current latency window (built
        here, at read time — the record path stays a deque append)."""
        return LogHistogram().extend(self._lat_snapshot())

    def summary(self) -> Dict:
        """Flat dict for benchmark tables / JSON artifacts."""
        hist = self.latency_histogram()
        out = dict(
            spawns=self.spawns,
            joins=self.joins,
            barriers=self.barriers,
            serial_items=self.serial_items,
            parallel_items=self.parallel_items,
            steals=self.steals,
            splits=self.splits,
            # quiescence invariant (gated from bench artifacts):
            # spawns == completions + cancelled once every join fired —
            # a raising task still completes (its exception is collected
            # by the joining scope), so errors is a subset of
            # completions, not a complement
            completions=self.completions,
            errors=self.errors,
            cancelled=self.cancelled,
            cancelled_items=self.cancelled_items,
            retries=self.retries,
            worker_deaths=self.worker_deaths,
            # serving chunked prefill: counted beside, never inside,
            # spawns/joins (AFE: one join per request, not per chunk)
            prefill_chunks=self.prefill_chunks,
            prefill_tokens=self.prefill_tokens,
            n_latencies=len(self.latencies),
            p50_ms=round(self.p50() * 1e3, 3),
            p99_ms=round(self.p99() * 1e3, 3),
            latency_hist=hist.summary(),
        )
        if self.errors_by_site:  # only surfaces that saw errors grow it
            out["errors_by_site"] = dict(sorted(self.errors_by_site.items()))
        if self.first_error is not None:
            out["first_error"] = self.first_error
        if self.steal_victims:  # only the work-stealing executor grows it
            out["steal_victims"] = {
                str(w): c for w, c in sorted(self.steal_victims.items())
            }
        if self.tenants:  # only multi-tenant surfaces grow the extra key
            out["tenants"] = {
                name: dict(spawns=c.spawns, joins=c.joins,
                           prefill_chunks=c.prefill_chunks,
                           prefill_tokens=c.prefill_tokens)
                for name, c in sorted(self.tenants.items())
            }
        if self.exchange.posted or self.exchange.completed:
            # only EP dispatch surfaces grow it
            out["exchange"] = self.exchange.summary()
        return out

    def counters_snapshot(self) -> Dict:
        """Cheap point-in-time copy of the monotone counters, in the
        shape :func:`repro.obs.export.crosscheck` understands.  Two
        snapshots diff (:func:`diff_counters`) into a *windowed* summary
        — the flight recorder crosschecks an incident's trace window
        against exactly such a delta, and the metrics plane derives
        per-interval rates the same way.  Taken under ``lock`` so a
        snapshot never tears a multi-field bump."""
        with self.lock:
            out: Dict = dict(
                spawns=self.spawns, joins=self.joins, steals=self.steals,
                splits=self.splits, completions=self.completions,
                errors=self.errors, cancelled=self.cancelled,
                retries=self.retries, worker_deaths=self.worker_deaths,
                prefill_chunks=self.prefill_chunks,
                prefill_tokens=self.prefill_tokens,
            )
            if self.errors_by_site:
                out["errors_by_site"] = dict(self.errors_by_site)
            ex = self.exchange
            if ex.posted or ex.completed:
                out["exchange"] = dict(posted=ex.posted,
                                       completed=ex.completed,
                                       degraded_rounds=ex.degraded_rounds)
        return out

    def to_json(self) -> str:
        return json.dumps(self.summary(), indent=1)

    def reset(self):
        self.spawns = self.joins = self.barriers = self.steps = 0
        self.work = 0.0
        self.serial_items = self.parallel_items = self.steals = 0
        self.splits = self.completions = self.errors = 0
        self.cancelled = self.cancelled_items = 0
        self.retries = self.worker_deaths = 0
        self.errors_by_site = {}
        self.first_error = None
        self.prefill_chunks = self.prefill_tokens = 0
        self.steal_victims = {}
        self.tenants = {}
        self.exchange = ExchangeCounters()
        self.latencies = deque(maxlen=LATENCY_WINDOW)  # atomic rebind
