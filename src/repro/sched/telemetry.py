"""Fig. 10-style scheduling telemetry: spawn/join counters plus latency
distributions (p50/p99), JSON-emittable for the benchmarks.

``SchedCounters`` is the shared counter core — the simulator's Fig. 10
counters (:class:`repro.core.runtime.Counters`) subclass it, so the IR
simulator, the host pools, and the serving batcher all report through
one counter vocabulary: *spawns* (``async`` analogue) and *joins*
(``finish`` analogue).
"""

from __future__ import annotations

import json
import math
import threading
from collections import deque
from dataclasses import dataclass, field
from itertools import islice
from typing import Deque, Dict, Iterable, List

#: Sliding window for latency samples: long-lived pools (the global data
#: pool runs for the whole training job) must not grow memory per item.
LATENCY_WINDOW = 8192


def percentile(data: Iterable[float], p: float) -> float:
    """Linear-interpolated percentile (numpy-compatible, dependency-free)."""
    data = list(data)
    if not data:
        return 0.0
    s = sorted(data)
    k = (len(s) - 1) * (p / 100.0)
    f = math.floor(k)
    c = math.ceil(k)
    if f == c:
        return float(s[int(k)])
    return s[f] + (s[c] - s[f]) * (k - f)


@dataclass
class SchedCounters:
    """The Fig. 10 dynamic counts, substrate-neutral."""

    spawns: int = 0      # tasks spawned (#async)
    joins: int = 0       # joins performed (#finish)
    barriers: int = 0
    steps: int = 0
    work: float = 0.0


@dataclass
class ExchangeCounters:
    """Per-round expert-parallel all-to-all accounting (``repro.ep``):
    how many (token, choice) pairs crossed the exchange, how many the
    DLBC plan *reassigned* to an idle expert shard before the collective
    (instead of dropping per-shard), and how many were dropped anyway.
    ``rounds`` counts dispatch rounds; the AFE invariant gated in CI is
    ``joins == rounds`` on the owning telemetry — ONE FinishScope join
    per round, not one per expert or per shard."""

    sent: int = 0         # (token, choice) pairs sent into the all-to-all
    received: int = 0     # pairs received across all shards (== sent)
    reassigned: int = 0   # overflow pairs re-planned to an idle shard
    dropped: int = 0      # pairs no shard had capacity for
    rounds: int = 0       # dispatch rounds (each = one escaped join)

    def summary(self) -> Dict[str, int]:
        return dict(sent=self.sent, received=self.received,
                    reassigned=self.reassigned, dropped=self.dropped,
                    rounds=self.rounds)


@dataclass
class SchedTelemetry(SchedCounters):
    """Counters + item accounting + latency distributions.

    The record path is lock-free: ``deque.append`` on a bounded deque is
    GIL-atomic, so worker threads record without contention (counter
    increments likewise stay plain adds — they are only ever bumped from
    the scheduling thread, matching the old pool).  Readers snapshot the
    deque, retrying the rare copy-during-append race."""

    serial_items: int = 0     # items run in the serial fallback block
    parallel_items: int = 0   # items run inside spawned/caller chunks
    steals: int = 0           # work-stealing executor only (whole + split)
    splits: int = 0           # steals that split a range (adaptive grain):
    #                           the thief took the back half of a stealable
    #                           range; steals - splits = whole-task steals
    #: which worker each steal victimised (work-stealing executor only);
    #: sum of the histogram == steals at quiescence, and a rotating/
    #: randomised victim scan spreads the keys instead of hammering
    #: worker 0.  Bumped under ``lock`` like every cross-thread counter.
    steal_victims: Dict[int, int] = field(default_factory=dict)
    completions: int = 0      # spawned tasks that finished (quiescence:
    #                           completions == spawns once every join fired)
    errors: int = 0           # spawned tasks that raised (contained by the
    #                           worker — the thread survives, the done event
    #                           still fires, the join never hangs)
    #: per-tenant spawn/join counters (multi-tenant serving); keys are
    #: tenant names, values share the Fig. 10 counter vocabulary.  The
    #: conservation invariant — sum of per-tenant spawns/joins equals the
    #: global counters — is gated in CI (bench_tenants).
    tenants: Dict[str, SchedCounters] = field(default_factory=dict)
    #: expert-parallel all-to-all accounting (``repro.ep``); only EP
    #: dispatch surfaces grow it.  Bumped via :meth:`record_exchange`
    #: under ``lock`` like every cross-thread counter.
    exchange: ExchangeCounters = field(default_factory=ExchangeCounters)
    #: most recent samples only (bounded window — see LATENCY_WINDOW)
    latencies: Deque[float] = field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW))
    #: guards counter increments that can race (several producer threads
    #: sharing one executor — the stress tests drive exactly that).  The
    #: latency path stays lock-free; single-threaded surfaces never
    #: contend on it.
    lock: threading.Lock = field(default_factory=threading.Lock,
                                 repr=False, compare=False)

    # Back-compat aliases for the pre-sched ``PoolStats`` field names.
    @property
    def tasks_spawned(self) -> int:
        return self.spawns

    @tasks_spawned.setter
    def tasks_spawned(self, v: int):
        self.spawns = v

    def tenant(self, name: str) -> SchedCounters:
        """The per-tenant counter bucket for ``name`` (created on first
        use).  Only ever touched from the scheduling thread, like the
        global counters."""
        bucket = self.tenants.get(name)
        if bucket is None:
            bucket = self.tenants[name] = SchedCounters()
        return bucket

    def tenant_totals(self) -> Dict[str, int]:
        """Sums of the per-tenant counters — CI gates these against the
        globals (telemetry conservation)."""
        return dict(
            spawns=sum(c.spawns for c in self.tenants.values()),
            joins=sum(c.joins for c in self.tenants.values()),
        )

    def record_exchange(self, *, sent: int = 0, received: int = 0,
                        reassigned: int = 0, dropped: int = 0,
                        rounds: int = 1):
        """Fold one EP dispatch round's exchange counts in.  The caller
        is responsible for the matching join (``repro.ep.dispatch`` runs
        each round under a ``FinishScope``, so ``joins`` advances by
        exactly one per round — the AFE invariant CI gates)."""
        with self.lock:
            ex = self.exchange
            ex.sent += int(sent)
            ex.received += int(received)
            ex.reassigned += int(reassigned)
            ex.dropped += int(dropped)
            ex.rounds += int(rounds)

    def record_latency(self, seconds: float):
        self.latencies.append(seconds)  # GIL-atomic, no lock on the hot path

    def _lat_snapshot(self) -> List[float]:
        while True:
            try:
                return list(self.latencies)
            except RuntimeError:  # deque mutated during copy; retry
                continue

    def recent_skew(self, n: int = 64, p: float = 90.0) -> float:
        """Cost-skew estimate over the most recent ``n`` latency samples:
        ``p``-th percentile / median (≥ 1.0 in practice; 1.0 when there
        are too few samples to judge).  O(n) — the grain controller reads
        this per loop, so it must not sort the whole window.  p90 rather
        than p99: a single OS-preempted item must not make a uniform
        loop look cost-skewed."""
        while True:
            try:
                recent = list(islice(reversed(self.latencies), n))
                break
            except RuntimeError:  # deque mutated during copy; retry
                continue
        if len(recent) < 8:
            return 1.0
        p50 = percentile(recent, 50)
        return percentile(recent, p) / p50 if p50 > 0 else 1.0

    def p50(self) -> float:
        return percentile(self._lat_snapshot(), 50)

    def p99(self) -> float:
        return percentile(self._lat_snapshot(), 99)

    def summary(self) -> Dict:
        """Flat dict for benchmark tables / JSON artifacts."""
        out = dict(
            spawns=self.spawns,
            joins=self.joins,
            barriers=self.barriers,
            serial_items=self.serial_items,
            parallel_items=self.parallel_items,
            steals=self.steals,
            splits=self.splits,
            n_latencies=len(self.latencies),
            p50_ms=round(self.p50() * 1e3, 3),
            p99_ms=round(self.p99() * 1e3, 3),
        )
        if self.steal_victims:  # only the work-stealing executor grows it
            out["steal_victims"] = {
                str(w): c for w, c in sorted(self.steal_victims.items())
            }
        if self.tenants:  # only multi-tenant surfaces grow the extra key
            out["tenants"] = {
                name: dict(spawns=c.spawns, joins=c.joins)
                for name, c in sorted(self.tenants.items())
            }
        if self.exchange.rounds:  # only EP dispatch surfaces grow it
            out["exchange"] = self.exchange.summary()
        return out

    def to_json(self) -> str:
        return json.dumps(self.summary(), indent=1)

    def reset(self):
        self.spawns = self.joins = self.barriers = self.steps = 0
        self.work = 0.0
        self.serial_items = self.parallel_items = self.steals = 0
        self.splits = self.completions = self.errors = 0
        self.steal_victims = {}
        self.tenants = {}
        self.exchange = ExchangeCounters()
        self.latencies = deque(maxlen=LATENCY_WINDOW)  # atomic rebind
