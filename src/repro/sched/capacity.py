"""CapacityProvider — what "idle workers" means on each execution surface.

The paper's ``Runtime.retIdleWorkers()`` is an *unsynchronised* read of
scheduler state (§3.2.1): two tasks sampling at the same instant may see
the same count, a benign race the policy tolerates by construction.
Every provider here preserves that contract — ``idle()`` is a plain read,
never a lock acquisition.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Protocol, runtime_checkable


@runtime_checkable
class CapacityProvider(Protocol):
    """Idle/total worker counts for one execution substrate."""

    def idle(self) -> int:
        """How many workers could take a task right now (racy read)."""
        ...

    def total(self) -> int:
        """Substrate size: threads, simulated workers, or device slots."""
        ...


@dataclass
class FixedCapacity:
    """A constant capacity — unit tests and cost modelling."""

    idle_n: int
    total_n: int

    def idle(self) -> int:
        return self.idle_n

    def total(self) -> int:
        return self.total_n


class SimWorkerCapacity:
    """Simulated workers of the discrete-event runtime
    (:class:`repro.core.runtime.Scheduler`, duck-typed to avoid a
    sched→core import cycle).  ``idle`` reads the scheduler's idle set at
    the current simulated instant — the benign race is preserved because
    same-instant events observe the same set."""

    def __init__(self, sched):
        self._sched = sched

    def idle(self) -> int:
        return len(self._sched.idle)

    def total(self) -> int:
        return self._sched.n_workers


class PoolCapacity:
    """Host thread-pool idleness: an intentionally unlocked read of the
    executor's idle counter (:class:`repro.sched.executors.ThreadExecutor`)."""

    def __init__(self, executor):
        self._ex = executor

    def idle(self) -> int:
        return self._ex._idle  # intentionally unlocked (paper §3.2.1)

    def total(self) -> int:
        return self._ex.n_workers


class ExpertCapacityProvider:
    """Per-expert slot capacity for MoE dispatch — the device-side
    analogue of :class:`SlotCapacity`: expert ``e`` owns ``slots_per_expert``
    capacity-buffer rows, and a (token, choice) pair is a task that may be
    admitted into one of them.

    This is where the MoE drop/admission arithmetic lives (it used to be a
    private policy inside ``repro.models.moe``): LC admits a token iff its
    static slot position fits (``admit_mask``), DLBC re-routes overflow
    against the residual capacity (``residual`` — the "idle workers" read
    of this substrate, per expert).  The array-valued reads are traced
    under jit; like every provider here they are plain unsynchronised
    reads of scheduler state (paper §3.2.1) — in SPMD form the "benign
    race" becomes reading the round-1 load before round-2 admission.
    """

    def __init__(self, n_experts: int, slots_per_expert: int):
        self.n_experts = n_experts
        self.slots_per_expert = slots_per_expert

    def total(self) -> int:
        return self.n_experts * self.slots_per_expert

    def idle(self) -> int:
        """Before any dispatch every slot is idle; per-expert residuals
        during dispatch come from :meth:`residual` (traced arrays)."""
        return self.total()

    def admit_mask(self, pos):
        """Admission rule: a (token, choice) with running slot index
        ``pos`` inside its chosen expert is admitted iff a slot exists.
        Works on jnp arrays (static-shape SPMD) and plain ints alike."""
        return pos < self.slots_per_expert

    def residual(self, load):
        """Idle slots per expert given the observed per-expert ``load``
        (an (E,) array) — the capacity round-2 re-routing admits against.

        Clamped at zero: a load exceeding an expert's capacity (or the
        provider's *total* capacity) yields zero idle slots, never a
        negative residual that round-2 arithmetic would mis-admit
        against.  The clamped excess is not silently lost — it is
        reported by :meth:`overflow` as a dropped count (the EP exchange
        planner consumes both sides of this split)."""
        import jax.numpy as jnp

        return jnp.maximum(self.slots_per_expert - load, 0)

    def overflow(self, load):
        """Per-expert dropped count: the positive part of
        ``load - slots_per_expert`` — what the :meth:`residual` clamp
        swallowed.  ``residual(load) - overflow(load)`` reconstructs the
        raw (possibly negative) headroom, so conservation
        ``sum(min(load, C)) + sum(overflow) == sum(load)`` holds even
        when the total load exceeds :meth:`total` capacity."""
        import jax.numpy as jnp

        return jnp.maximum(load - self.slots_per_expert, 0)


class SlotCapacity:
    """Device decode slots of the serving batcher: a slot is idle when no
    request occupies it."""

    def __init__(self, slots: List[Optional[object]]):
        self._slots = slots

    def idle(self) -> int:
        return len(self.idle_indices())

    def idle_indices(self) -> List[int]:
        """Idle slot indices, lowest first (the Fig. 6 refill priority:
        oldest queued request → lowest slot)."""
        return [i for i, r in enumerate(self._slots) if r is None]

    def total(self) -> int:
        return len(self._slots)
