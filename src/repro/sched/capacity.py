"""CapacityProvider — what "idle workers" means on each execution surface.

The paper's ``Runtime.retIdleWorkers()`` is an *unsynchronised* read of
scheduler state (§3.2.1): two tasks sampling at the same instant may see
the same count, a benign race the policy tolerates by construction.
Every provider here preserves that contract — ``idle()`` is a plain read,
never a lock acquisition.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Protocol, runtime_checkable


@runtime_checkable
class CapacityProvider(Protocol):
    """Idle/total worker counts for one execution substrate."""

    def idle(self) -> int:
        """How many workers could take a task right now (racy read)."""
        ...

    def total(self) -> int:
        """Substrate size: threads, simulated workers, or device slots."""
        ...


@dataclass
class FixedCapacity:
    """A constant capacity — unit tests and cost modelling."""

    idle_n: int
    total_n: int

    def idle(self) -> int:
        return self.idle_n

    def total(self) -> int:
        return self.total_n


class SimWorkerCapacity:
    """Simulated workers of the discrete-event runtime
    (:class:`repro.core.runtime.Scheduler`, duck-typed to avoid a
    sched→core import cycle).  ``idle`` reads the scheduler's idle set at
    the current simulated instant — the benign race is preserved because
    same-instant events observe the same set."""

    def __init__(self, sched):
        self._sched = sched

    def idle(self) -> int:
        return len(self._sched.idle)

    def total(self) -> int:
        return self._sched.n_workers


class PoolCapacity:
    """Host thread-pool idleness: an intentionally unlocked read of the
    executor's idle counter (:class:`repro.sched.executors.ThreadExecutor`)."""

    def __init__(self, executor):
        self._ex = executor

    def idle(self) -> int:
        return self._ex._idle  # intentionally unlocked (paper §3.2.1)

    def total(self) -> int:
        return self._ex.n_workers


class SlotCapacity:
    """Device decode slots of the serving batcher: a slot is idle when no
    request occupies it."""

    def __init__(self, slots: List[Optional[object]]):
        self._slots = slots

    def idle(self) -> int:
        return len(self.idle_indices())

    def idle_indices(self) -> List[int]:
        """Idle slot indices, lowest first (the Fig. 6 refill priority:
        oldest queued request → lowest slot)."""
        return [i for i, r in enumerate(self._slots) if r is None]

    def total(self) -> int:
        return len(self._slots)
