"""Multi-tenant admission: per-tenant queues + weighted-DLBC refill.

The serving batcher used to serve a single anonymous FIFO.  Multi-tenant
serving keeps ONE :class:`~repro.sched.executors.SlotExecutor` (one
device, one set of decode slots) and layers per-tenant queues over it:
the DLBC rule still decides *how many* requests the freed slots admit
each step (spawn only into idle workers, re-checked every iteration —
paper §3.2), and a weighted deficit-round-robin decides *which tenant*
each of those admissions comes from.

Deficit arithmetic (smoothed DRR, the nginx SWRR discipline):

* every admission round, each *backlogged* tenant's ``deficit`` grows by
  its ``weight``;
* the tenant with the largest deficit is served (FIFO within the
  tenant) and pays the total active weight ``W = sum(w_i)``;
* a tenant whose queue empties forfeits its deficit — idleness banks no
  credit, so a bursty tenant cannot save up and starve a steady one.

Properties (the property tests in ``tests/test_tenancy_property.py``
assert these over random weights/depths/slot counts):

* **work conservation** — while any queue is non-empty, every admission
  the base policy grants is used (no idle slot with queued work);
* **weighted fairness** — over any window where all tenants stay
  backlogged, tenant ``i``'s share of admissions converges to
  ``w_i / W`` (exact at every full cycle of ``W`` admissions for
  integer weights, ±1 admission inside a cycle);
* **no starvation** — a backlogged tenant with weight ``w_i`` is served
  at least once per ``ceil(W / w_i)`` admissions, so a request at
  queue position ``p`` waits at most ``(p + 1) * ceil(W / w_i)``
  admissions.

With a single tenant the deficit bookkeeping is inert — every admission
serves the one queue in FIFO order — so ``wdlbc`` reduces *step-for-step*
to the single-queue DLBC trace (the oracle test in
``tests/test_serve_regression.py`` pins this).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple, Union

from .policy import POLICIES, SchedPolicy, get_policy


@dataclass
class TenantQueue:
    """One tenant: a FIFO of pending requests plus its DRR state."""

    name: str
    weight: float = 1.0
    queue: List[Any] = field(default_factory=list)
    #: DRR credit: grows by ``weight`` each backlogged round, pays the
    #: total active weight when served, forfeited while empty.
    deficit: float = 0.0
    #: lifetime admission count (slot-share accounting / tests)
    admitted: int = 0
    #: per-tenant SLO deadline in decode steps (0 = none): the batcher
    #: derives a request's expiry deadline and join timeout from this —
    #: a request still running ``slo_steps`` after arrival is expired so
    #: its slot frees for the tenant's queue instead of stalling it.
    slo_steps: int = 0
    #: per-token decode-cost ceiling in vtime steps (0 = derive from
    #: ``slo_steps``): the SLO monitor (repro.obs.monitor) counts a step
    #: whose decode cost exceeds this as burning the tenant's error
    #: budget — pure decode costs 1, a co-scheduled whole-prompt prefill
    #: costs ≈ 1 + prompt_len, which is the violation DLBC chunking
    #: exists to prevent.
    slo_cost: float = 0.0

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(
                f"tenant {self.name!r}: weight must be > 0, "
                f"got {self.weight}")

    def __len__(self) -> int:
        return len(self.queue)


class TenantRegistry:
    """Ordered registry of :class:`TenantQueue`\\ s (registration order is
    the DRR tie-break, so admission traces are deterministic)."""

    def __init__(self, weights: Optional[Dict[str, float]] = None):
        self._tenants: Dict[str, TenantQueue] = {}
        for name, w in (weights or {}).items():
            self.register(name, w)

    def register(self, name: str, weight: float = 1.0) -> TenantQueue:
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        t = TenantQueue(name=name, weight=weight)
        self._tenants[name] = t
        return t

    def get(self, name: str) -> TenantQueue:
        return self._tenants[name]

    def submit(self, item: Any, tenant: str = "default") -> TenantQueue:
        """Enqueue ``item`` for ``tenant`` (auto-registering unknown
        tenants at weight 1.0, the anonymous-queue default)."""
        t = self._tenants.get(tenant)
        if t is None:
            t = self.register(tenant, 1.0)
        t.queue.append(item)
        return t

    def order(self) -> List[TenantQueue]:
        return list(self._tenants.values())

    def names(self) -> List[str]:
        return list(self._tenants)

    def total_queued(self) -> int:
        return sum(len(t.queue) for t in self._tenants.values())

    def total_weight(self, backlogged_only: bool = True) -> float:
        ts = [t for t in self._tenants.values()
              if t.queue or not backlogged_only]
        return sum(t.weight for t in ts)

    def __iter__(self) -> Iterator[TenantQueue]:
        return iter(self._tenants.values())

    def __len__(self) -> int:
        return len(self._tenants)

    def __contains__(self, name: str) -> bool:
        return name in self._tenants


class WeightedRefillPolicy(SchedPolicy):
    """Weighted-DLBC admission: the base policy answers *how many* (the
    idle-slot arithmetic of Fig. 6 applied to device slots), the deficit
    round-robin answers *from which tenant*.

    ``decide``/``admit`` delegate to the wrapped base policy, so a
    ``WeightedRefillPolicy`` drops in anywhere a ``SchedPolicy`` goes;
    ``pick`` is the extra cross-tenant surface the generalized
    :meth:`repro.sched.executors.SlotExecutor.refill` consults.
    """

    name = "wdlbc"

    def __init__(self, base: Union[str, SchedPolicy, None] = "dlbc"):
        self.base = get_policy(base, default="dlbc")
        if self.base.escape_join:
            # admission joins are per-request completions; nothing to escape
            raise ValueError("weighted refill over an escape-join base "
                             "policy is not meaningful")

    @property
    def escape_join(self) -> bool:  # type: ignore[override]
        return self.base.escape_join

    def decide(self, pos, end, capacity):
        return self.base.decide(pos, end, capacity)

    def admit(self, idle, queued, total_slots):
        return self.base.admit(idle, queued, total_slots)

    def prefill_chunk_len(self, remaining, busy, cap):
        # chunk arithmetic is the base policy's, like grain_plan below
        return self.base.prefill_chunk_len(remaining, busy, cap)

    def grain_plan(self, n, capacity, telemetry=None):
        # host-side range work under a weighted policy chunks (and
        # steal-splits) exactly like its base: tenancy only changes
        # *whose* request fills a slot, never grain arithmetic
        return self.base.grain_plan(n, capacity, telemetry)

    # -- the cross-tenant choice ---------------------------------------------

    def pick(self, registry: TenantRegistry,
             k: int) -> List[Tuple[TenantQueue, Any]]:
        """Pop up to ``k`` requests across tenants by smoothed DRR.

        Work-conserving: returns exactly ``min(k, total queued)`` items.
        Mutates tenant queues and deficits.
        """
        picks: List[Tuple[TenantQueue, Any]] = []
        # idle tenants forfeit their credit before the round begins
        for t in registry:
            if not t.queue:
                t.deficit = 0.0
        while len(picks) < k:
            active = [t for t in registry.order() if t.queue]
            if not active:
                break
            w_total = sum(t.weight for t in active)
            best = active[0]
            for t in active:
                t.deficit += t.weight
                if t.deficit > best.deficit:  # ties → registration order
                    best = t
            best.deficit -= w_total
            best.admitted += 1
            picks.append((best, best.queue.pop(0)))
            if not best.queue:
                best.deficit = 0.0  # served dry: forfeit leftover credit
        return picks

    @staticmethod
    def starvation_bound(registry: TenantRegistry, tenant: str) -> int:
        """Max admissions between consecutive services of a backlogged
        ``tenant`` (every queued request is admitted within
        ``(position + 1) * bound`` admissions)."""
        t = registry.get(tenant)
        return math.ceil(registry.total_weight(backlogged_only=False)
                         / t.weight)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"WeightedRefillPolicy(base={self.base!r})"


# Register under the policy registry so `get_policy("wdlbc")` and the
# launcher's `--policy wdlbc` resolve like any other policy.
POLICIES["wdlbc"] = WeightedRefillPolicy


def ensure_weighted(policy: Union[str, SchedPolicy, None]
                    ) -> WeightedRefillPolicy:
    """Resolve ``policy`` to a :class:`WeightedRefillPolicy`, wrapping a
    plain base policy (``"dlbc"``, ``DLBC()``, …) when needed — multi-
    tenant refill always goes through the deficit round-robin, which is
    FIFO-transparent for a single tenant."""
    pol = get_policy(policy, default="wdlbc")
    if isinstance(pol, WeightedRefillPolicy):
        return pol
    return WeightedRefillPolicy(base=pol)
