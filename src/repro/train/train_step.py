"""Train-step builder: microbatch accumulation × AFE sync policies.

The paper's join-granularity ladder (DESIGN.md §2.2), expressed as where
gradient synchronisation happens in the compiled step — each rung is
measurable in the dry-run HLO as collective op count / bytes:

* ``unopt``     — pure DP, params replicated over (pod, data); every
                  microbatch's gradients are forced to replicated sharding
                  inside the accumulation scan → an all-reduce *per
                  microbatch per tensor* (the join inside the recursion).
* ``lc``        — pure DP, sync deferred: gradients stay unreduced through
                  the scan; one all-reduce per tensor at step end (static
                  chunking of joins — Nandivada et al.'s LC analogue).
* ``afe``       — FSDP/ZeRO: params + optimizer state sharded over
                  (pod, data); the final gradient constraint is the param
                  sharding, so XLA emits reduce-scatters (half the
                  per-direction bytes of all-reduce) and per-layer
                  all-gathers that overlap with the layer scan — the join
                  hoisted into the sharding structure (the pull).
* ``afe_bucket``— beyond-paper: additionally concatenates the step-end
                  gradients into a few size-balanced flat buckets before
                  the reduce-scatter (finish *fusion*: fewer, larger
                  collectives), with optional bf16 gradient compression.

All policies produce bitwise-identical math (modulo reduction order); the
ladder changes only synchronisation placement — exactly the paper's
semantics-preserving claim.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..distributed.sharding import current_mesh, param_specs_tree
from ..models import model as MDL
from ..sched import FixedCapacity, get_policy
from .optimizer import AdamWConfig, adamw_update

POLICIES = ("unopt", "lc", "afe", "afe_bucket")


@dataclass(frozen=True)
class StepConfig:
    policy: str = "afe"
    grad_compress: str = "none"   # none | bf16
    n_buckets: int = 4            # reduction streams (afe_bucket width)
    sched_policy: str = "dlbc"    # repro.sched policy scheduling the step:
                                  # microbatch unroll + gradient bucketing
    schedule: str = "masked"      # attention chunk schedule (masked | tri)
    q_chunk: int = 1024
    k_chunk: int = 1024
    ssm_chunk: int = 256
    remat: bool = True


def _constrain_tree(tree, spec_tree):
    mesh = current_mesh()
    if mesh is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)),
        tree, spec_tree,
        is_leaf=lambda x: not isinstance(x, dict),
    )


def _replicated_specs(tree):
    return jax.tree.map(lambda x: P(*([None] * x.ndim)), tree)


def _bucketize(grads, n_buckets: int, policy=None, capacity=None):
    """Concatenate raveled grads into fp32 reduction buckets.

    Bucket assignment is scheduled through ``repro.sched`` when a policy
    is given: the policy's ``decide`` over the leaf list yields a
    ``ChunkPlan``, and the *bucket count* comes from that plan — the
    Fig. 6 arithmetic over ``capacity`` (default: ``n_buckets`` reduction
    streams, all but the caller's idle), so fewer idle streams mean fewer
    buckets.  Payload is then spread across that many buckets by greedy
    LPT (bytes, not leaf counts — one embedding leaf outweighs hundreds
    of norm scales), with the caller — the thread issuing the step —
    keeping the smallest-payload bucket, ordered last.

    When the policy declines the parallel arm (no idle reduction streams,
    or ``policy=None``), falls back to LPT into ``n_buckets`` bins — the
    fixed-bucket behaviour, kept as the serial arm and as the oracle the
    sched path is tested against.

    Returns (flatten, unflatten).
    """
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [int(l.size) for l in leaves]
    order = sorted(range(len(leaves)), key=lambda i: -sizes[i])
    nb = n_buckets
    caller_last = False
    if policy is not None:
        policy = get_policy(policy)
        if capacity is None:
            capacity = FixedCapacity(idle_n=n_buckets - 1, total_n=n_buckets)
        plan = policy.decide(0, len(leaves), capacity).plan
        if plan is not None:
            nb = len([c for c in plan.chunks if c[1] > c[0]])
            caller_last = plan.caller[1] > plan.caller[0]
    nb = max(1, min(nb, len(sizes) or 1))
    bins = [[] for _ in range(nb)]
    bin_sz = [0] * nb
    for i in order:
        j = min(range(nb), key=lambda b: bin_sz[b])
        bins[j].append(i)
        bin_sz[j] += sizes[i]
    bins = [b for b in bins if b]
    if caller_last:
        # the caller keeps the smallest chunk: lightest payload last
        bins.sort(key=lambda b: -sum(sizes[i] for i in b))

    def flatten(grads_leaves):
        out = []
        for b in bins:
            out.append(jnp.concatenate(
                [grads_leaves[i].reshape(-1).astype(jnp.float32) for i in b]))
        return out

    def unflatten(buckets):
        new = [None] * len(leaves)
        for bk, b in zip(buckets, bins):
            off = 0
            for i in b:
                n = sizes[i]
                new[i] = bk[off:off + n].reshape(leaves[i].shape)
                off += n
        return jax.tree.unflatten(treedef, new)

    return flatten, unflatten


def build_train_step(cfg: ModelConfig, shape: ShapeConfig,
                     scfg: StepConfig, ocfg: AdamWConfig):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``dp_shard`` (FSDP) is on for the afe policies, off for unopt/lc.
    """
    dp_shard = scfg.policy in ("afe", "afe_bucket")
    M = max(1, shape.microbatches)
    fwd_kw = dict(schedule=scfg.schedule, q_chunk=scfg.q_chunk,
                  k_chunk=scfg.k_chunk, ssm_chunk=scfg.ssm_chunk,
                  remat=scfg.remat)

    # --- scheduling (repro.sched): both step-internal loops are planned by
    # the one policy engine.  Capacity = the step's reduction streams
    # (n_buckets of them; all but the caller's are idle when the step is
    # issued).  The microbatch plan sets the scan unroll (how many
    # accumulation bodies the compiler sees at once — the chunk spawned
    # together); the bucket plan partitions gradient leaves (below).
    sched_pol = get_policy(scfg.sched_policy)
    sched_cap = FixedCapacity(idle_n=scfg.n_buckets - 1,
                              total_n=scfg.n_buckets)
    mb_plan = sched_pol.decide(0, M, sched_cap).plan if M > 1 else None
    mb_unroll = max([1] + [b - a for a, b in mb_plan.chunks]) \
        if mb_plan is not None else 1
    # Fig. 10-comparable static counts per executed step: microbatch chunks
    # and (for afe_bucket) reduction buckets are the spawns; the step-end
    # synchronisation is the join — escaped to the trainer's outer finish
    # scope under DCAFE (one join per training run, not per step).
    spawns_per_step = len(mb_plan.spawned) if mb_plan is not None else 0
    if scfg.policy == "afe_bucket":
        n_leaves = len(jax.tree.leaves(MDL.param_shapes(cfg)))
        bplan = sched_pol.decide(0, n_leaves, sched_cap).plan
        # serial arm (plan None) builds its buckets on the caller: 0 spawns
        spawns_per_step += len(bplan.spawned) if bplan is not None else 0
    sched_counts = {
        "policy": sched_pol.name,
        "spawns": spawns_per_step,
        # nothing spawned (serial arm) → nothing to join; DCAFE escapes
        # its join to the trainer's outer finish
        "joins": 0 if (sched_pol.escape_join or spawns_per_step == 0)
        else 1,
        "mb_unroll": mb_unroll,
        "escape_join": sched_pol.escape_join,
    }

    def loss(params, mb):
        return MDL.loss_fn(params, cfg, mb, **fwd_kw)

    def step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        assert B % M == 0

        def split(x):
            return x.reshape(M, B // M, *x.shape[1:])

        mbs = {k: split(v) for k, v in batch.items()}
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        grad_fn = jax.grad(loss)
        pspecs_fsdp = None
        if scfg.policy in ("afe", "afe_bucket"):
            pspecs_fsdp = param_specs_tree(
                jax.tree.map(
                    lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params),
                cfg, dp_shard=True)
            zero = _constrain_tree(zero, pspecs_fsdp)

        def mb_body(acc, mb):
            g = grad_fn(params, mb)
            g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
            if scfg.policy == "unopt":
                # Join inside the loop: force replication (all-reduce) on
                # every microbatch's gradients.
                g = _constrain_tree(g, _replicated_specs(g))
            elif pspecs_fsdp is not None:
                # True ZeRO-2: reduce-scatter every microbatch's grads to
                # the param sharding — the fp32 accumulation carry stays
                # FSDP-sharded (an unsharded carry is 4 B/param/device:
                # qwen2.5-32b would hold 8.2 GB of gradient state alone —
                # §Perf iteration 6).
                g = _constrain_tree(g, pspecs_fsdp)
            acc = jax.tree.map(jnp.add, acc, g)
            if pspecs_fsdp is not None:
                acc = _constrain_tree(acc, pspecs_fsdp)
            return acc, jnp.zeros((), jnp.float32)

        if M == 1:
            grads = grad_fn(params, {k: v[0] for k, v in mbs.items()})
            grads = jax.tree.map(lambda x: x.astype(jnp.float32), grads)
            if scfg.policy == "unopt":
                grads = _constrain_tree(grads, _replicated_specs(grads))
        else:
            # Microbatch accumulation runs in the chunks the policy
            # planned: ``unroll`` bodies are in flight per scan step, so
            # XLA can overlap their reduce-scatters (the spawned chunk);
            # the remainder runs in the rolled tail (the caller's chunk).
            grads, _ = jax.lax.scan(mb_body, zero, mbs,
                                    unroll=min(mb_unroll, M))
        grads = jax.tree.map(lambda g: g / M, grads)

        # --- step-end synchronisation per policy -------------------------
        if scfg.policy == "lc":
            grads = _constrain_tree(grads, _replicated_specs(grads))
        elif scfg.policy == "afe":
            pspecs = param_specs_tree(
                jax.tree.map(
                    lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params),
                cfg, dp_shard=True)
            grads = _constrain_tree(grads, pspecs)
        elif scfg.policy == "afe_bucket":
            flatten, unflatten = _bucketize(grads, scfg.n_buckets,
                                            policy=sched_pol,
                                            capacity=sched_cap)
            buckets = flatten(jax.tree.leaves(grads))
            if scfg.grad_compress == "bf16":
                buckets = [b.astype(jnp.bfloat16) for b in buckets]
            mesh = current_mesh()
            if mesh is not None:
                # Flat buckets shard over EVERY mesh axis: a partially
                # replicated spec here (e.g. data-only) makes the SPMD
                # partitioner mis-reshard the mixed-sharding concat on
                # some jax releases (observed: gradients exactly doubled
                # on a (2,2) host mesh), and full flat sharding is the
                # ZeRO-correct layout for a fused reduction payload.
                buckets = [
                    jax.lax.with_sharding_constraint(
                        b, NamedSharding(mesh, P(tuple(mesh.axis_names))))
                    for b in buckets
                ]
            buckets = [b.astype(jnp.float32) for b in buckets]
            grads = unflatten(buckets)
            pspecs = param_specs_tree(
                jax.tree.map(
                    lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params),
                cfg, dp_shard=True)
            grads = _constrain_tree(grads, pspecs)

        new_params, new_state, metrics = adamw_update(
            params, grads, opt_state, ocfg)
        return new_params, new_state, metrics

    # Static per-step schedule record: the trainer multiplies these by
    # executed steps into its SchedTelemetry (Fig. 10 spawn/join JSON).
    step.sched_counts = sched_counts
    return step, dp_shard


def build_eval_loss(cfg: ModelConfig, scfg: StepConfig):
    fwd_kw = dict(schedule=scfg.schedule, q_chunk=scfg.q_chunk,
                  k_chunk=scfg.k_chunk, ssm_chunk=scfg.ssm_chunk,
                  remat=scfg.remat)

    def eval_loss(params, batch):
        return MDL.loss_fn(params, cfg, batch, **fwd_kw)

    return eval_loss


def build_prefill_step(cfg: ModelConfig, scfg: StepConfig):
    fwd_kw = dict(schedule=scfg.schedule, q_chunk=scfg.q_chunk,
                  k_chunk=scfg.k_chunk, ssm_chunk=scfg.ssm_chunk,
                  remat=scfg.remat)

    def prefill(params, batch):
        logits = MDL.forward(params, cfg, batch, last_only=True, **fwd_kw)
        return logits[:, -1]  # next-token logits

    return prefill


def build_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, batch):
        return MDL.decode_step(params, cfg, cache, batch)

    return serve_step
