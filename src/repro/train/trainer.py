"""Fault-tolerant training loop.

Large-scale runnability features (designed for 1000+ nodes, exercised on
this host):

* **checkpoint/restart** — async sharded checkpoints every
  ``ckpt_every`` steps; on (re)start the trainer scans for the newest
  *complete* checkpoint and resumes exactly (data pipeline is a pure
  function of step → bitwise-identical batch replay);
* **failure injection** — ``failure_at`` simulates a node crash
  mid-training (raises after the step completes); integration tests
  restart the trainer and verify loss-curve continuity;
* **straggler mitigation** — per-step wall-time EWMA; steps slower than
  ``straggler_factor ×`` the median are logged and counted (on real
  hardware this feeds the reshard/hot-spare controller; here it drives
  the metric surface the tests assert on);
* **elastic restart** — restore() re-places arrays under the current mesh
  sharding, so the same checkpoint resumes on a different device count;
* **non-finite-grad guard** — the optimizer skips bad steps atomically
  (the paper's exception semantics: a failure inside the step must not
  poison the join).
"""

from __future__ import annotations

import sys
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from ..ckpt.checkpoint import CheckpointManager
from ..configs.base import ModelConfig, ShapeConfig
from ..data.pipeline import DataConfig, SyntheticPipeline
from ..models import model as MDL
from ..obs import metrics as obs_metrics
from ..obs import trace as obs
from ..sched import SchedTelemetry
from .optimizer import AdamWConfig, init_opt_state
from .train_step import StepConfig, build_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 2.0
    failure_at: Optional[int] = None  # simulate a crash after this step
    seed: int = 0
    ckpt_sched_policy: str = "dcafe"  # shard-write scheduling (repro.sched)
    #: run checkpoint shard writes on the adaptive work-stealing executor
    #: (steal-driven chunk splitting; grain from the policy's controller)
    ckpt_stealing: bool = False


@dataclass
class TrainReport:
    losses: list = field(default_factory=list)
    grad_norms: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    stragglers: int = 0
    resumed_from: Optional[int] = None
    completed: int = 0
    #: Fig. 10-comparable per-surface spawn/join/latency telemetry
    sched: dict = field(default_factory=dict)


class SimulatedFailure(RuntimeError):
    pass


# Metrics-plane handles (looked up once; bumped once per training step —
# the same per-scheduling-edge discipline as the sched.* handles).
_MX_STEPS = obs_metrics.counter("train.steps")
_MX_STRAGGLERS = obs_metrics.counter("train.stragglers")
_MX_STEP_S = obs_metrics.histogram("train.step_s")
_MX_LOSS = obs_metrics.gauge("train.loss")
_MX_GRAD_NORM = obs_metrics.gauge("train.grad_norm")


def run_training(cfg: ModelConfig, shape: ShapeConfig,
                 tcfg: TrainerConfig,
                 scfg: Optional[StepConfig] = None,
                 ocfg: Optional[AdamWConfig] = None,
                 eval_loss_hook: bool = True) -> TrainReport:
    scfg = scfg or StepConfig(q_chunk=min(1024, shape.seq_len),
                              k_chunk=min(1024, shape.seq_len))
    ocfg = ocfg or AdamWConfig()
    report = TrainReport()

    step_fn, _ = build_train_step(cfg, shape, scfg, ocfg)
    sched_counts = step_fn.sched_counts
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    from .train_step import build_eval_loss

    eval_fn = jax.jit(build_eval_loss(cfg, scfg)) if eval_loss_hook else None

    mgr = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep,
                            sched_policy=tcfg.ckpt_sched_policy,
                            stealing=tcfg.ckpt_stealing)
    # Train-step surface telemetry: the step's static schedule (microbatch
    # chunks + reduction buckets, planned by scfg.sched_policy) counted per
    # executed step; latencies are step wall times.
    step_tel = SchedTelemetry()
    data = SyntheticPipeline(DataConfig(
        seq_len=shape.seq_len, global_batch=shape.global_batch,
        vocab=cfg.vocab, seed=tcfg.seed,
        n_shards=min(8, shape.global_batch)))

    start_step = 0
    latest = mgr.latest_step()
    if latest is not None:
        _, state = mgr.restore(latest)
        params, opt_state = state["params"], state["opt"]
        # restore dtypes (npz roundtrip keeps them; cast params to model dt)
        params = jax.tree.map(
            lambda a, s: jax.numpy.asarray(a, s.dtype), params,
            MDL.param_shapes(cfg))
        start_step = latest
        report.resumed_from = latest
    else:
        params = MDL.init_params(cfg, jax.random.PRNGKey(tcfg.seed))
        opt_state = init_opt_state(params, ocfg)

    times: list = []
    try:
        for step in range(start_step, tcfg.steps):
            # obs phases (cat="train"): data → eval → step → ckpt, one
            # span each per iteration so a trace shows what the wall time
            # of a training step is made of.
            with obs.trace_span("train", "data"):
                batch_np = data.batch_at(step)
                batch = {k: jax.numpy.asarray(v)
                         for k, v in batch_np.items()}
                if cfg.family == "encdec":
                    batch["enc_frames"] = jax.numpy.zeros(
                        (shape.global_batch, cfg.enc_seq, cfg.d_model),
                        jax.numpy.bfloat16)
                if cfg.family == "vlm":
                    batch["vis_embed"] = jax.numpy.zeros(
                        (shape.global_batch, cfg.vis_seq, cfg.d_model),
                        jax.numpy.bfloat16)
            # monotonic step timing (straggler EWMA differences these;
            # time.time() can jump under NTP)
            t0 = time.perf_counter()
            if eval_fn is not None:
                with obs.trace_span("train", "eval"):
                    loss = float(eval_fn(params, batch))
                report.losses.append(loss)
            with obs.trace_span("train", "step", {"step": step}
                                if obs.enabled() else None):
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
                jax.block_until_ready(metrics["grad_norm"])
            dt = time.perf_counter() - t0
            times.append(dt)
            report.step_times.append(dt)
            _MX_STEPS.inc()
            _MX_STEP_S.observe(dt)
            if report.losses:
                _MX_LOSS.set(report.losses[-1])
            step_tel.spawns += sched_counts["spawns"]
            step_tel.joins += sched_counts["joins"]
            # which arm executed the microbatches (run_loop semantics)
            if sched_counts["spawns"] > 0:
                step_tel.parallel_items += max(1, shape.microbatches)
            else:
                step_tel.serial_items += max(1, shape.microbatches)
            step_tel.record_latency(dt)
            report.grad_norms.append(float(metrics["grad_norm"]))
            _MX_GRAD_NORM.set(report.grad_norms[-1])
            # straggler detection
            if len(times) >= 5:
                med = float(np.median(times[-20:]))
                if dt > tcfg.straggler_factor * med:
                    report.stragglers += 1
                    _MX_STRAGGLERS.inc()
            if (step + 1) % tcfg.ckpt_every == 0 or step + 1 == tcfg.steps:
                with obs.trace_span("train", "ckpt", {"step": step + 1}
                                    if obs.enabled() else None):
                    mgr.save(step + 1,
                             {"params": params, "opt": opt_state},
                             blocking=(step + 1 == tcfg.steps))
            elif mgr.pending:
                # the previous step's save overlapped this step's compute;
                # join + publish now so the durability gap is one step,
                # not a whole checkpoint interval
                with obs.trace_span("train", "ckpt_wait"):
                    mgr.wait()
            report.completed = step + 1
            if tcfg.failure_at is not None and step + 1 == tcfg.failure_at:
                raise SimulatedFailure(
                    f"injected failure after step {step+1}")
        if sched_counts["escape_join"] and step_tel.spawns > 0:
            step_tel.joins += 1  # DCAFE: the single outer finish of the run
        report.sched = {
            "train_step": dict(policy=sched_counts["policy"],
                               mb_unroll=sched_counts["mb_unroll"],
                               **step_tel.summary()),
            "checkpoint": dict(policy=mgr.policy.name,
                               **mgr.telemetry.summary()),
        }
        return report
    finally:
        # close() waits on (and publishes) any pending save, then shuts
        # the I/O pool down — also on the failure-injection path.  If an
        # exception is already propagating, a failed pending publish must
        # not replace it (callers match on the primary error, e.g.
        # SimulatedFailure); data.stop() always runs.
        propagating = sys.exc_info()[0] is not None
        try:
            mgr.close()
        except Exception:
            if not propagating:
                raise
        finally:
            data.stop()
