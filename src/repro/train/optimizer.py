"""Sharded AdamW with fp32 master weights.

Optimizer state shards exactly like the params (FSDP under the "afe"
policies, replicated-over-data under the pure-DP "unopt"/"lc" policies) —
see train_step.py for the policy ladder.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    master_fp32: bool = True


def opt_state_shapes(param_shapes: dict, ocfg: AdamWConfig) -> dict:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    out = {
        "m": jax.tree.map(f32, param_shapes),
        "v": jax.tree.map(f32, param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if ocfg.master_fp32:
        out["master"] = jax.tree.map(f32, param_shapes)
    return out


def init_opt_state(params: dict, ocfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    out = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if ocfg.master_fp32:
        out["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return out


def _schedule(step, ocfg: AdamWConfig):
    warm = jnp.minimum(step.astype(jnp.float32) / ocfg.warmup_steps, 1.0)
    return ocfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(params: dict, grads: dict, state: dict, ocfg: AdamWConfig):
    """Returns (new_params, new_state, metrics).

    The non-finite-gradient guard is the exception-semantics analogue
    (DESIGN.md §2.2): a bad microbatch must not corrupt the step — the
    update is skipped atomically, like an exception caught at the single
    outer finish.
    """
    step = state["step"] + 1
    lr = _schedule(step, ocfg)
    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    clip = jnp.where(
        gnorm > ocfg.grad_clip, ocfg.grad_clip / jnp.maximum(gnorm, 1e-9), 1.0)

    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        base = master.astype(jnp.float32)
        new_master = base - lr * (mh / (jnp.sqrt(vh) + ocfg.eps)
                                  + ocfg.weight_decay * base)
        # Exception guard: skip the whole update on non-finite grads.
        m2 = jnp.where(finite, m2, m)
        v2 = jnp.where(finite, v2, v)
        new_master = jnp.where(finite, new_master, base)
        return new_master.astype(p.dtype), m2, v2, new_master

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_ma = jax.tree.leaves(masters)
    new_p, new_m, new_v, new_ma = [], [], [], []
    for p, g, m, v, ma in zip(flat_p, flat_g, flat_m, flat_v, flat_ma):
        a, b, c, d = upd(p, g, m, v, ma)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
        new_ma.append(d)
    new_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    if "master" in state:
        new_state["master"] = jax.tree.unflatten(treedef, new_ma)
    metrics = {"grad_norm": gnorm, "lr": lr,
               "nonfinite_skipped": (~finite).astype(jnp.int32)}
    return jax.tree.unflatten(treedef, new_p), new_state, metrics
