"""Continuous-batching serving loop with DLBC slot scheduling.

The decode step runs a fixed-width batch of slots (static shapes for
XLA).  The scheduler is the DLBC policy over *device slots*:

* an arriving request is admitted only if an idle slot exists (the
  "spawn only when idle workers exist" rule);
* when no slot is idle, requests queue and the current batch keeps
  decoding ("serial block") — after every decode step the scheduler
  re-checks the queue against freed slots (per-iteration re-check);
* freed slots (finished sequences) are refilled in FIFO order with the
  remainder-spread priority of Fig. 6 (oldest request → lowest slot).

Compare with the LC baseline (``policy="lc"``): fixed batching — wait
until a full batch accumulates, run it to completion, then take the next
batch (static chunking of requests).  The benchmark measures mean/p99
latency and slot utilisation for both.

Multi-tenant serving (``policy="wdlbc"`` or a ``tenants=`` weight map)
keeps the SAME slot arithmetic over ONE :class:`SlotExecutor` and layers
per-tenant queues on top: the base policy still sizes each refill to the
idle-slot count, and a weighted deficit-round-robin
(:class:`repro.sched.tenancy.WeightedRefillPolicy`) picks *which tenant*
each freed slot goes to.  With a single tenant the admission trace is
step-for-step identical to plain DLBC (pinned by
``tests/test_serve_regression.py``).

The admission decision itself lives in :mod:`repro.sched` (the shared
policy engine): this module delegates slot refill to
:class:`repro.sched.executors.SlotExecutor`, whose telemetry counts
admissions as spawns and completed sequences as joins (Fig. 10
analogues) alongside latency distributions — per tenant as well as
globally, with the conservation invariant (per-tenant sums == globals)
gated in CI.

Cache positions are tracked PER SLOT and passed to ``decode_step`` as a
``(n_slots,)`` vector: a freshly refilled slot decodes against ITS OWN
position 0 while its neighbours keep decoding at theirs.  (The previous
scheme shared one ``max(slot_pos)`` index across the batch, so a refill
mid-decode wrote the new request's KV at the old request's position and
attended over stale entries — see the refill-mid-decode regression
test.)  Attention-family caches are fully isolated by the per-slot
index + validity mask; SSM/hybrid recurrent state is not position-
indexed and would additionally need a per-slot state reset on refill —
the serving path is exercised with attention families.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as MDL
from ..obs import trace as obs
from ..sched.executors import SlotExecutor
from ..sched.policy import SchedPolicy
from ..sched.telemetry import percentile
from ..sched.tenancy import TenantRegistry, WeightedRefillPolicy


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    arrive_step: int = 0
    start_step: Optional[int] = None
    done_step: Optional[int] = None
    tokens: list = field(default_factory=list)
    tenant: str = "default"


@dataclass
class ServeStats:
    steps: int = 0
    busy_slot_steps: int = 0
    total_slot_steps: int = 0
    latencies: list = field(default_factory=list)
    queue_waits: list = field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.busy_slot_steps / max(1, self.total_slot_steps)

    @property
    def p50_latency(self) -> float:
        return percentile(self.latencies, 50)

    @property
    def p99_latency(self) -> float:
        return percentile(self.latencies, 99)

    def summary(self) -> Dict:
        return dict(steps=self.steps, utilization=round(self.utilization, 4),
                    n_done=len(self.latencies),
                    p50_latency=self.p50_latency,
                    p99_latency=self.p99_latency,
                    mean_queue_wait=(float(np.mean(self.queue_waits))
                                     if self.queue_waits else 0.0))


class ContinuousBatcher:
    """Step-synchronous simulator of the serving loop (decode steps are the
    clock — on hardware each step is one ``serve_step`` launch)."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 cache_len: int = 256,
                 policy: Union[str, SchedPolicy] = "dlbc",
                 tenants: Optional[Dict[str, float]] = None):
        assert isinstance(policy, SchedPolicy) \
            or policy in ("dlbc", "lc", "wdlbc")
        if cfg.family in ("ssm", "hybrid"):
            # The per-slot cache index isolates attention KV across a
            # refill, but SSM/hybrid recurrent state is not position-
            # indexed: a refilled slot would consume the previous
            # occupant's conv/SSM state.  Refuse loudly rather than
            # decode corrupted tokens; serving recurrent families needs
            # a per-slot state reset on refill first.
            raise NotImplementedError(
                f"ContinuousBatcher does not support recurrent cache "
                f"families yet (family={cfg.family!r}): slot refill "
                f"would leak SSM state between requests")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.sched = SlotExecutor(n_slots, policy=policy)
        self.policy = self.sched.policy.name
        # tenant mode: explicit weights, or any weighted-refill policy
        self.registry: Optional[TenantRegistry] = None
        if tenants is not None \
                or isinstance(self.sched.policy, WeightedRefillPolicy):
            self.registry = TenantRegistry(tenants or {"default": 1.0})
            # resolve the refill wrapper NOW so an invalid base policy
            # (escape-join) fails at construction, not mid-run
            self.sched.weighted_policy()
            if not isinstance(self.sched.policy, WeightedRefillPolicy):
                # refill wraps the base policy in the deficit round-robin;
                # label the run accordingly ("wdlbc", "wlc", ...)
                self.policy = f"w{self.policy}"
        self.cache = MDL.init_cache(cfg, n_slots, cache_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.queue: List[Request] = []   # single-queue (anonymous) mode
        self.stats = ServeStats()
        self.tenant_stats: Dict[str, ServeStats] = {}
        if self.registry is not None:
            for name in self.registry.names():
                self.tenant_stats[name] = ServeStats()
        #: admission trace: (step, slot, rid, tenant) per placement — the
        #: golden-file surface of the regression tests
        self.admissions: List[Tuple[int, int, int, str]] = []
        self._decode = jax.jit(
            lambda p, c, b: MDL.decode_step(p, cfg, c, b))

    # -- admission (DLBC vs LC vs weighted-DLBC) -----------------------------

    def submit(self, req: Request, tenant: Optional[str] = None):
        """Queue a request.  ``tenant`` overrides ``req.tenant``; in
        single-queue mode tenant labels are carried but not scheduled on."""
        if tenant is not None:
            req.tenant = tenant
        if self.registry is not None:
            self.registry.submit(req, req.tenant)
            if req.tenant not in self.tenant_stats:
                self.tenant_stats[req.tenant] = ServeStats()
        else:
            self.queue.append(req)

    def queued(self) -> int:
        return (self.registry.total_queued() if self.registry is not None
                else len(self.queue))

    def _admit(self, now: int):
        # Delegated to the shared policy engine: DLBC fills every idle
        # slot at every step; LC only starts a full batch together; the
        # weighted deficit-round-robin arbitrates across tenant queues.
        backlog = self.registry if self.registry is not None else self.queue
        for slot, req in self.sched.refill(self.slot_req, backlog):
            self._place(slot, req, now)

    def _place(self, slot: int, req: Request, now: int):
        req.start_step = now
        wait = now - req.arrive_step
        self.stats.queue_waits.append(wait)
        if self.registry is not None:
            self.tenant_stats[req.tenant].queue_waits.append(wait)
        self.admissions.append((now, slot, req.rid, req.tenant))
        self.slot_req[slot] = req
        # prefill approximated token-by-token for simplicity of the
        # simulator; prompt tokens replay through decode_step
        self.slot_pos[slot] = 0
        req.tokens = list(req.prompt)

    # -- one decode step across all slots ------------------------------------

    def step(self, now: int):
        # obs phases (cat="serve"): refill → decode → complete, so a
        # trace shows where a decode step's wall time goes (admission
        # arithmetic vs device step vs completion bookkeeping) and slot
        # occupancy can be read against the admit/join instants.
        with obs.trace_span("serve", "refill"):
            self._admit(now)
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        self.stats.total_slot_steps += self.n_slots
        self.stats.busy_slot_steps += len(active)
        self.stats.steps += 1
        for st in self.tenant_stats.values():
            st.total_slot_steps += self.n_slots
            st.steps += 1
        # slot-share accounting off the executor's tenant occupancy map
        # (set at refill, cleared at complete)
        for name, n_busy in self.sched.tenant_busy_slots().items():
            self.tenant_stats[name].busy_slot_steps += n_busy
        if not active:
            return
        with obs.trace_span("serve", "decode",
                            {"active": len(active)} if obs.enabled()
                            else None):
            tokens = np.zeros((self.n_slots, 1), np.int32)
            for i in active:
                tokens[i, 0] = self.slot_req[i].tokens[-1] % self.cfg.vocab
            # Per-slot cache positions: each slot writes/attends at ITS
            # OWN index, so a freshly refilled slot (pos 0) is isolated
            # from a neighbour deep into its sequence (refill-mid-decode
            # safety).
            cache_index = jnp.asarray(self.slot_pos, jnp.int32)
            logits, self.cache = self._decode(
                self.params, self.cache,
                {"tokens": jnp.asarray(tokens), "cache_index": cache_index})
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
        with obs.trace_span("serve", "complete"):
            for i in active:
                r = self.slot_req[i]
                r.tokens.append(int(nxt[i]))
                self.slot_pos[i] += 1
                produced = len(r.tokens) - len(r.prompt)
                if produced >= r.max_new \
                        or self.slot_pos[i] >= self.cache_len - 1:
                    r.done_step = now
                    # latencies live in ServeStats (the serving-facing
                    # record); telemetry only counts the join so Fig. 10
                    # comparisons hold
                    lat = now - r.arrive_step
                    self.stats.latencies.append(lat)
                    ts = self.tenant_stats.get(r.tenant)
                    if ts is not None:
                        ts.latencies.append(lat)
                    self.sched.complete(slot=i)
                    self.slot_req[i] = None
                    self.slot_pos[i] = 0

    # -- driving --------------------------------------------------------------

    def slot_shares(self) -> Dict[str, float]:
        """Fraction of occupied slot-time each tenant received — compare
        against the weight shares for the isolation claim."""
        busy = max(1, self.stats.busy_slot_steps)
        return {name: st.busy_slot_steps / busy
                for name, st in sorted(self.tenant_stats.items())}

    def run(self, requests: List[Request], max_steps: int = 10_000):
        """Drive the clock, injecting each request at its ``arrive_step``
        (stable order for simultaneous arrivals)."""
        pending = sorted(requests, key=lambda r: r.arrive_step)
        now, nxt = 0, 0
        while (nxt < len(pending) or self.queued()
               or any(r is not None for r in self.slot_req)) \
                and now < max_steps:
            while nxt < len(pending) and pending[nxt].arrive_step <= now:
                self.submit(pending[nxt])
                nxt += 1
            self.step(now)
            now += 1
        return self.stats
