"""Continuous-batching serving loop with DLBC slot scheduling and
DLBC-chunked prefill.

The decode step runs a fixed-width batch of slots (static shapes for
XLA).  The scheduler is the DLBC policy over *device slots*:

* an arriving request is admitted only if an idle slot exists (the
  "spawn only when idle workers exist" rule);
* when no slot is idle, requests queue and the current batch keeps
  decoding ("serial block") — after every decode step the scheduler
  re-checks the queue against freed slots (per-iteration re-check);
* freed slots (finished sequences) are refilled in FIFO order with the
  remainder-spread priority of Fig. 6 (oldest request → lowest slot).

Compare with the LC baseline (``policy="lc"``): fixed batching — wait
until a full batch accumulates, run it to completion, then take the next
batch (static chunking of requests).  The benchmark measures mean/p99
latency and slot utilisation for both.

Multi-tenant serving (``policy="wdlbc"`` or a ``tenants=`` weight map)
keeps the SAME slot arithmetic over ONE :class:`SlotExecutor` and layers
per-tenant queues on top: the base policy still sizes each refill to the
idle-slot count, and a weighted deficit-round-robin
(:class:`repro.sched.tenancy.WeightedRefillPolicy`) picks *which tenant*
each freed slot goes to.  With a single tenant the admission trace is
step-for-step identical to plain DLBC (pinned by
``tests/test_serve_regression.py``).

Prefill is REAL and chunked.  On placement, prompt tokens ``0..L-2``
are written into the KV cache by batched span-prefill launches
(:func:`repro.models.model.prefill_step` — per-row cache indices, padded
rows inert), and decode then starts from the LAST prompt token at
position ``L-1``.  The span is split into DLBC-planned chunks: each
step, every prefilling slot asks ``policy.prefill_chunk_len(remaining,
busy, cap)`` — the Fig. 6 arithmetic with the *decoding* slot count as
the contended capacity, re-probed per step like the serial block — so a
long prompt interleaves with its neighbours' decode steps instead of
holding them hostage for its whole prefill.  Chunked prefill is bitwise
identical to whole-prompt prefill (every chunk runs through the same
static launch buffer and each query attends over the full cache; pinned
by ``tests/test_prefill.py``).  AFE: each request holds ONE
:class:`FinishScope` spanning all its prefill chunks plus decode, joined
exactly once at completion — telemetry counts joins == requests, with
chunk work in the separate ``prefill_chunks``/``prefill_tokens``
counters and ``serve.prefill_chunk`` trace spans.

The admission decision itself lives in :mod:`repro.sched` (the shared
policy engine): this module delegates slot refill to
:class:`repro.sched.executors.SlotExecutor`, whose telemetry counts
admissions as spawns and completed sequences as joins (Fig. 10
analogues) alongside latency distributions — per tenant as well as
globally, with the conservation invariant (per-tenant sums == globals)
gated in CI.

Cache positions are tracked PER SLOT and passed to ``decode_step`` /
``prefill_step`` as a ``(n_slots,)`` vector: a freshly refilled slot
prefills/decodes against ITS OWN position while its neighbours keep
decoding at theirs.  (The previous scheme shared one ``max(slot_pos)``
index across the batch, so a refill mid-decode wrote the new request's
KV at the old request's position and attended over stale entries — see
the refill-mid-decode regression test.)  Attention-family caches are
fully isolated by the per-slot index + validity mask; SSM/hybrid
recurrent state is not position-indexed and would additionally need a
per-slot state reset on refill — the serving path is exercised with
attention families.

Step cost is accounted in slot-step *token units*: a step costs 1 for
the decode launch plus the largest prefill chunk that shared it.
``ServeStats.decode_step_costs`` records that cost once per decoded
token, so the per-token decode-latency distribution (and its p99)
directly exposes how much prefill work stalled decoders — the SLO
surface ``bench_tenants`` gates under a long-prompt adversary.

Fault containment is PER REQUEST: a request that raises mid-serve (the
``serve.request`` fault-injection site, or a failed prefill collected
by its scope) frees its slot and is requeued under a bounded
:class:`~repro.sched.faults.RetryPolicy` budget, then counted
``ServeStats.failed`` — neighbouring slots keep decoding bitwise
identically (pinned by ``tests/test_faults.py``).  Tenants may carry an
SLO deadline (``slos=`` or ``TenantQueue.slo_steps``, in decode steps):
requests still in-slot past it are evicted and counted ``expired``, and
the request's one scope join uses a timeout derived from the same SLO
(:class:`~repro.sched.executors.JoinOutcome` distinguishes "timed out"
from "done with failures").
"""

from __future__ import annotations

import traceback
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as MDL
from ..obs import metrics as obs_metrics
from ..obs import trace as obs
from ..obs.monitor import SloMonitor
from ..sched import faults
from ..sched.executors import FinishScope, RangeLatch, SlotExecutor
from ..sched.faults import RetryPolicy
from ..sched.policy import SchedPolicy
from ..sched.telemetry import percentile
from ..sched.tenancy import TenantRegistry, WeightedRefillPolicy

#: always-on metrics plane: one bump set per STEP, never per token
_MX_SERVE_STEPS = obs_metrics.counter("serve.steps")
_MX_QUEUE_DEPTH = obs_metrics.gauge("serve.queue_depth")
_MX_STEP_COST = obs_metrics.gauge("serve.step_cost")


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    arrive_step: int = 0
    start_step: Optional[int] = None
    done_step: Optional[int] = None
    tokens: list = field(default_factory=list)
    tenant: str = "default"
    #: how many times this request has been (re-)admitted after a
    #: failure — compared against ``RetryPolicy.attempts`` before a
    #: poisoned request is requeued instead of counted ``failed``
    attempts: int = 0


@dataclass
class ServeStats:
    steps: int = 0
    busy_slot_steps: int = 0
    total_slot_steps: int = 0
    #: step index at which this stats object started integrating — 0 for
    #: the global stats; for a tenant first seen mid-run it is the
    #: backfill point, so ``steps``/``total_slot_steps`` stay comparable
    #: across tenants (conservation: every tenant's denominators equal
    #: the global ones).
    first_step: int = 0
    #: requests killed by the cache bound (``slot_pos`` ran into
    #: ``cache_len``) before producing ``max_new`` tokens — counted
    #: separately from normal completions so an SLO gate cannot be
    #: satisfied by silently cutting sequences short.
    truncated: int = 0
    #: requests that raised mid-serve (poisoned) and exhausted their
    #: retry budget — the slot was freed, the neighbours kept decoding
    #: (containment), and no latency sample was recorded for them
    failed: int = 0
    #: requests evicted past their tenant's ``slo_steps`` deadline —
    #: the slot frees for queued work instead of a stale request
    #: holding it (counted apart from ``failed``: nothing raised)
    expired: int = 0
    latencies: list = field(default_factory=list)
    queue_waits: list = field(default_factory=list)
    #: one entry per decoded token: the slot-step cost of the step that
    #: produced it (1 + the largest prefill chunk sharing the step) —
    #: the per-token decode latency surface in virtual-time units.
    decode_step_costs: list = field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.busy_slot_steps / max(1, self.total_slot_steps)

    @property
    def p50_latency(self) -> float:
        return percentile(self.latencies, 50)

    @property
    def p99_latency(self) -> float:
        return percentile(self.latencies, 99)

    @property
    def p50_decode_cost(self) -> float:
        return percentile(self.decode_step_costs, 50)

    @property
    def p99_decode_cost(self) -> float:
        return percentile(self.decode_step_costs, 99)

    def summary(self) -> Dict:
        return dict(steps=self.steps, utilization=round(self.utilization, 4),
                    n_done=len(self.latencies),
                    truncated=self.truncated,
                    failed=self.failed,
                    expired=self.expired,
                    p50_latency=self.p50_latency,
                    p99_latency=self.p99_latency,
                    mean_queue_wait=(float(np.mean(self.queue_waits))
                                     if self.queue_waits else 0.0),
                    n_decode_tokens=len(self.decode_step_costs),
                    p50_decode_cost=self.p50_decode_cost,
                    p99_decode_cost=self.p99_decode_cost)


class _PrefillState:
    """Progress of one request's span prefill: the prompt prefix still
    owed to the cache, a cursor, and the range latch its chunks
    discharge into (one latch per request — the AFE join waits it)."""

    __slots__ = ("tokens", "cursor", "latch")

    def __init__(self, tokens: List[int], latch: RangeLatch):
        self.tokens = tokens
        self.cursor = 0
        self.latch = latch


class ContinuousBatcher:
    """Step-synchronous simulator of the serving loop (decode steps are the
    clock — on hardware each step is one ``serve_step`` launch)."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 cache_len: int = 256,
                 policy: Union[str, SchedPolicy] = "dlbc",
                 tenants: Optional[Dict[str, float]] = None,
                 prefill_chunk: int = 32,
                 prefill_mode: str = "chunked",
                 retry: Optional[RetryPolicy] = None,
                 slos: Optional[Dict[str, int]] = None,
                 monitor: Optional[SloMonitor] = None):
        assert isinstance(policy, SchedPolicy) \
            or policy in ("dlbc", "lc", "wdlbc")
        assert prefill_mode in ("chunked", "whole"), prefill_mode
        if cfg.family in ("ssm", "hybrid"):
            # The per-slot cache index isolates attention KV across a
            # refill, but SSM/hybrid recurrent state is not position-
            # indexed: a refilled slot would consume the previous
            # occupant's conv/SSM state.  Refuse loudly rather than
            # decode corrupted tokens; serving recurrent families needs
            # a per-slot state reset on refill first.
            raise NotImplementedError(
                f"ContinuousBatcher does not support recurrent cache "
                f"families yet (family={cfg.family!r}): slot refill "
                f"would leak SSM state between requests")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        #: static width of the batched prefill launch buffer — every
        #: chunk pads to this, which is what keeps chunked prefill
        #: bitwise equal to whole-prompt prefill (one compiled shape)
        self.prefill_chunk = max(1, int(prefill_chunk))
        #: "chunked" interleaves DLBC-planned chunks with decode steps;
        #: "whole" drains a request's entire prefill in its placement
        #: step (the unchunked baseline arm the adversary bench compares
        #: against)
        self.prefill_mode = prefill_mode
        #: per-request containment budget: a poisoned request (one that
        #: raises mid-serve) is requeued until it has been admitted
        #: ``retry.attempts`` times, then counted ``failed`` — its slot
        #: frees either way, so one tenant's poison never stalls another
        #: tenant's decode
        self.retry = retry if retry is not None else RetryPolicy(attempts=3)
        #: per-tenant SLO burn-rate monitor (repro.obs.monitor): fed once
        #: per step; ``None`` costs one attribute read per step
        self.monitor = monitor
        #: tenant → SLO deadline in decode steps (0/absent = none);
        #: merged with any ``TenantQueue.slo_steps`` set on the registry
        self.slos: Dict[str, int] = dict(slos or {})
        self.sched = SlotExecutor(n_slots, policy=policy)
        self.policy = self.sched.policy.name
        # tenant mode: explicit weights, or any weighted-refill policy
        self.registry: Optional[TenantRegistry] = None
        if tenants is not None \
                or isinstance(self.sched.policy, WeightedRefillPolicy):
            self.registry = TenantRegistry(tenants or {"default": 1.0})
            # resolve the refill wrapper NOW so an invalid base policy
            # (escape-join) fails at construction, not mid-run
            self.sched.weighted_policy()
            if not isinstance(self.sched.policy, WeightedRefillPolicy):
                # refill wraps the base policy in the deficit round-robin;
                # label the run accordingly ("wdlbc", "wlc", ...)
                self.policy = f"w{self.policy}"
        if self.registry is not None:
            # mirror explicit SLOs onto the tenant queues so the two
            # spellings (slos= kwarg, TenantQueue.slo_steps) agree
            for name, slo in self.slos.items():
                try:
                    self.registry.get(name).slo_steps = int(slo)
                except KeyError:
                    pass
        self.cache = MDL.init_cache(cfg, n_slots, cache_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        #: one FinishScope per in-flight request, spanning all its
        #: prefill chunks; joined exactly once at completion (AFE)
        self.slot_scope: List[Optional[FinishScope]] = [None] * n_slots
        #: slots whose prompt prefix is still being written (slot →
        #: prefill progress); a slot decodes only once it leaves here
        self._prefilling: Dict[int, _PrefillState] = {}
        self.queue: List[Request] = []   # single-queue (anonymous) mode
        self.stats = ServeStats()
        self.tenant_stats: Dict[str, ServeStats] = {}
        if self.registry is not None:
            for name in self.registry.names():
                self.tenant_stats[name] = ServeStats()
        #: admission trace: (step, slot, rid, tenant) per placement — the
        #: golden-file surface of the regression tests
        self.admissions: List[Tuple[int, int, int, str]] = []
        #: virtual clock in slot-step token units (decodes cost 1, a
        #: prefill round costs its largest chunk) — the time base of the
        #: decode-cost SLO surface
        self.vtime = 0
        self._decode = jax.jit(
            lambda p, c, b: MDL.decode_step(p, cfg, c, b))
        self._prefill = jax.jit(
            lambda p, c, b: MDL.prefill_step(p, cfg, c, b))

    # -- admission (DLBC vs LC vs weighted-DLBC) -----------------------------

    def submit(self, req: Request, tenant: Optional[str] = None):
        """Queue a request.  ``tenant`` overrides ``req.tenant``; in
        single-queue mode tenant labels are carried but not scheduled on.

        Validates the prompt here, at the boundary: an empty prompt used
        to crash deep in ``step()`` (``tokens[-1]`` IndexError) and
        out-of-vocab ids used to be silently wrapped ``% vocab`` —
        both now fail loudly at submission."""
        if tenant is not None:
            req.tenant = tenant
        if not req.prompt:
            raise ValueError(
                f"request {req.rid}: empty prompt — decode needs at "
                f"least one token to feed the first step")
        bad = [int(t) for t in req.prompt
               if not 0 <= int(t) < self.cfg.vocab]
        if bad:
            raise ValueError(
                f"request {req.rid}: prompt ids {bad[:4]} outside "
                f"[0, {self.cfg.vocab}) — out-of-vocab ids are not "
                f"silently remapped")
        if len(req.prompt) > self.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot fit cache_len={self.cache_len}")
        if len(req.prompt) > 1 and (self.cfg.sliding_window > 0
                                    or self.cfg.family not in
                                    ("dense", "moe")):
            raise NotImplementedError(
                f"span prefill needs a full position-indexed KV cache "
                f"(dense/moe, no sliding window); "
                f"family={self.cfg.family!r} "
                f"sliding_window={self.cfg.sliding_window} is limited "
                f"to single-token prompts")
        if self.registry is not None:
            self.registry.submit(req, req.tenant)
            if req.tenant not in self.tenant_stats:
                # Backfill the denominators: a tenant first seen mid-run
                # starts from the GLOBAL step/slot-step counts, so its
                # utilization shares the same denominator as tenants
                # registered at step 0 (conservation invariant asserted
                # in test_tenancy_property).
                self.tenant_stats[req.tenant] = ServeStats(
                    steps=self.stats.steps,
                    total_slot_steps=self.stats.total_slot_steps,
                    first_step=self.stats.steps)
        else:
            self.queue.append(req)

    def queued(self) -> int:
        return (self.registry.total_queued() if self.registry is not None
                else len(self.queue))

    def _admit(self, now: int):
        # Delegated to the shared policy engine: DLBC fills every idle
        # slot at every step; LC only starts a full batch together; the
        # weighted deficit-round-robin arbitrates across tenant queues.
        backlog = self.registry if self.registry is not None else self.queue
        for slot, req in self.sched.refill(self.slot_req, backlog):
            self._place(slot, req, now)

    def _place(self, slot: int, req: Request, now: int):
        req.start_step = now
        wait = now - req.arrive_step
        self.stats.queue_waits.append(wait)
        if self.registry is not None:
            self.tenant_stats[req.tenant].queue_waits.append(wait)
        self.admissions.append((now, slot, req.rid, req.tenant))
        self.slot_req[slot] = req
        # Real prefill: prompt tokens 0..L-2 are written into the KV
        # cache by span-prefill chunks (interleaved with decode steps by
        # the policy's chunk arithmetic); decode then starts from the
        # LAST prompt token at position L-1.
        self.slot_pos[slot] = 0
        req.tokens = list(req.prompt)
        prefix = req.prompt[:-1]
        # One FinishScope per request over ONE latch covering every
        # prefill chunk (AFE: chunks discharge the latch, the scope is
        # joined once at completion).  telemetry=None — the request's
        # single counted join stays sched.complete()'s.
        scope = FinishScope()
        latch = RangeLatch(len(prefix))
        scope.add([latch])
        self.slot_scope[slot] = scope
        if prefix:
            self._prefilling[slot] = _PrefillState(prefix, latch)

    # -- per-request containment (faults, retries, SLO deadlines) ------------

    def _slo_of(self, tenant: str) -> int:
        """Deadline in decode steps for ``tenant`` (0 = none): the
        explicit ``slos=`` map wins, else the tenant queue's
        ``slo_steps``."""
        if tenant in self.slos:
            return int(self.slos[tenant])
        if self.registry is not None:
            try:
                return int(self.registry.get(tenant).slo_steps)
            except KeyError:
                return 0
        return 0

    def _join_timeout_s(self, tenant: str) -> Optional[float]:
        """Wall bound for the request's ONE scope join, derived from the
        tenant SLO (1 ms of wall time per SLO step — generous, since the
        prefill latch discharges in-step; ``None`` = no SLO, block)."""
        slo = self._slo_of(tenant)
        return None if slo <= 0 else max(1e-3, 1e-3 * slo)

    def _release_slot(self, i: int):
        """Free slot ``i`` without recording a completion latency: drop
        any prefill progress, count the join via ``sched.complete`` (so
        spawns == joins survives failure paths), and clear the slot."""
        self._prefilling.pop(i, None)
        self.sched.complete(slot=i)
        self.slot_req[i] = None
        self.slot_pos[i] = 0

    def _fail_request(self, i: int, now: int):
        """Contain a poisoned request in slot ``i``: record the error,
        free the slot (neighbours keep decoding), then either requeue it
        (within the retry budget) or count it ``failed``.  Never raises —
        one tenant's poison must not take the serving loop down."""
        r = self.slot_req[i]
        self.sched.telemetry.record_error("serve.request",
                                          tb=traceback.format_exc())
        obs.instant("sched", "error", args={"site": "serve.request"})
        scope = self.slot_scope[i]
        if scope is not None:
            # typed, non-raising join: the slot must free regardless of
            # what the scope collected
            scope.wait(timeout=self._join_timeout_s(r.tenant))
            self.slot_scope[i] = None
        self._release_slot(i)
        ts = self.tenant_stats.get(r.tenant)
        if r.attempts + 1 < self.retry.attempts:
            r.attempts += 1
            self.sched.telemetry.record_retry("serve.request")
            obs.instant("sched", "retry", args={"site": "serve.request"})
            r.arrive_step = now
            r.start_step = None
            r.done_step = None
            r.tokens = []
            if self.registry is not None:
                self.registry.submit(r, r.tenant)
            else:
                self.queue.append(r)
        else:
            self.stats.failed += 1
            if ts is not None:
                ts.failed += 1

    def _expire_request(self, i: int, now: int):
        """Evict the request in slot ``i`` past its tenant SLO deadline:
        the slot frees for queued work; the eviction is counted
        ``expired`` (apart from ``failed`` — nothing raised)."""
        r = self.slot_req[i]
        scope = self.slot_scope[i]
        if scope is not None:
            scope.wait(timeout=self._join_timeout_s(r.tenant))
            self.slot_scope[i] = None
        self._release_slot(i)
        self.stats.expired += 1
        ts = self.tenant_stats.get(r.tenant)
        if ts is not None:
            ts.expired += 1

    # -- chunked prefill ------------------------------------------------------

    def _prefill_phase(self) -> int:
        """Run prefill chunks for every prefilling slot (one batched
        ``prefill_step`` launch per round; rows of non-prefilling slots
        are inert via ``count == 0``).  Chunk lengths come from the
        policy's Fig. 6 arithmetic against the number of DECODING slots,
        re-probed every step; ``prefill_mode="whole"`` instead drains
        each prefill completely in this one step (the unchunked
        baseline).  Returns the phase's cost in token units (the largest
        chunk of each round, summed over rounds)."""
        n_decoding = sum(1 for i, r in enumerate(self.slot_req)
                         if r is not None and i not in self._prefilling)
        cost = 0
        while self._prefilling:
            chunk_of: Dict[int, int] = {}
            for i, st in self._prefilling.items():
                rem = len(st.tokens) - st.cursor
                if self.prefill_mode == "whole":
                    c = min(rem, self.prefill_chunk)
                else:
                    c = self.sched.policy.prefill_chunk_len(
                        rem, n_decoding, self.prefill_chunk)
                chunk_of[i] = max(1, min(int(c), rem, self.prefill_chunk))
            tokens = np.zeros((self.n_slots, self.prefill_chunk), np.int32)
            counts = np.zeros(self.n_slots, np.int32)
            for i, c in chunk_of.items():
                st = self._prefilling[i]
                tokens[i, :c] = st.tokens[st.cursor:st.cursor + c]
                counts[i] = c
            with obs.trace_span("serve", "prefill_chunk",
                                {"slots": len(chunk_of),
                                 "tokens": int(sum(chunk_of.values()))}
                                if obs.enabled() else None):
                _, self.cache = self._prefill(
                    self.params, self.cache,
                    {"tokens": jnp.asarray(tokens),
                     "cache_index": jnp.asarray(self.slot_pos, jnp.int32),
                     "count": jnp.asarray(counts, jnp.int32)})
            cost += max(chunk_of.values())
            for i, c in chunk_of.items():
                st = self._prefilling[i]
                st.cursor += c
                self.slot_pos[i] += c
                st.latch.discharge(c)
                self.sched.prefill(i, c)
                if st.cursor >= len(st.tokens):
                    # prefix complete: the slot joins decode THIS step
                    del self._prefilling[i]
            if self.prefill_mode != "whole":
                break  # chunked: one round per step, re-probe next step
        return cost

    # -- one decode step across all slots ------------------------------------

    def step(self, now: int):
        # obs phases (cat="serve"): refill → prefill_chunk* → decode →
        # complete, so a trace shows where a step's wall time goes
        # (admission arithmetic vs span prefill vs device step vs
        # completion bookkeeping) and slot occupancy can be read against
        # the admit/join/prefill_chunk instants.
        with obs.trace_span("serve", "refill"):
            self._admit(now)
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        self.stats.total_slot_steps += self.n_slots
        self.stats.busy_slot_steps += len(active)
        self.stats.steps += 1
        for st in self.tenant_stats.values():
            st.total_slot_steps += self.n_slots
            st.steps += 1
        # slot-share accounting off the executor's tenant occupancy map
        # (set at refill, cleared at complete)
        for name, n_busy in self.sched.tenant_busy_slots().items():
            self.tenant_stats[name].busy_slot_steps += n_busy
        # SLO expiry: a request still in-slot ``slo_steps`` after arrival
        # is evicted NOW so its slot refills next step — a stale request
        # cannot hold a slot past its tenant's deadline
        expired_any = False
        for i in active:
            r = self.slot_req[i]
            slo = self._slo_of(r.tenant)
            if slo > 0 and now - r.arrive_step >= slo:
                self._expire_request(i, now)
                expired_any = True
        if expired_any:
            active = [i for i, r in enumerate(self.slot_req)
                      if r is not None]
        if not active:
            self.vtime += 1
            self._post_step(now, 0)
            return
        prefill_cost = 0
        if self._prefilling:
            prefill_cost = self._prefill_phase()
        decoding = [i for i in active if i not in self._prefilling]
        step_cost = prefill_cost + (1 if decoding else 0)
        if decoding:
            with obs.trace_span("serve", "decode",
                                {"active": len(decoding)} if obs.enabled()
                                else None):
                tokens = np.zeros((self.n_slots, 1), np.int32)
                for i in decoding:
                    tokens[i, 0] = self.slot_req[i].tokens[-1]
                # Per-slot cache positions: each slot writes/attends at
                # ITS OWN index, so a freshly refilled slot is isolated
                # from a neighbour deep into its sequence
                # (refill-mid-decode safety).
                cache_index = jnp.asarray(self.slot_pos, jnp.int32)
                logits, self.cache = self._decode(
                    self.params, self.cache,
                    {"tokens": jnp.asarray(tokens),
                     "cache_index": cache_index})
                # argmax over the REAL vocab: the padded tail rows of the
                # lm_head are arbitrary init values, and generated ids
                # must stay submittable (no silent % vocab anywhere)
                nxt = np.asarray(
                    jnp.argmax(logits[:, :self.cfg.vocab], axis=-1))
        with obs.trace_span("serve", "complete"):
            plan = faults.active()
            for i in decoding:
                r = self.slot_req[i]
                if plan is not None:
                    # poison hook: an injected fault on this request is
                    # CONTAINED — error recorded, slot freed, request
                    # requeued or failed; the loop moves to the next slot
                    try:
                        plan.poke("serve.request")
                    except Exception:
                        self._fail_request(i, now)
                        continue
                r.tokens.append(int(nxt[i]))
                self.slot_pos[i] += 1
                # per-token decode latency in token units: 1 for the
                # decode plus whatever prefill work shared the step
                self.stats.decode_step_costs.append(step_cost)
                ts = self.tenant_stats.get(r.tenant)
                if ts is not None:
                    ts.decode_step_costs.append(step_cost)
                produced = len(r.tokens) - len(r.prompt)
                done = produced >= r.max_new
                trunc = (not done) and self.slot_pos[i] >= self.cache_len - 1
                if done or trunc:
                    scope, self.slot_scope[i] = self.slot_scope[i], None
                    ok = True
                    if scope is not None:
                        # AFE: the request's ONE join point — waits the
                        # latch spanning every prefill chunk (already
                        # discharged in-step), never one join per chunk.
                        # The typed wait (deadline from the tenant SLO)
                        # distinguishes "timed out" from "done with
                        # failures"; either way the slot frees and the
                        # request is contained as failed rather than
                        # crashing the serving loop.
                        out = scope.wait(
                            timeout=self._join_timeout_s(r.tenant))
                        if out.status != "done":
                            ok = False
                            tb = out.errors[0].tb if out.errors else None
                            self.sched.telemetry.record_error(
                                "serve.request", tb=tb)
                            obs.instant("sched", "error",
                                        args={"site": "serve.request"})
                            self.stats.failed += 1
                            if ts is not None:
                                ts.failed += 1
                    if ok:
                        if trunc:
                            # cache-bound kill: count it apart from
                            # normal completions so p99 gates can't be
                            # satisfied by silently cutting sequences
                            # short
                            self.stats.truncated += 1
                            if ts is not None:
                                ts.truncated += 1
                        r.done_step = now
                        # latencies live in ServeStats (the serving-
                        # facing record); telemetry only counts the join
                        # so Fig. 10 comparisons hold
                        lat = now - r.arrive_step
                        self.stats.latencies.append(lat)
                        if ts is not None:
                            ts.latencies.append(lat)
                    self.sched.complete(slot=i)
                    self.slot_req[i] = None
                    self.slot_pos[i] = 0
        self.vtime += max(1, step_cost)
        self._post_step(now, step_cost)

    def _post_step(self, now: int, step_cost: int):
        """Once per step: feed the always-on metrics plane and (when
        attached) the per-tenant SLO burn-rate monitor."""
        _MX_SERVE_STEPS.inc()
        _MX_QUEUE_DEPTH.set(self.queued())
        if step_cost:
            _MX_STEP_COST.set(step_cost)
        if self.monitor is not None:
            self.monitor.observe(self, now)

    # -- driving --------------------------------------------------------------

    def slot_shares(self) -> Dict[str, float]:
        """Fraction of occupied slot-time each tenant received — compare
        against the weight shares for the isolation claim."""
        busy = max(1, self.stats.busy_slot_steps)
        return {name: st.busy_slot_steps / busy
                for name, st in sorted(self.tenant_stats.items())}

    def run(self, requests: List[Request], max_steps: int = 10_000):
        """Drive the clock, injecting each request at its ``arrive_step``
        (stable order for simultaneous arrivals)."""
        pending = sorted(requests, key=lambda r: r.arrive_step)
        now, nxt = 0, 0
        while (nxt < len(pending) or self.queued()
               or any(r is not None for r in self.slot_req)) \
                and now < max_steps:
            while nxt < len(pending) and pending[nxt].arrive_step <= now:
                self.submit(pending[nxt])
                nxt += 1
            self.step(now)
            now += 1
        return self.stats
