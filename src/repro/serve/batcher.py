"""Continuous-batching serving loop with DLBC slot scheduling.

The decode step runs a fixed-width batch of slots (static shapes for
XLA).  The scheduler is the DLBC policy over *device slots*:

* an arriving request is admitted only if an idle slot exists (the
  "spawn only when idle workers exist" rule);
* when no slot is idle, requests queue and the current batch keeps
  decoding ("serial block") — after every decode step the scheduler
  re-checks the queue against freed slots (per-iteration re-check);
* freed slots (finished sequences) are refilled in FIFO order with the
  remainder-spread priority of Fig. 6 (oldest request → lowest slot).

Compare with the LC baseline (``policy="lc"``): fixed batching — wait
until a full batch accumulates, run it to completion, then take the next
batch (static chunking of requests).  The benchmark measures mean/p99
latency and slot utilisation for both.

The admission decision itself lives in :mod:`repro.sched` (the shared
policy engine): this module delegates slot refill to
:class:`repro.sched.executors.SlotExecutor`, whose telemetry counts
admissions as spawns and completed sequences as joins (Fig. 10
analogues) alongside latency distributions.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as MDL
from ..sched.executors import SlotExecutor
from ..sched.policy import SchedPolicy


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    arrive_step: int = 0
    start_step: Optional[int] = None
    done_step: Optional[int] = None
    tokens: list = field(default_factory=list)


@dataclass
class ServeStats:
    steps: int = 0
    busy_slot_steps: int = 0
    total_slot_steps: int = 0
    latencies: list = field(default_factory=list)
    queue_waits: list = field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.busy_slot_steps / max(1, self.total_slot_steps)


class ContinuousBatcher:
    """Step-synchronous simulator of the serving loop (decode steps are the
    clock — on hardware each step is one ``serve_step`` launch)."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 cache_len: int = 256, policy: str = "dlbc"):
        assert isinstance(policy, SchedPolicy) or policy in ("dlbc", "lc")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.sched = SlotExecutor(n_slots, policy=policy)
        self.policy = self.sched.policy.name
        self.cache = MDL.init_cache(cfg, n_slots, cache_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.queue: List[Request] = []
        self.stats = ServeStats()
        self._decode = jax.jit(
            lambda p, c, b: MDL.decode_step(p, cfg, c, b))

    # -- admission (DLBC vs LC) ----------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self, now: int):
        # Delegated to the shared policy engine: DLBC fills every idle
        # slot at every step; LC only starts a full batch together.
        for slot, req in self.sched.refill(self.slot_req, self.queue):
            self._place(slot, req, now)

    def _place(self, slot: int, req: Request, now: int):
        req.start_step = now
        self.stats.queue_waits.append(now - req.arrive_step)
        self.slot_req[slot] = req
        # prefill approximated token-by-token for simplicity of the
        # simulator; prompt tokens replay through decode_step
        self.slot_pos[slot] = 0
        req.tokens = list(req.prompt)

    # -- one decode step across all slots ---------------------------------------

    def step(self, now: int):
        self._admit(now)
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        self.stats.total_slot_steps += self.n_slots
        self.stats.busy_slot_steps += len(active)
        self.stats.steps += 1
        if not active:
            return
        tokens = np.zeros((self.n_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slot_req[i].tokens[-1] % self.cfg.vocab
        # All slots share a cache index in this static-shape step; per-slot
        # positions are tracked host-side and the cache is slot-major.
        cache_index = jnp.asarray(int(max(self.slot_pos[i] for i in active)),
                                  jnp.int32)
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"tokens": jnp.asarray(tokens), "cache_index": cache_index})
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            r = self.slot_req[i]
            r.tokens.append(int(nxt[i]))
            self.slot_pos[i] += 1
            produced = len(r.tokens) - len(r.prompt)
            if produced >= r.max_new or self.slot_pos[i] >= self.cache_len - 1:
                r.done_step = now
                # latencies live in ServeStats (the serving-facing record);
                # telemetry only counts the join so Fig. 10 comparisons hold
                self.stats.latencies.append(now - r.arrive_step)
                self.sched.complete()
                self.slot_req[i] = None
                self.slot_pos[i] = 0

    def run(self, requests: List[Request], max_steps: int = 10_000):
        for r in requests:
            self.submit(r)
        now = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and now < max_steps:
            self.step(now)
            now += 1
        return self.stats
