"""Sharded, asynchronous, fault-tolerant checkpointing.

Design (multi-host-shaped, exercised single-host here):

* each host writes only its **addressable shards** (``addressable_shards``)
  as ``<step>/shard_<proc>_<i>.npz`` files plus a pytree manifest;
* writes go to a temp dir, fsync'd, then atomically renamed —
  a crash mid-write never corrupts the latest checkpoint
  (the trainer's restore scans for the newest *complete* step);
* saving is asynchronous: the arrays are snapshotted to host memory in the
  trainer thread (cheap device→host copy), the file I/O runs on the DLBC
  worker pool (repro/data/pool.py — the paper's runtime scheduling real
  host-side work);
* restore supports **elastic resharding**: arrays are reassembled
  logically and re-placed under the *current* mesh sharding, so a job can
  restart on a different pod count (checkpoint written on 512 chips,
  resumed on 256).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten_with_paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flatten_with_paths(tree[k], f"{prefix}/{k}"))
    else:
        out.append((prefix, tree))
    return out


def _unflatten_from_paths(items: dict):
    root: dict = {}
    for path, val in items.items():
        keys = [k for k in path.split("/") if k]
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = val
    return root


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_pool=None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = async_pool
        self._pending: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: dict, *, blocking: bool = False):
        """Snapshot to host, then write asynchronously."""
        snap = {}
        for path, arr in _flatten_with_paths(tree):
            snap[path] = np.asarray(arr)  # device→host copy now
        self.wait()
        t = threading.Thread(target=self._write, args=(step, snap),
                             daemon=True)
        t.start()
        self._pending = t
        if blocking:
            self.wait()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, snap: dict):
        proc = jax.process_index()
        tmp = self.dir / f"tmp_{step}_{proc}_{os.getpid()}"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {}
        for i, (path, arr) in enumerate(sorted(snap.items())):
            fname = f"shard_{proc}_{i}.npy"
            logical_dtype = str(arr.dtype)
            if arr.dtype.kind not in "biufc":
                # bf16 & friends: store as a same-width integer view; the
                # logical dtype in the manifest restores it on load.
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            np.save(tmp / fname, arr)
            manifest[path] = {"file": fname, "shape": list(arr.shape),
                              "dtype": logical_dtype}
        (tmp / f"manifest_{proc}.json").write_text(json.dumps(manifest))
        (tmp / "COMMIT").write_text(str(time.time()))
        # Atomic publish.
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():  # complete checkpoints only
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Optional[dict] = None) -> tuple:
        """Returns (step, tree).  With ``shardings`` (a pytree of
        NamedSharding matching the saved structure) arrays are re-placed
        under the current mesh — elastic restart path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        proc = jax.process_index()
        manifest = json.loads((d / f"manifest_{proc}.json").read_text())
        flat_shard = None
        if shardings is not None:
            flat_shard = dict(_flatten_with_paths(shardings))
        items = {}
        for path, meta in manifest.items():
            arr = np.load(d / meta["file"])
            import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)

            logical = np.dtype(meta["dtype"])
            if arr.dtype != logical:
                arr = arr.view(logical)
            if flat_shard is not None and path in flat_shard:
                items[path] = jax.device_put(arr, flat_shard[path])
            else:
                items[path] = jax.numpy.asarray(arr)
        return step, _unflatten_from_paths(items)
