"""Sharded, asynchronous, fault-tolerant checkpointing.

Design (multi-host-shaped, exercised single-host here):

* each host writes only its **addressable shards** (``addressable_shards``)
  as ``<step>/shard_<proc>_<i>.npz`` files plus a pytree manifest;
* writes go to a temp dir, fsync'd, then atomically renamed —
  a crash mid-write never corrupts the latest checkpoint
  (the trainer's restore scans for the newest *complete* step);
* saving is asynchronous and scheduled by ``repro.sched``: the arrays are
  snapshotted to host memory in the trainer thread (cheap device→host
  copy), then the per-shard file writes run on a
  :class:`repro.sched.executors.ThreadExecutor` under the manager's
  scheduling policy.  Under the default DCAFE policy the spawned write
  chunks escape their per-loop join into a :class:`FinishScope` — one
  join per ``save``, performed by :meth:`wait`, so the train loop overlaps
  with the I/O and the atomic publish happens at the join;
* restore supports **elastic resharding**: arrays are reassembled
  logically and re-placed under the *current* mesh sharding, so a job can
  restart on a different pod count (checkpoint written on 512 chips,
  resumed on 256).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import traceback
import weakref
from pathlib import Path
from typing import Callable, Optional

import jax
import numpy as np

from ..obs import trace as obs
from ..sched import (
    FinishScope, MultipleExceptions, RetryPolicy, SchedTelemetry,
    TaskError, ThreadExecutor, WorkStealingExecutor, get_policy,
)
from ..sched import faults


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 executor: Optional[ThreadExecutor] = None,
                 sched_policy: str = "dcafe", n_io_workers: int = 4,
                 stealing: bool = False,
                 retry: Optional[RetryPolicy] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.policy = get_policy(sched_policy)
        #: per-shard write retries: a transiently failing shard retries
        #: (bounded, deterministic backoff keyed by shard index) without
        #: aborting the save; only exhausted retries fail the publish.
        self.retry = retry if retry is not None else RetryPolicy(attempts=3)
        # The I/O pool is created lazily on the first save: restore-only
        # managers never spawn threads, and close() is only needed once
        # a save has run.
        self._own_executor = executor is None
        self._ex = executor
        self._n_io_workers = n_io_workers
        # Adaptive work stealing for shard writes: ranges split on steal
        # when shard sizes skew, grain comes from the policy's
        # GrainController (no grain arithmetic on this surface).
        self._stealing = stealing
        self.telemetry = executor.telemetry if executor is not None \
            else SchedTelemetry()
        self._scope: Optional[FinishScope] = None
        self._finalize: Optional[Callable[[], None]] = None

    @property
    def executor(self) -> ThreadExecutor:
        if self._ex is None:
            cls = WorkStealingExecutor if self._stealing else ThreadExecutor
            self._ex = cls(n_workers=self._n_io_workers,
                           telemetry=self.telemetry)
            if self._own_executor:
                # a dropped manager must not leak its worker threads even
                # if the caller never reached close()
                weakref.finalize(self, self._ex.shutdown)
        return self._ex

    @property
    def pending(self) -> bool:
        """A non-blocking save is awaiting its join/publish."""
        return self._scope is not None or self._finalize is not None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: dict, *, blocking: bool = False):
        """Snapshot to host, then write shards through the scheduler.

        Returns once the shard writes are *scheduled* (plus whatever chunk
        the DCAFE plan keeps on the caller); the checkpoint is published
        atomically by :meth:`wait` — exactly one join per save.  A
        non-blocking save is therefore NOT durable until the next
        ``wait()``/``save()``/``close()`` — callers wanting overlap with
        bounded exposure should ``wait()`` shortly after (the trainer
        does so one step later, once the I/O has had a step to finish).
        """
        with obs.trace_span("ckpt", "snapshot", {"step": step}
                            if obs.enabled() else None):
            snap = {}
            for path, arr in _flatten_with_paths(tree):
                snap[path] = np.asarray(arr)  # device→host copy now
        self.wait()
        self._scope = FinishScope(self.telemetry) \
            if self.policy.escape_join else None
        self._finalize = self._write(step, snap, self._scope)
        if blocking:
            self.wait()

    def wait(self):
        """Join the pending save (ONE join — the escaped finish) and
        atomically publish it.  Shard failures collected by the scope
        (after their per-shard retries were exhausted) surface HERE, as
        the publish's ``RuntimeError`` — a failed shard can never be
        COMMITted, and the temp dir is left un-published for forensics.
        """
        scope_errors = []
        if self._scope is not None:
            scope, self._scope = self._scope, None
            out = scope.wait()  # non-raising: publish reports, once
            if out.failed:
                scope_errors = list(out.errors)
        if self._finalize is not None:
            # cleared before the call: a failed publish raises once, not
            # on every subsequent wait()/close()
            fin, self._finalize = self._finalize, None
            fin(scope_errors)

    def close(self):
        try:
            self.wait()
        finally:
            # a failed pending publish must not leak the I/O pool
            if self._own_executor and self._ex is not None:
                self._ex.shutdown()
                self._ex = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def _write(self, step: int, snap: dict, scope: Optional[FinishScope]):
        """Schedule the shard writes; return the publish closure.

        The manifest is fully determined by the snapshot, so it is built
        up front and only the ``np.save`` calls — the actual I/O — run as
        scheduled tasks.
        """
        proc = jax.process_index()
        tmp = self.dir / f"tmp_{step}_{proc}_{os.getpid()}"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {}
        shard_jobs = []
        for i, (path, arr) in enumerate(sorted(snap.items())):
            fname = f"shard_{proc}_{i}.npy"
            logical_dtype = str(arr.dtype)
            if arr.dtype.kind not in "biufc":
                # bf16 & friends: store as a same-width integer view; the
                # logical dtype in the manifest restores it on load.
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            manifest[path] = {"file": fname, "shape": list(arr.shape),
                              "dtype": logical_dtype}
            # the shard index rides along as the retry jitter key — a
            # stable int, never hash(filename) (salted per process)
            shard_jobs.append((tmp / fname, arr, i))

        # A transiently failing shard retries in place (bounded backoff,
        # without aborting the sibling writes); only exhausted retries
        # fail the shard, and those are CONTAINED here — collected under
        # a lock regardless of whether the shard ran on a worker or on
        # the caller's chunk (caller items would otherwise propagate raw
        # and abort the loop mid-save) — then re-checked by publish() so
        # a failed shard can never be COMMITted.
        collected = []  # TaskErrors from exhausted per-shard retries
        collected_lock = threading.Lock()

        def write_shard(job):
            fname, arr, idx = job

            def attempt():
                plan = faults.active()
                if plan is not None:
                    plan.poke("ckpt.shard")
                with obs.trace_span("ckpt", "shard_write",
                                    {"bytes": int(arr.nbytes)}
                                    if obs.enabled() else None):
                    np.save(fname, arr)

            try:
                self.retry.run(attempt, key=idx, site="ckpt.shard",
                               telemetry=self.telemetry)
            except Exception as e:
                with collected_lock:
                    collected.append(TaskError(
                        exc=e, site="ckpt.shard", lo=idx, hi=idx + 1,
                        tb=traceback.format_exc()))

        try:
            self.executor.run_loop(shard_jobs, write_shard,
                                   policy=self.policy, scope=scope)
        except MultipleExceptions as e:
            # defensive: write_shard contains its own failures, but any
            # error a join still surfaces must reach publish identically
            collected.extend(e.errors)

        def publish(scope_errors=()):
            errors = collected + list(scope_errors)
            if errors:
                err = errors[0]
                raise RuntimeError(
                    f"checkpoint step {step}: {len(errors)} shard "
                    f"write(s) failed after retries "
                    f"(first: {err.summary()}); "
                    "leaving the un-COMMITted temp dir") from err.exc
            with obs.trace_span("ckpt", "publish", {"step": step}
                                if obs.enabled() else None):
                (tmp / f"manifest_{proc}.json").write_text(
                    json.dumps(manifest))
                # wall-clock commit timestamp on purpose (it is read by
                # humans across restarts, not differenced)
                (tmp / "COMMIT").write_text(str(time.time()))
                # Atomic publish.
                if final.exists():
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._gc()

        return publish

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():  # complete checkpoints only
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Optional[dict] = None) -> tuple:
        """Returns (step, tree).  With ``shardings`` (a pytree of
        NamedSharding matching the saved structure) arrays are re-placed
        under the current mesh — elastic restart path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        proc = jax.process_index()
        manifest = json.loads((d / f"manifest_{proc}.json").read_text())
        flat_shard = None
        if shardings is not None:
            flat_shard = dict(_flatten_with_paths(shardings))
        items = {}
        for path, meta in manifest.items():
            arr = np.load(d / meta["file"])
            import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)

            logical = np.dtype(meta["dtype"])
            if arr.dtype != logical:
                arr = arr.view(logical)
            if flat_shard is not None and path in flat_shard:
                items[path] = jax.device_put(arr, flat_shard[path])
            else:
                items[path] = jax.numpy.asarray(arr)
        return step, _unflatten_from_paths(items)


def _flatten_with_paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flatten_with_paths(tree[k], f"{prefix}/{k}"))
    else:
        out.append((prefix, tree))
    return out


def _unflatten_from_paths(items: dict):
    root: dict = {}
    for path, val in items.items():
        keys = [k for k in path.split("/") if k]
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = val
    return root
