"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the available devices (smoke configs on CPU; the full
configs are for TPU pods — their distribution plan is proven by
``dryrun.py``).
"""

from __future__ import annotations

import argparse
import json

from ..configs import ARCH_IDS, get_config
from ..configs.base import ShapeConfig
from ..train.optimizer import AdamWConfig
from ..train.train_step import POLICIES, StepConfig
from ..train.trainer import TrainerConfig, run_training


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--policy", default="afe", choices=POLICIES)
    ap.add_argument("--sched-policy", default="dlbc",
                    choices=("serial", "lc", "dlbc", "dcafe"),
                    help="repro.sched policy scheduling the train step "
                         "(microbatch unroll + gradient buckets)")
    ap.add_argument("--ckpt-sched-policy", default="dcafe",
                    choices=("serial", "lc", "dlbc", "dcafe"),
                    help="repro.sched policy for checkpoint shard writes")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--failure-at", type=int, default=None)
    ap.add_argument("--telemetry-json", default=None,
                    help="also dump the per-surface sched telemetry here")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record an obs span trace of the run and write "
                         "Chrome trace-event JSON here (Perfetto-loadable)")
    ap.add_argument("--metrics-json", default=None, metavar="OUT.jsonl",
                    help="stream windowed metrics-registry snapshots "
                         "(JSON lines, one delta per interval) here")
    ap.add_argument("--metrics-interval", type=float, default=1.0,
                    help="snapshot interval in seconds for --metrics-json")
    args = ap.parse_args(argv)

    if args.trace:
        from ..obs import trace as obs_trace
        obs_trace.enable()
    snapshotter = None
    if args.metrics_json:
        from ..obs.metrics import Snapshotter
        snapshotter = Snapshotter(interval_s=args.metrics_interval,
                                  path=args.metrics_json)
        snapshotter.start()

    cfg = get_config(args.arch, smoke=args.smoke)
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train",
                        microbatches=args.microbatches)
    scfg = StepConfig(policy=args.policy, sched_policy=args.sched_policy,
                      q_chunk=min(512, args.seq_len),
                      k_chunk=min(512, args.seq_len),
                      ssm_chunk=min(128, args.seq_len))
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, failure_at=args.failure_at,
                         ckpt_sched_policy=args.ckpt_sched_policy)
    try:
        rep = run_training(cfg, shape, tcfg, scfg, AdamWConfig())
    finally:
        if snapshotter is not None:
            snapshotter.stop()
    out = {
        "arch": cfg.name, "completed": rep.completed,
        "resumed_from": rep.resumed_from,
        "first_loss": rep.losses[0] if rep.losses else None,
        "last_loss": rep.losses[-1] if rep.losses else None,
        "stragglers": rep.stragglers,
        "mean_step_s": sum(rep.step_times) / max(1, len(rep.step_times)),
        # Fig. 10-comparable spawn/join telemetry per execution surface
        "sched": rep.sched,
    }
    print(json.dumps(out, indent=1))
    if args.telemetry_json:
        with open(args.telemetry_json, "w") as f:
            json.dump(rep.sched, f, indent=1)
    if args.trace:
        from ..obs import export as obs_export
        obs_export.write_chrome_trace(args.trace,
                                      extra={"telemetry": rep.sched})
        print(f"[trace written to {args.trace}]")


if __name__ == "__main__":
    main()
