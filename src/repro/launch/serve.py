"""Serving launcher: continuous batching with DLBC slot scheduling.

``python -m repro.launch.serve --arch qwen2.5-32b --smoke --requests 32``
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models import model as MDL
from ..serve.batcher import ContinuousBatcher, Request


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--policy", default="dlbc", choices=("dlbc", "lc"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry-json", default=None,
                    help="also dump the slot-scheduler telemetry here")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = MDL.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i,
                prompt=list(rng.integers(0, cfg.vocab, size=4)),
                max_new=int(rng.integers(4, args.cache_len // 2)),
                arrive_step=int(i * rng.integers(0, 3)))
        for i in range(args.requests)
    ]
    batcher = ContinuousBatcher(cfg, params, n_slots=args.slots,
                                cache_len=args.cache_len, policy=args.policy)
    stats = batcher.run(reqs)
    # Fig. 10-comparable spawn/join telemetry from the slot scheduler
    telemetry = batcher.sched.telemetry.summary()
    print(json.dumps({
        "arch": cfg.name, "policy": args.policy, "steps": stats.steps,
        "utilization": round(stats.utilization, 3),
        "mean_latency_steps": float(np.mean(stats.latencies)),
        "p99_latency_steps": float(np.percentile(stats.latencies, 99)),
        "mean_queue_wait": float(np.mean(stats.queue_waits)),
        "sched": telemetry,
    }, indent=1))
    if args.telemetry_json:
        with open(args.telemetry_json, "w") as f:
            json.dump({"serve_slots": telemetry}, f, indent=1)


if __name__ == "__main__":
    main()
