"""Serving launcher: continuous batching with DLBC slot scheduling.

``python -m repro.launch.serve --arch qwen2.5-32b --smoke --requests 32``

Multi-tenant serving (weighted-DLBC admission over one slot executor):

``python -m repro.launch.serve --arch qwen2.5-32b --smoke --policy wdlbc \\
    --tenants steady,bursty --tenant-weights 3,1 \\
    --tenant-arrivals steady,bursty``

Arrival mixes per tenant: ``steady`` spreads that tenant's requests
uniformly over the trace; ``bursty`` drops them in a few synchronized
bursts; ``front`` queues everything at step 0.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models import model as MDL
from ..serve.batcher import ContinuousBatcher, Request

ARRIVAL_MIXES = ("steady", "bursty", "front")


def make_arrivals(mix: str, n: int, horizon: int, rng) -> list:
    """Arrival steps for one tenant's ``n`` requests over ``horizon``."""
    if mix == "front":
        return [0] * n
    if mix == "steady":
        gap = max(1, horizon // max(1, n))
        return [i * gap for i in range(n)]
    if mix == "bursty":
        n_bursts = max(1, min(4, n // 4))
        starts = sorted(int(rng.integers(0, max(1, horizon)))
                        for _ in range(n_bursts))
        return [starts[i % n_bursts] for i in range(n)]
    raise ValueError(f"unknown arrival mix {mix!r} "
                     f"(choose from {ARRIVAL_MIXES})")


def build_requests(args, cfg, rng) -> tuple:
    """(requests, tenants-weight-map-or-None) from the CLI flags."""
    if args.cache_len < 10:
        raise SystemExit("--cache-len must be >= 10 (max_new is sampled "
                         "from [4, cache_len // 2))")

    def request(rid, arrive_step, tenant="default"):
        # draw order (prompt, max_new, then arrive) matches the original
        # single-queue generator so a given --seed reproduces the same
        # trace it always did
        prompt = list(rng.integers(0, cfg.vocab, size=4))
        max_new = int(rng.integers(4, args.cache_len // 2))
        if arrive_step is None:
            arrive_step = int(rid * rng.integers(0, 3))
        return Request(rid=rid, prompt=prompt, max_new=max_new,
                       arrive_step=arrive_step, tenant=tenant)

    if not args.tenants:
        return [request(i, None) for i in range(args.requests)], None
    names = [t.strip() for t in args.tenants.split(",") if t.strip()]
    weights = ([float(w) for w in args.tenant_weights.split(",")]
               if args.tenant_weights else [1.0] * len(names))
    if len(weights) != len(names):
        raise SystemExit("--tenant-weights must match --tenants")
    mixes = ([m.strip() for m in args.tenant_arrivals.split(",")]
             if args.tenant_arrivals else ["steady"] * len(names))
    if len(mixes) != len(names):
        raise SystemExit("--tenant-arrivals must match --tenants")
    horizon = max(8, args.requests * 2)
    reqs, rid = [], 0
    for name, mix in zip(names, mixes):
        for step in make_arrivals(mix, args.requests, horizon, rng):
            reqs.append(request(rid, step, tenant=name))
            rid += 1
    return reqs, dict(zip(names, weights))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16,
                    help="requests total (single queue) or per tenant")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--policy", default="dlbc",
                    choices=("dlbc", "lc", "wdlbc"))
    ap.add_argument("--tenants", default=None,
                    help="comma-separated tenant names (enables "
                         "multi-tenant admission)")
    ap.add_argument("--tenant-weights", default=None,
                    help="comma-separated weights matching --tenants")
    ap.add_argument("--tenant-arrivals", default=None,
                    help=f"per-tenant arrival mix {ARRIVAL_MIXES}, "
                         "matching --tenants")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry-json", default=None,
                    help="also dump the slot-scheduler telemetry here")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record an obs span trace of the run and write "
                         "Chrome trace-event JSON here (Perfetto-loadable)")
    ap.add_argument("--metrics-json", default=None, metavar="OUT.jsonl",
                    help="stream windowed metrics-registry snapshots "
                         "(JSON lines, one delta per interval) here")
    ap.add_argument("--metrics-interval", type=float, default=0.5,
                    help="snapshot interval in seconds for --metrics-json")
    args = ap.parse_args(argv)

    if args.trace:
        from ..obs import trace as obs_trace
        obs_trace.enable()
    snapshotter = None
    if args.metrics_json:
        from ..obs.metrics import Snapshotter
        snapshotter = Snapshotter(interval_s=args.metrics_interval,
                                  path=args.metrics_json)
        snapshotter.start()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = MDL.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs, tenants = build_requests(args, cfg, rng)
    batcher = ContinuousBatcher(cfg, params, n_slots=args.slots,
                                cache_len=args.cache_len,
                                policy=args.policy, tenants=tenants)
    try:
        stats = batcher.run(reqs)
    finally:
        if snapshotter is not None:
            snapshotter.stop()
    # Fig. 10-comparable spawn/join telemetry from the slot scheduler
    telemetry = batcher.sched.telemetry.summary()
    out = {
        "arch": cfg.name, "policy": batcher.policy, "steps": stats.steps,
        "utilization": round(stats.utilization, 3),
        "mean_latency_steps": float(np.mean(stats.latencies)),
        "p99_latency_steps": float(np.percentile(stats.latencies, 99)),
        "mean_queue_wait": float(np.mean(stats.queue_waits)),
        "sched": telemetry,
    }
    if batcher.tenant_stats:
        out["tenants"] = {name: st.summary()
                          for name, st in sorted(batcher.tenant_stats.items())}
        out["slot_shares"] = batcher.slot_shares()
    print(json.dumps(out, indent=1))
    if args.telemetry_json:
        with open(args.telemetry_json, "w") as f:
            json.dump({"serve_slots": telemetry}, f, indent=1)
    if args.trace:
        from ..obs import export as obs_export
        doc = obs_export.write_chrome_trace(
            args.trace, extra={"telemetry": telemetry})
        check = obs_export.crosscheck(doc, telemetry)
        print(f"[trace written to {args.trace}; "
              f"crosscheck ok={check['ok']}]")


if __name__ == "__main__":
    main()
