"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the host-device override before ANY other import (jax locks the
device count on first initialisation).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, SHAPES, get_config, input_specs, shape_applicable
from ..distributed.sharding import (
    fsdp_axes, mesh_context, named_shardings, param_specs_tree,
)
from ..models import model as MDL
from ..roofline.analysis import (
    model_flops_estimate, roofline_fraction, roofline_from_artifacts,
    roofline_from_opcost,
)
from ..roofline.hlo_analyzer import analyze_hlo
from ..train.optimizer import AdamWConfig, opt_state_shapes
from ..train.train_step import (
    StepConfig, build_decode_step, build_prefill_step, build_train_step,
)
from .mesh import make_production_mesh


def _batch_shardings(specs: dict, mesh, cfg) -> dict:
    fa = fsdp_axes(mesh)
    out = {}
    for k, v in specs.items():
        if k == "cache_index":
            out[k] = NamedSharding(mesh, P())
        elif v.ndim == 2:
            B = v.shape[0]
            dp = fa if B % _axis_size(mesh, fa) == 0 else None
            out[k] = NamedSharding(mesh, P(dp, None))
        else:  # (B, T, D) stub embeddings
            B = v.shape[0]
            dp = fa if B % _axis_size(mesh, fa) == 0 else None
            out[k] = NamedSharding(mesh, P(dp, None, None))
    return out


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _cache_shardings(cache_tree: dict, mesh, cfg):
    """Sharding rules for decode caches (SP for long-context cells):

    * KV caches (L, B, T, KV, h): B → data axes when divisible, else the
      time axis T → data (context/sequence parallelism for B=1 long_500k);
      T additionally → model when still divisible (KV heads are usually
      too few to split 16-way).
    * SSM conv (L, B, cw-1, Di) / state (L, B, Di, N): Di → model
      (matches the in/out projection sharding); B → data when divisible.
    """
    fa = fsdp_axes(mesh)
    dsize = _axis_size(mesh, fa)
    msize = mesh.shape["model"]

    def leaf_spec(path, s):
        nd = s.ndim
        if nd == 5:  # (L, B, T, KV, h)
            _, B, T, KV, h = s.shape
            if B % dsize == 0:
                b_ax, t_ax = fa, ("model" if T % msize == 0 else None)
            elif T % (dsize * msize) == 0:
                b_ax, t_ax = None, (fa + ("model",))
            elif T % dsize == 0:
                b_ax, t_ax = None, fa
            else:
                b_ax, t_ax = None, None
            return NamedSharding(mesh, P(None, b_ax, t_ax, None, None))
        if nd == 4:  # ssm: (L, B, cw-1, Di) or (L, B, Di, N)
            if "conv" in path:
                _, B, _, Di = s.shape
                b_ax = fa if B % dsize == 0 else None
                d_ax = "model" if Di % msize == 0 else None
                return NamedSharding(mesh, P(None, b_ax, None, d_ax))
            _, B, Di, N = s.shape
            b_ax = fa if B % dsize == 0 else None
            d_ax = "model" if Di % msize == 0 else None
            return NamedSharding(mesh, P(None, b_ax, d_ax, None))
        if nd == 6:  # vlm nested self stack (g, k-1, B, T, KV, h)
            _, _, B, T, KV, h = s.shape
            b_ax = fa if B % dsize == 0 else None
            t_ax = "model" if T % msize == 0 else None
            return NamedSharding(mesh, P(None, None, b_ax, t_ax, None, None))
        return NamedSharding(mesh, P(*([None] * nd)))

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        return leaf_spec(path, node)

    return walk(cache_tree, "")


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             policy: str = "afe", schedule: str = "masked",
             mesh=None, verbose: bool = True, hlo_dump=None) -> dict:
    """Lower + compile one cell; return the roofline/memory record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec = dict(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16",
               policy=policy, schedule=schedule, status="skipped",
               reason=reason)
    if not ok:
        return rec
    t0 = time.perf_counter()  # monotonic: lower/compile are timed deltas
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    dp_shard = policy in ("afe", "afe_bucket")
    scfg = StepConfig(policy=policy, schedule=schedule)
    ocfg = AdamWConfig()
    with mesh_context(mesh):
        pshapes = MDL.param_shapes(cfg)
        pshard = named_shardings(pshapes, cfg, dp_shard=dp_shard)
        bspecs = input_specs(cfg, shape)
        bshard = _batch_shardings(bspecs, mesh, cfg)

        if shape.kind == "train":
            oshapes = opt_state_shapes(pshapes, ocfg)
            oshard = {
                "m": named_shardings(pshapes, cfg, dp_shard=dp_shard),
                "v": named_shardings(pshapes, cfg, dp_shard=dp_shard),
                "step": NamedSharding(mesh, P()),
                "master": named_shardings(pshapes, cfg, dp_shard=dp_shard),
            }
            oshapes = {k: oshapes[k] for k in oshard}
            step, _ = build_train_step(cfg, shape, scfg, ocfg)
            fn = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            )
            args = (pshapes, oshapes, bspecs)
        elif shape.kind == "prefill":
            prefill = build_prefill_step(cfg, scfg)
            fn = jax.jit(prefill, in_shardings=(pshard, bshard))
            args = (pshapes, bspecs)
        else:  # decode
            cshapes = MDL.cache_shapes(cfg, shape.global_batch, shape.seq_len)
            cshard = _cache_shardings(cshapes, mesh, cfg)
            serve = build_decode_step(cfg)
            fn = jax.jit(
                serve,
                in_shardings=(pshard, cshard, bshard),
                out_shardings=(None, cshard),
                donate_argnums=(1,),
            )
            args = (pshapes, cshapes, bspecs)

        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    model_flops = model_flops_estimate(cfg, shape)
    # Trip-count-scaled roofline (cost_analysis counts scan bodies once —
    # raw numbers kept under "cost" for reference).
    opcost = analyze_hlo(hlo)
    terms = roofline_from_opcost(opcost, chips=chips,
                                 model_flops=model_flops)
    if hlo_dump is not None:
        import zstandard

        Path(hlo_dump).write_bytes(
            zstandard.ZstdCompressor(level=6).compress(hlo.encode()))
    mem_rec = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        mem_rec[k] = getattr(mem, k, None)
    per_device_bytes = (mem_rec.get("argument_size_in_bytes") or 0) + \
        (mem_rec.get("temp_size_in_bytes") or 0)
    rec.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=mem_rec,
        hbm_per_device_gb=round(per_device_bytes / 2 ** 30, 3),
        fits_hbm=bool(per_device_bytes < 16 * 2 ** 30),
        cost={k: cost.get(k) for k in ("flops", "bytes accessed")
              if k in cost},
        roofline=terms.as_dict(),
        roofline_fraction=round(roofline_fraction(terms), 4),
        n_params=cfg.n_params(),
        n_active_params=cfg.n_active_params(),
    )
    if verbose:
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "status",
                           "hbm_per_device_gb", "fits_hbm",
                           "roofline_fraction", "compile_s")}),
              flush=True)
        print(f"  dominant={terms.dominant} compute={terms.compute_s:.4f}s "
              f"memory={terms.memory_s:.4f}s "
              f"collective={terms.collective_s:.4f}s "
              f"coll_ops={terms.collective_ops}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--policy", default="afe")
    ap.add_argument("--schedule", default="masked")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mname = "2x16x16" if multi_pod else "16x16"
        for arch in archs:
            for shape in shapes:
                tag = f"{mname}_{arch}_{shape}_{args.policy}_{args.schedule}"
                path = outdir / f"{tag}.json"
                if path.exists():
                    print(f"skip (exists): {tag}", flush=True)
                    continue
                print(f"=== {tag}", flush=True)
                try:
                    rec = run_cell(arch, shape, multi_pod=multi_pod,
                                   policy=args.policy,
                                   schedule=args.schedule, mesh=mesh,
                                   hlo_dump=outdir / f"{tag}.hlo.zst")
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = dict(arch=arch, shape=shape, mesh=mname,
                               policy=args.policy, status="error",
                               error=f"{type(e).__name__}: {e}")
                    failures += 1
                path.write_text(json.dumps(rec, indent=1))
    print(f"done; failures={failures}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
