"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis extends data parallelism across the inter-pod links (gradient
sync is the only cross-pod traffic; TP stays inside a pod where ICI is
fastest).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialisation).
"""

from __future__ import annotations

import jax

from ..distributed.sharding import EXPERT_AXIS


def make_production_mesh(*, multi_pod: bool = False, expert: int = 0):
    """``expert > 0`` carves an expert-parallel axis out of the *data*
    axis (16 must divide by it): tokens are exchanged between expert
    shards over intra-pod ICI while gradient sync stays the only
    cross-pod traffic — axes ``("expert", data/expert, "model")``
    (with a leading ``"pod"`` when multi-pod).  MoE expert weights
    shard E over "expert" (distributed/sharding.py) and
    ``moe_apply`` takes the repro.ep all-to-all dispatch path."""
    data = 16
    if expert:
        if data % expert:
            raise ValueError(
                f"expert axis {expert} must divide the data axis {data}")
        shape = (2, expert, data // expert, 16) if multi_pod else \
            (expert, data // expert, 16)
        axes = ("pod", EXPERT_AXIS, "data", "model") if multi_pod else \
            (EXPERT_AXIS, "data", "model")
        return jax.make_mesh(shape, axes)
    shape = (2, data, 16) if multi_pod else (data, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0,
                   expert: int = 0):
    """Small mesh for CI (requires xla_force_host_platform_device_count)."""
    shape, axes = (), ()
    if pod:
        shape, axes = (pod,), ("pod",)
    if expert:
        shape, axes = shape + (expert,), axes + (EXPERT_AXIS,)
    shape, axes = shape + (data, model), axes + ("data", "model")
    return jax.make_mesh(shape, axes)
