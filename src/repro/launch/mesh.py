"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis extends data parallelism across the inter-pod links (gradient
sync is the only cross-pod traffic; TP stays inside a pod where ICI is
fastest).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialisation).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CI (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
