"""The paper's evaluation ladder (Fig. 12): Serial, UnOpt, UnOpt+AFE, LC,
LC+AFE, DLBC, DCAFE — each as a program→program scheme, plus a one-call
runner that returns the Fig. 10 dynamic counts and Fig. 11/13 metrics."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

from .afe import apply_afe
from .dlbc import apply_dcafe, apply_dlbc
from .ir import Program
from .kernels_rtp import RTPKernel, build_kernel
from .lc import apply_lc
from .runtime import CostModel, SimResult, run_program, serial_program


def scheme_unopt(p: Program) -> Program:
    return p


def scheme_serial(p: Program) -> Program:
    return serial_program(p)


def scheme_afe(p: Program) -> Program:
    out, _ = apply_afe(p)
    return out


def scheme_lc(p: Program) -> Program:
    return apply_lc(p)


def scheme_lc_afe(p: Program) -> Program:
    out, _ = apply_afe(apply_lc(p))
    return out


def scheme_dlbc(p: Program) -> Program:
    return apply_dlbc(p)


def scheme_dcafe(p: Program) -> Program:
    out, _ = apply_dcafe(p)
    return out


SCHEMES: Dict[str, Callable[[Program], Program]] = {
    "Serial": scheme_serial,
    "UnOpt": scheme_unopt,
    "UnOpt+AFE": scheme_afe,
    "LC": scheme_lc,
    "LC+AFE": scheme_lc_afe,
    "DLBC": scheme_dlbc,
    "DCAFE": scheme_dcafe,
}


@dataclass
class SchemeRun:
    kernel: str
    scheme: str
    workers: int
    time: float
    energy: float
    asyncs: int
    finishes: int
    barriers: int
    ok: bool
    result: dict

    def row(self):
        return dict(kernel=self.kernel, scheme=self.scheme,
                    workers=self.workers, time=round(self.time, 2),
                    energy=round(self.energy, 2), asyncs=self.asyncs,
                    finishes=self.finishes, ok=self.ok)

    def sched_summary(self) -> dict:
        """The run's Fig. 10 counts in the shared ``repro.sched`` counter
        vocabulary (spawns/joins), comparable across the simulator, the
        host pools, and the serving batcher."""
        return dict(spawns=self.asyncs, joins=self.finishes,
                    barriers=self.barriers)


def run_scheme(kernel: RTPKernel, scheme: str, workers: int = 4,
               cost_model: Optional[CostModel] = None,
               max_events: int = 50_000_000) -> SchemeRun:
    prog = SCHEMES[scheme](kernel.program)
    res: SimResult = run_program(
        prog, n_workers=(1 if scheme == "Serial" else workers),
        heap=kernel.fresh_heap(), cost_model=cost_model,
        max_events=max_events,
    )
    got = kernel.extract(res.heap)
    want = {k: v for k, v in kernel.expected().items()
            if k in kernel.result_keys}
    ok = res.ok and got == want
    return SchemeRun(
        kernel=kernel.name, scheme=scheme, workers=workers, time=res.time,
        energy=res.energy, asyncs=res.counters.asyncs,
        finishes=res.counters.finishes, barriers=res.counters.barriers,
        ok=ok, result=got,
    )
