"""Loop Chunking (LC) — the Nandivada et al. 2013 baseline (paper Fig. 1(b), Fig. 7(b)).

Splits each parallel loop ``finish { for (i) async [clocked] B }`` into
``nChunks = Runtime.retNthreads()`` chunks of serial iterations, each chunk
executed by one spawned task.  For clocked bodies (``B = S0; advanceAll;
S1; ...``) each phase is chunked inside the async with the barriers kept
between phases (Fig. 7(b)).

This is the comparison target the paper requires ("the base X10 compiler
extended with loop-chunking of Nandivada et al."), implemented here so the
evaluation ladder UnOpt / LC / LC+AFE / DLBC / DCAFE is complete.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional

from .analysis import Summaries, loop_carried_dependence
from .ir import (
    Assign, Async, Barrier, Call, Finish, ForLoop, If, MethodDef, Program,
    Seq, Skip, Stmt, binop, children, const, expr, fresh, n_threads, rebuild,
    seq, var, walk,
)
from ..sched.policy import static_chunk_size


# ---------------------------------------------------------------------------
# Pattern matching
# ---------------------------------------------------------------------------


@dataclass
class ParallelLoop:
    loop: ForLoop
    async_: Async
    phases: List[Stmt]  # async body split on top-level barriers
    clocked: bool


def _single(s: Stmt) -> Stmt:
    while isinstance(s, Seq) and len(s.stmts) == 1:
        s = s.stmts[0]
    return s


def split_phases(body: Stmt) -> List[Stmt]:
    """Split an async body on its top-level ``Clock.advanceAll()`` calls."""
    if isinstance(body, Seq):
        phases: List[List[Stmt]] = [[]]
        for st in body.stmts:
            if isinstance(st, Barrier):
                phases.append([])
            else:
                phases[-1].append(st)
        return [seq(*p) for p in phases]
    if isinstance(body, Barrier):
        return [Skip(), Skip()]
    return [body]


def match_parallel_loop(s: Stmt) -> Optional[ParallelLoop]:
    """Match ``for (i=lo; i<hi; i+=1) { async [clocked] B }``."""
    if not isinstance(s, ForLoop):
        return None
    body = _single(s.body)
    if not isinstance(body, Async):
        return None
    # Only unit-step loops are chunked (all the paper's kernels).
    try:
        if s.step.fn(None) != 1:  # step must be the constant 1
            return None
    except Exception:
        return None
    phases = split_phases(body.body)
    return ParallelLoop(loop=s, async_=body, phases=phases,
                        clocked=bool(body.clocks))


def chunkable(pl: ParallelLoop, summaries: Summaries,
              private: frozenset = frozenset()) -> bool:
    """Is the loop safe to chunk?

    Serializing parallel iterations is always a legal schedule restriction
    in the async-finish model (no futures/conditions in the IR; clocked
    bodies are phase-split so a chunk never blocks on a sibling iteration).
    The only hard requirement is that spawned tasks must not modify the
    loop bounds or the induction variable.
    """
    from .analysis import bound_locals, drop_private

    eff = summaries.stmt_escaping_effects(pl.async_)
    priv = (private | bound_locals(pl.async_.body)
            | frozenset({pl.loop.loopvar}))
    eff_writes = drop_private(eff.writes, priv)
    bound_reads = drop_private(
        pl.loop.lo.reads | pl.loop.hi.reads | pl.loop.step.reads, priv
    )
    from .ir import sets_conflict

    if sets_conflict(eff_writes, bound_reads):
        return False
    if sets_conflict(eff.writes, frozenset({pl.loop.loopvar})):
        return False
    return True


# ---------------------------------------------------------------------------
# LC codegen (Fig. 1(b) / Fig. 7(b))
# ---------------------------------------------------------------------------


def lc_chunked_loop(pl: ParallelLoop) -> Stmt:
    i = pl.loop.loopvar
    lo, hi = pl.loop.lo, pl.loop.hi
    nchunks = fresh("nChunks")
    csize = fresh("chunkSize")
    ii = fresh("ii")
    ni = fresh("ni")
    kx = fresh("kx")

    def phase_chunk(p: Stmt) -> Stmt:
        return ForLoop(loopvar=i, lo=var(ni), hi=var(kx), step=const(1), body=p)

    inner: List[Stmt] = [
        Assign(target=kx,
               value=binop("min", binop("+", var(ni), var(csize)), hi),
               declare_local=True),
    ]
    for idx, p in enumerate(pl.phases):
        if idx > 0:
            inner.append(Barrier())
        inner.append(phase_chunk(p))

    total = binop("-", hi, lo)
    return seq(
        Assign(target=nchunks, value=n_threads(), declare_local=True),
        Assign(
            target=csize,
            value=expr(
                lambda env, _t=total, _n=nchunks: static_chunk_size(
                    _t.fn(env), env[_n]
                ),
                *(total.reads | frozenset({nchunks})),
                label=f"ceil(({total.label})/{nchunks})",
            ),
            declare_local=True,
        ),
        ForLoop(
            loopvar=ii, lo=lo, hi=hi, step=var(csize),
            body=seq(
                Assign(target=ni, value=var(ii), declare_local=True),
                Async(body=seq(*inner), clocks=pl.async_.clocks),
            ),
        ),
    )


def apply_lc(prog: Program) -> Program:
    """Chunk every parallel loop in every method (whole-program, like the
    paper's implementation in x10c)."""
    from .analysis import bound_locals

    summaries = Summaries.compute(prog)

    def rw_method(m: MethodDef) -> MethodDef:
        private = frozenset(m.params) | bound_locals(m.body)

        def rw(s: Stmt) -> Stmt:
            kids = [rw(c) for c in children(s)]
            s2 = rebuild(s, kids) if kids else s
            pl = match_parallel_loop(s2)
            if pl is not None and chunkable(pl, summaries, private):
                return lc_chunked_loop(pl)
            return s2

        return replace(m, body=rw(m.body))

    return Program(
        methods=tuple(rw_method(m) for m in prog.methods),
        main=prog.main,
    )
