"""Deterministic multi-worker runtime simulator for the async-finish IR.

Models the X10 runtime (XRX) the paper targets:

* a pool of W workers (``X10_NTHREADS``) executing tasks non-preemptively;
* spawned tasks enter a FIFO pool; idle workers take the oldest task;
* an activity blocked at a ``finish`` join releases its worker (XRX
  work-stealing semantics — required for recursive programs to make
  progress at all), configurable via ``CostModel.blocked_worker_helps``;
* ``Runtime.retIdleWorkers()`` reads the scheduler's idle-worker count at
  the current simulated instant *without atomics* — two tasks sampling at
  the same instant may observe the same count, exactly the benign race the
  paper describes (§3.2.1);
* clocks: spawned ``async clocked(c)`` tasks register on ``c``;
  ``Clock.advanceAll()`` blocks until every registered task arrives; task
  termination deregisters.  A task blocking at a finish join is
  auto-deregistered from its clocks (X10 forbids joining while registered —
  ClockUseException — the paper's generated code never does; deregistering
  keeps the simulator deadlock-free, documented in DESIGN.md);
* dynamic counters for task creation (``async``) and termination
  (``finish``) operations — the paper's Fig. 10 metrics — plus a simulated
  makespan and an energy proxy (busy/idle power model + per-op energy, the
  Fig. 13 analogue).

Event ordering is a (time, seq) heap → fully deterministic.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from .errors import ExcValue, SimException, make_me
from .ir import (
    Assign, Async, Barrier, Break, Call, Compute, Continue, Expr, Finish,
    ForLoop, If, MethodDef, NewClock, Program, Seq, Skip, Stmt, Throw,
    TryCatch, While,
)
from ..sched.capacity import SimWorkerCapacity
from ..sched.telemetry import SchedCounters

# ---------------------------------------------------------------------------
# Cost / power model
# ---------------------------------------------------------------------------


@dataclass
class CostModel:
    async_spawn: float = 1.0      # task-creation overhead (the paper's target)
    finish_op: float = 1.0        # join bookkeeping (collect exceptions, dealloc)
    barrier_op: float = 0.5
    dispatch: float = 0.25        # ready task → running on an idle worker
    stmt_overhead: float = 0.02   # interpreted statement (chunk math, checks)
    blocked_worker_helps: bool = True
    power_busy: float = 1.0
    power_idle: float = 0.3
    energy_per_async: float = 0.5
    energy_per_finish: float = 0.5


class Counters(SchedCounters):
    """Fig. 10 counter names over the shared scheduling counters
    (:class:`repro.sched.telemetry.SchedCounters`): ``asyncs`` ≡ spawns,
    ``finishes`` ≡ joins — one vocabulary across the simulator, the host
    pools, and the serving batcher."""

    @property
    def asyncs(self) -> int:
        return self.spawns

    @asyncs.setter
    def asyncs(self, v: int):
        self.spawns = v

    @property
    def finishes(self) -> int:
        return self.joins

    @finishes.setter
    def finishes(self, v: int):
        self.joins = v

    def as_dict(self):
        return dict(asyncs=self.asyncs, finishes=self.finishes,
                    barriers=self.barriers, steps=self.steps, work=self.work)


# ---------------------------------------------------------------------------
# Runtime objects
# ---------------------------------------------------------------------------


class BreakSignal(Exception):
    pass


class ContinueSignal(Exception):
    pass


class FinishFrame:
    __slots__ = ("active", "collected", "waiter", "closed")

    def __init__(self):
        self.active = 0
        self.collected: List[ExcValue] = []
        self.waiter: Optional["Task"] = None
        self.closed = False


class ClockObj:
    _ids = itertools.count()

    def __init__(self):
        self.id = next(ClockObj._ids)
        self.registered: set = set()
        self.arrived: set = set()
        self.phase = 0

    def __repr__(self):  # pragma: no cover
        return f"Clock#{self.id}(reg={len(self.registered)}, arr={len(self.arrived)})"


class Task:
    _ids = itertools.count()

    def __init__(self, gen, ief: Optional[FinishFrame], clocks=()):
        self.id = next(Task._ids)
        self.gen = gen
        self.ief = ief
        self.finish_stack: List[FinishFrame] = []
        self.clocks: List[ClockObj] = list(clocks)
        self.local_time = 0.0
        self.worker: Optional[int] = None
        self.blocked_on: Any = None
        self.done = False

    def current_frame(self) -> Optional[FinishFrame]:
        return self.finish_stack[-1] if self.finish_stack else self.ief


class EnvView:
    """Locals → heap name resolution + scheduler hooks for intrinsics."""

    __slots__ = ("locals", "heap", "sched")

    def __init__(self, locals_: dict, heap: dict, sched: "Scheduler"):
        self.locals = locals_
        self.heap = heap
        self.sched = sched

    def __getitem__(self, name: str):
        if name in self.locals:
            return self.locals[name]
        return self.heap[name]

    def get(self, name: str, default=None):
        if name in self.locals:
            return self.locals[name]
        return self.heap.get(name, default)

    def __contains__(self, name: str):
        return name in self.locals or name in self.heap

    def set(self, name: str, value, declare_local: bool = False):
        if declare_local or name in self.locals:
            self.locals[name] = value
        elif name in self.heap:
            self.heap[name] = value
        else:
            self.locals[name] = value

    def set_heap(self, name: str, value):
        self.heap[name] = value

    # -- intrinsics ---------------------------------------------------------

    def runtime_idle_workers(self) -> int:
        return self.sched.idle_count()

    def runtime_n_threads(self) -> int:
        return self.sched.n_workers

    def rethrow(self, value):
        if value is None:
            return
        if not isinstance(value, ExcValue):
            value = ExcValue(payload=value)
        raise SimException(value)

    def wrap_me(self, *values):
        return make_me(*values)


# ---------------------------------------------------------------------------
# Interpreter (generator-based)
# ---------------------------------------------------------------------------

WORK = "work"
SPAWN = "spawn"
JOIN = "join"
ADVANCE = "advance"
SYNC = "sync"  # zero-duration heap round-trip (orders intrinsic reads)


class Interp:
    def __init__(self, prog: Program, sched: "Scheduler", cm: CostModel):
        self.prog = prog
        self.sched = sched
        self.cm = cm
        self.methods = {m.name: m for m in prog.methods}

    def task_gen(self, body: Stmt, locals_: dict, task_box: list):
        """Top-level generator for a task; task_box[0] is set to the Task."""
        env = EnvView(locals_, self.sched.heap, self.sched)
        yield from self.exec(body, env, task_box)

    # -- statement execution -------------------------------------------------

    def exec(self, s: Stmt, env: EnvView, tb: list):
        cm = self.cm
        sched = self.sched
        if isinstance(s, Skip):
            return
        sched.counters.steps += 1
        if isinstance(s, Seq):
            for st in s.stmts:
                yield from self.exec(st, env, tb)
            return
        if isinstance(s, Assign):
            if s.value.intrinsic:
                yield (SYNC,)  # order intrinsic reads in global time
            env.set(s.target, s.value.fn(env), declare_local=s.declare_local)
            c = s.cost + cm.stmt_overhead
            if c > 0:
                yield (WORK, c)
            return
        if isinstance(s, Compute):
            cost = s.cost.fn(env) if isinstance(s.cost, Expr) else s.cost
            s.fn(env)
            yield (WORK, float(cost) + cm.stmt_overhead)
            return
        if isinstance(s, Async):
            clock_objs = []
            for cname in s.clocks:
                c = env[cname]
                assert isinstance(c, ClockObj), f"{cname} is not a clock"
                clock_objs.append(c)
            child_locals = dict(env.locals)  # X10 val-capture snapshot
            yield (WORK, cm.async_spawn)
            yield (SPAWN, (s.body, child_locals, clock_objs))
            return
        if isinstance(s, Finish):
            assert not s.exlist, "pending exlist must be lowered before execution"
            task: Task = tb[0]
            frame = FinishFrame()
            task.finish_stack.append(frame)
            sync_exc: Optional[ExcValue] = None
            try:
                yield from self.exec(s.body, env, tb)
            except SimException as ex:
                sync_exc = ex.value
            finally:
                task.finish_stack.pop()
            frame.closed = True
            yield (JOIN, frame)
            yield (WORK, cm.finish_op)
            sched.counters.finishes += 1
            excs = ([sync_exc] if sync_exc is not None else []) + frame.collected
            if excs:
                raise SimException(make_me(*excs))
            return
        if isinstance(s, ForLoop):
            v = s.loopvar
            env.set(v, s.lo.fn(env), declare_local=True)
            while True:
                hi = s.hi.fn(env)
                if not (env[v] < hi):
                    break
                try:
                    yield from self.exec(s.body, env, tb)
                except BreakSignal:
                    break
                except ContinueSignal:
                    pass
                env.set(v, env[v] + s.step.fn(env))
            return
        if isinstance(s, While):
            while True:
                if s.cond.intrinsic:
                    yield (SYNC,)
                if not s.cond.fn(env):
                    break
                try:
                    yield from self.exec(s.body, env, tb)
                except BreakSignal:
                    break
                except ContinueSignal:
                    continue
            return
        if isinstance(s, Break):
            raise BreakSignal()
        if isinstance(s, Continue):
            raise ContinueSignal()
        if isinstance(s, If):
            if s.cond.intrinsic:
                yield (SYNC,)
            if s.cond.fn(env):
                yield from self.exec(s.then, env, tb)
            else:
                yield from self.exec(s.els, env, tb)
            return
        if isinstance(s, Call):
            m = self.methods[s.callee]
            argvals = [a.fn(env) for a in s.args]
            call_env = EnvView(dict(zip(m.params, argvals)), env.heap, self.sched)
            yield (WORK, cm.stmt_overhead)
            yield from self.exec(m.body, call_env, tb)
            return
        if isinstance(s, NewClock):
            c = ClockObj()
            task: Task = tb[0]
            c.registered.add(task)
            task.clocks.append(c)
            env.set(s.target, c, declare_local=True)
            return
        if isinstance(s, Barrier):
            yield (ADVANCE,)
            yield (WORK, cm.barrier_op)
            self.sched.counters.barriers += 1
            return
        if isinstance(s, Throw):
            raise SimException(ExcValue(type_name=s.exc_type, payload=s.payload.fn(env)))
        if isinstance(s, TryCatch):
            try:
                yield from self.exec(s.body, env, tb)
            except SimException as ex:
                if ex.value.matches(s.exc_types):
                    env.set(s.exc_var, ex.value, declare_local=True)
                    yield from self.exec(s.handler, env, tb)
                else:
                    raise
            return
        raise TypeError(f"unknown statement {s!r}")


# ---------------------------------------------------------------------------
# Scheduler (discrete-event, deterministic)
# ---------------------------------------------------------------------------


@dataclass
class SimResult:
    time: float
    counters: Counters
    energy: float
    heap: dict
    error: Optional[ExcValue] = None
    worker_busy: tuple = ()

    @property
    def ok(self) -> bool:
        return self.error is None


class Scheduler:
    def __init__(self, prog: Program, n_workers: int, cm: Optional[CostModel] = None,
                 heap: Optional[dict] = None, max_events: int = 50_000_000):
        self.prog = prog
        self.n_workers = n_workers
        self.cm = cm or CostModel()
        self.heap: dict = dict(heap or {})
        self.counters = Counters()
        self.interp = Interp(prog, self, self.cm)
        self.events: list = []  # (time, seq, task)
        self._seq = itertools.count()
        self.idle: set = set(range(n_workers))
        self.capacity = SimWorkerCapacity(self)  # repro.sched view of idleness
        self.pending: List[Task] = []  # FIFO task pool
        self.busy_time = [0.0] * n_workers
        self.now = 0.0
        self.max_events = max_events
        self.root_frame = FinishFrame()
        self.root_error: Optional[ExcValue] = None

    # -- queries --------------------------------------------------------------

    def idle_count(self) -> int:
        # ``Runtime.retIdleWorkers()`` — routed through the shared
        # CapacityProvider so the simulator reads idleness the same way
        # the host pools and the batcher do (benign race preserved).
        return self.capacity.idle()

    # -- scheduling primitives --------------------------------------------------

    def _push(self, t: float, task: Task):
        heapq.heappush(self.events, (t, next(self._seq), task))

    def _make_task(self, body: Stmt, locals_: dict, clocks, ief: Optional[FinishFrame]) -> Task:
        tb: list = [None]
        gen = self.interp.task_gen(body, locals_, tb)
        task = Task(gen, ief, clocks)
        tb[0] = task
        for c in task.clocks:
            c.registered.add(task)
        if ief is not None:
            ief.active += 1
        return task

    def _enqueue_ready(self, task: Task, t: float):
        """Task is runnable; give it a worker or pool it."""
        if self.idle:
            w = min(self.idle)
            self.idle.discard(w)
            task.worker = w
            self._push(t + self.cm.dispatch, task)
        else:
            self.pending.append(task)

    def _release_worker(self, w: int, t: float):
        if self.pending:
            task = self.pending.pop(0)
            task.worker = w
            self._push(t + self.cm.dispatch, task)
        else:
            self.idle.add(w)

    # -- clock machinery ---------------------------------------------------------

    def _clock_try_release(self, c: ClockObj, t: float):
        if c.registered and c.arrived >= c.registered:
            c.phase += 1
            waiters = list(c.arrived)
            c.arrived = set()
            for task in waiters:
                if task.blocked_on == ("clock",) and all(
                    (cc.phase > task._wait_phase[cc.id]) for cc in task.clocks
                ):
                    task.blocked_on = None
                    self._enqueue_ready_resume(task, t)

    def _enqueue_ready_resume(self, task: Task, t: float):
        if task.worker is not None:
            # Worker was held (blocked_worker_helps=False path).
            self._push(t, task)
        else:
            self._enqueue_ready(task, t)

    def _deregister_clocks(self, task: Task, t: float):
        for c in task.clocks:
            c.registered.discard(task)
            c.arrived.discard(task)
            self._clock_try_release(c, t)
        task.clocks = []

    # -- task lifecycle ------------------------------------------------------------

    def _finish_task(self, task: Task, t: float, exc: Optional[ExcValue]):
        task.done = True
        self._deregister_clocks(task, t)
        frame = task.ief
        if exc is not None:
            if frame is not None:
                frame.collected.append(exc)
            else:
                self.root_error = exc
        if frame is not None:
            frame.active -= 1
            if frame.active == 0 and frame.waiter is not None:
                waiter = frame.waiter
                frame.waiter = None
                waiter.blocked_on = None
                self._enqueue_ready_resume(waiter, t)
        if task.worker is not None:
            w = task.worker
            task.worker = None
            self._release_worker(w, t)

    def _block_task(self, task: Task, t: float):
        """Release worker per help-first policy."""
        if self.cm.blocked_worker_helps and task.worker is not None:
            w = task.worker
            task.worker = None
            self._release_worker(w, t)

    # -- main loop -------------------------------------------------------------------

    def run(self, main_args: tuple = ()) -> SimResult:
        main = self.prog.method(self.prog.main)
        locals_ = dict(zip(main.params, main_args))
        root = self._make_task(self.prog.method(self.prog.main).body, locals_, (), self.root_frame)
        self.root_frame.active = 1
        self._enqueue_ready(root, 0.0)

        events_processed = 0
        while self.events:
            events_processed += 1
            if events_processed > self.max_events:
                raise RuntimeError("simulation exceeded max_events")
            t, _, task = heapq.heappop(self.events)
            self.now = max(self.now, t)
            if task.done:
                continue
            self._step_task(task, t)

        err = self.root_error
        if self.root_frame.collected:
            err = make_me(*self.root_frame.collected)
        if err is None and (self.root_frame.active > 0 or self.pending):
            err = ExcValue(type_name="DeadlockError",
                           payload=f"{self.root_frame.active} tasks blocked")
        makespan = self.now
        cm = self.cm
        energy = sum(
            b * cm.power_busy + (makespan - b) * cm.power_idle
            for b in self.busy_time
        )
        energy += (
            self.counters.asyncs * cm.energy_per_async
            + self.counters.finishes * cm.energy_per_finish
        )
        return SimResult(
            time=makespan,
            counters=self.counters,
            energy=energy,
            heap=self.heap,
            error=err,
            worker_busy=tuple(self.busy_time),
        )

    def _step_task(self, task: Task, t: float):
        """Drive the task's generator until it blocks, sleeps, or terminates."""
        gen = task.gen
        send_val = None
        while True:
            try:
                ev = gen.send(send_val)
            except StopIteration:
                self._finish_task(task, t, None)
                return
            except SimException as ex:
                self._finish_task(task, t, ex.value)
                return
            send_val = None
            kind = ev[0]
            if kind == WORK:
                c = ev[1]
                if c <= 0:
                    continue
                if task.worker is not None:
                    self.busy_time[task.worker] += c
                self.counters.work += c
                self._push(t + c, task)
                return
            if kind == SYNC:
                self._push(t, task)
                return
            if kind == SPAWN:
                body, child_locals, clock_objs = ev[1]
                ief = task.current_frame()
                child = self._make_task(body, child_locals, clock_objs, ief)
                self.counters.asyncs += 1
                self._enqueue_ready(child, t)
                continue
            if kind == JOIN:
                frame: FinishFrame = ev[1]
                if frame.active == 0:
                    continue
                frame.waiter = task
                task.blocked_on = ("join", frame)
                # X10 forbids blocking at a finish while registered on a
                # clock (ClockUseException); deregistering here keeps the
                # spawned clocked tasks' barriers live (see module docstring).
                self._deregister_clocks(task, t)
                self._block_task(task, t)
                return
            if kind == ADVANCE:
                if not task.clocks:
                    continue
                task._wait_phase = {c.id: c.phase for c in task.clocks}
                task.blocked_on = ("clock",)
                for c in task.clocks:
                    c.arrived.add(task)
                # Release the worker first so a released sibling (or this
                # task itself, re-enqueued by _clock_try_release) can use it.
                self._block_task(task, t)
                for c in task.clocks:
                    self._clock_try_release(c, t)
                return
            raise TypeError(f"unknown event {ev!r}")


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def run_program(
    prog: Program,
    n_workers: int = 4,
    heap: Optional[dict] = None,
    cost_model: Optional[CostModel] = None,
    main_args: tuple = (),
    max_events: int = 50_000_000,
) -> SimResult:
    from .ir import lower_program_pending

    prog = lower_program_pending(prog)
    sched = Scheduler(prog, n_workers, cost_model, heap, max_events)
    return sched.run(main_args)


def serial_elide(s: Stmt) -> Stmt:
    """Sequential elision: async → body, finish → body, barrier → skip.

    Valid for kernels whose clocked loops are phase-separable (all our
    clocked kernels run whole parallel loops between barriers); the Fig. 12
    'Serial' baseline.
    """
    from .ir import children, rebuild, seq as seq_

    kids = [serial_elide(c) for c in children(s)]
    s2 = rebuild(s, kids) if kids else s
    if isinstance(s2, Async):
        return s2.body
    if isinstance(s2, Finish):
        return s2.body
    if isinstance(s2, Barrier):
        return Skip()
    return s2


def serial_program(prog: Program) -> Program:
    from dataclasses import replace as _replace

    return Program(
        methods=tuple(_replace(m, body=serial_elide(m.body)) for m in prog.methods),
        main=prog.main,
    )
