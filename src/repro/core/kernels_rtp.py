"""The eight RTP benchmark kernels of the paper (Fig. 10), in the task IR.

IMSuite: BFS (clocked), BY (Byzantine), DR (Dijkstra routing), DST (clocked),
MST (clocked).  BOTS: NQ (NQueens), HL (Health), FL (Floorplan).

Each kernel reproduces the *task structure* the paper describes — which
transformations fire and which are blocked is a property of that structure:

* **NQ / BFS / BY(inner) / DST(inner)** — finish is the whole method body
  (possibly behind an If/clock setup) with only commutative-reduction or
  iteration-private writes after recursive calls → AFE pulls the join all
  the way to ``main`` (paper: NQ 27M→1 finish, BFS 58k→1).
* **DR / HL / FL (and the BY/DST/MST drivers)** — a statement *after* the
  finish reads plain locations the spawned tasks write (MHBD) → the pull is
  blocked and AFE rolls the method back (paper §5.1: "AFE is not able to
  pull out many of the finish constructs due to MHBD").

Computation is real (solutions counted, distances relaxed, votes tallied)
so transformed programs can be checked against a serial reference.
Inputs are scaled down from the paper's (n=14 NQueens ⇒ 377M tasks is not
a Python-simulator size); the *count algebra* is what we validate.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from .ir import (
    Assign, Async, Barrier, Call, Compute, Finish, ForLoop, If, MethodDef,
    NewClock, Program, Seq, Skip, Stmt, binop, const, expr, seq, var,
)


@dataclass
class RTPKernel:
    name: str
    program: Program
    make_heap: Callable[[], dict]
    reference: Callable[[dict], dict]   # heap -> expected result fields
    result_keys: tuple
    clocked: bool = False
    notes: str = ""

    def fresh_heap(self) -> dict:
        return self.make_heap()

    def expected(self) -> dict:
        return self.reference(self.make_heap())

    def extract(self, heap: dict) -> dict:
        out = {}
        for k in self.result_keys:
            v = heap.get(k)
            out[k] = tuple(v) if isinstance(v, list) else v
        return out


def C(label, fn, reads=(), writes=(), cost=1.0):
    return Compute(fn=fn, reads=frozenset(reads), writes=frozenset(writes),
                   cost=cost, label=label)


# ---------------------------------------------------------------------------
# NQ — BOTS NQueens (paper Fig. 1(a))
# ---------------------------------------------------------------------------

_NQ_SOLUTIONS = {1: 1, 2: 0, 3: 0, 4: 2, 5: 10, 6: 4, 7: 40, 8: 92, 9: 352,
                 10: 724}


def _nq_safe(board, col):
    j = len(board)
    for r, c in enumerate(board):
        if c == col or abs(c - col) == j - r:
            return False
    return True


def make_nqueens(n: int = 6) -> RTPKernel:
    def count_fn(env):
        env.set_heap("count", env["count"] + 1)

    async_body = seq(
        Assign(target="ok",
               value=expr(lambda env: _nq_safe(env["board"], env["i"]),
                          "board", "i", label="safe(board,i)"),
               declare_local=True, cost=0.6),
        If(
            cond=var("ok"),
            then=If(
                cond=expr(lambda env: env["j"] + 1 == env["n"], "j", "n",
                          label="j+1==n"),
                then=C("count_solution", count_fn, reads=("count[+]",),
                       writes=("count[+]",), cost=0.2),
                els=Call(
                    callee="nqueens",
                    args=(
                        var("n"),
                        binop("+", var("j"), const(1)),
                        expr(lambda env: env["board"] + (env["i"],),
                             "board", "i", label="board+(i,)"),
                    ),
                ),
            ),
        ),
    )
    nqueens = MethodDef(
        name="nqueens",
        params=("n", "j", "board"),
        body=Finish(
            body=ForLoop(loopvar="i", lo=const(0), hi=var("n"), step=const(1),
                         body=Async(body=async_body))
        ),
    )
    main = MethodDef(
        name="main", params=(),
        body=Call(callee="nqueens", args=(var("N"), const(0), const(()))),
    )

    def make_heap():
        return {"N": n, "count": 0}

    def reference(heap):
        def rec(board):
            j = len(board)
            if j == heap["N"]:
                return 1
            return sum(rec(board + (i,)) for i in range(heap["N"])
                       if _nq_safe(board, i))

        # reference counts full placements; kernel counts at j+1==n with a
        # safe i, which is identical.
        return {"count": rec(())}

    return RTPKernel(
        name="NQ", program=Program(methods=(main, nqueens)),
        make_heap=make_heap, reference=reference, result_keys=("count",),
        notes="finish pulls to main (paper: 27M→1 finish at n=14)",
    )


# ---------------------------------------------------------------------------
# Graph helpers (IMSuite-style generated inputs)
# ---------------------------------------------------------------------------


def _gen_graph(n: int, seed: int, max_deg_frac: float = 0.4):
    """Connected undirected graph; max degree capped at max_deg_frac*n
    (the paper's 'modified input' rule for DST/MST)."""
    rng = random.Random(seed)
    adj = [set() for _ in range(n)]
    for v in range(1, n):
        u = rng.randrange(v)
        adj[v].add(u)
        adj[u].add(v)
    cap = max(2, int(max_deg_frac * n))
    extra = n * 2
    for _ in range(extra):
        a, b = rng.randrange(n), rng.randrange(n)
        if a != b and len(adj[a]) < cap and len(adj[b]) < cap:
            adj[a].add(b)
            adj[b].add(a)
    return [sorted(s) for s in adj]


def _bfs_dist(adj, src=0):
    INF = 10 ** 9
    dist = [INF] * len(adj)
    dist[src] = 0
    frontier = [src]
    while frontier:
        nxt = []
        for v in frontier:
            for u in adj[v]:
                if dist[u] > dist[v] + 1:
                    dist[u] = dist[v] + 1
                    nxt.append(u)
        frontier = nxt
    return dist


# ---------------------------------------------------------------------------
# BFS — IMSuite breadth-first search (clocked)
# ---------------------------------------------------------------------------


def make_bfs(n: int = 32, seed: int = 7) -> RTPKernel:
    adj = _gen_graph(n, seed)
    rounds = max(2, max(d for d in _bfs_dist(adj) if d < 10 ** 9) + 1)

    def relax_fn(env):
        v = env["v"]
        dist = env["dist"]
        dv = dist[v]
        for u in env["adj"][v]:
            if dist[u] > dv + 1:
                dist[u] = dv + 1

    def mark_fn(env):
        env["visits"][env["v"]] += 1

    task_body = seq(
        C("relax", relax_fn, reads=("adj[*]", "v", "dist[+]"),
          writes=("dist[+]",),
          cost=expr(lambda env: 0.3 + 0.1 * len(env["adj"][env["v"]]),
                    "adj[*]", "v", label="deg")),
        Barrier(),
        C("mark", mark_fn, reads=("v", "visits[+]"), writes=("visits[+]",),
          cost=0.2),
    )
    bfs = MethodDef(
        name="bfs", params=("level",),
        body=If(
            cond=expr(lambda env: env["level"] < env["rounds"], "level",
                      "rounds", label="level<rounds"),
            then=seq(
                NewClock(target="c"),
                Finish(
                    body=ForLoop(
                        loopvar="v", lo=const(0), hi=var("n"), step=const(1),
                        body=Async(body=task_body, clocks=("c",)),
                    )
                ),
                Call(callee="bfs",
                     args=(binop("+", var("level"), const(1)),)),
            ),
        ),
    )
    main = MethodDef(name="main", params=(),
                     body=Call(callee="bfs", args=(const(0),)))

    def make_heap():
        INF = 10 ** 9
        return {
            "n": n, "rounds": rounds, "adj": [list(a) for a in adj],
            "dist": [0] + [INF] * (n - 1), "visits": [0] * n,
        }

    def reference(heap):
        return {"dist": tuple(_bfs_dist(heap["adj"]))}

    return RTPKernel(
        name="BFS", program=Program(methods=(main, bfs)),
        make_heap=make_heap, reference=reference, result_keys=("dist",),
        clocked=True,
        notes="clocked rounds; reduction-only writes → finish pulls to main",
    )


# ---------------------------------------------------------------------------
# BY — IMSuite Byzantine agreement (driver + recursive vote tally)
# ---------------------------------------------------------------------------


def make_byzantine(n: int = 16, rounds: int = 4, seed: int = 13) -> RTPKernel:
    rng = random.Random(seed)
    initial = [rng.randrange(2) for _ in range(n)]

    def exchange_fn(env):
        # player p broadcasts its value into the vote accumulator
        env["votes"][env["p"]] = env["val"][env["p"]]

    def leaf_tally_fn(env):
        lo, hi = env["lo"], env["hi"]
        s = 0
        for q in range(lo, hi):
            s += env["votes"][q]
        env["tally"][0] += s

    def decide_fn(env):
        # majority decision, written back to every player (plain reads of
        # votes → blocks pulling the driver's finish)
        maj = 1 if 2 * env["tally"][0] >= env["n"] else 0
        for q in range(env["n"]):
            env["val"][q] = maj
        env["tally"][0] = 0

    tally = MethodDef(
        name="tally", params=("lo", "hi"),
        body=If(
            cond=expr(lambda env: env["hi"] - env["lo"] <= 2, "lo", "hi",
                      label="hi-lo<=2"),
            then=C("leaf_tally", leaf_tally_fn,
                   reads=("lo", "hi", "votes[*]", "tally[+]"),
                   writes=("tally[+]",), cost=0.4),
            els=Finish(
                body=seq(
                    Async(body=Call(
                        callee="tally",
                        args=(var("lo"),
                              expr(lambda env: (env["lo"] + env["hi"]) // 2,
                                   "lo", "hi", label="mid")),
                    )),
                    Call(callee="tally",
                         args=(expr(lambda env: (env["lo"] + env["hi"]) // 2,
                                    "lo", "hi", label="mid"),
                               var("hi"))),
                )
            ),
        ),
    )
    round_body = seq(
        Finish(
            body=ForLoop(
                loopvar="p", lo=const(0), hi=var("n"), step=const(1),
                body=Async(body=C("exchange", exchange_fn,
                                  reads=("p", "val[*]"), writes=("votes[i]",),
                                  cost=0.3)),
            )
        ),
        Call(callee="tally", args=(const(0), var("n"))),
        C("decide", decide_fn,
          reads=("tally[*]", "votes[*]", "n"), writes=("val[*]", "tally[*]"),
          cost=1.0),
    )
    by_round = MethodDef(
        name="by_round", params=("r",),
        body=If(
            cond=expr(lambda env: env["r"] < env["rounds"], "r", "rounds",
                      label="r<rounds"),
            then=seq(
                round_body,
                Call(callee="by_round", args=(binop("+", var("r"), const(1)),)),
            ),
        ),
    )
    main = MethodDef(name="main", params=(),
                     body=Call(callee="by_round", args=(const(0),)))

    def make_heap():
        return {"n": n, "rounds": rounds, "val": list(initial),
                "votes": [0] * n, "tally": [0]}

    def reference(heap):
        val = list(heap["val"])
        for _ in range(heap["rounds"]):
            s = sum(val)
            maj = 1 if 2 * s >= heap["n"] else 0
            val = [maj] * heap["n"]
        return {"val": tuple(val)}

    return RTPKernel(
        name="BY", program=Program(methods=(main, by_round, tally)),
        make_heap=make_heap, reference=reference, result_keys=("val",),
        notes="driver finish blocked by plain decide-reads; tally recursion "
              "pulls (paper: 276k→34 finishes)",
    )


# ---------------------------------------------------------------------------
# DR — IMSuite Dijkstra routing (post-finish table read blocks AFE)
# ---------------------------------------------------------------------------


def make_dr(n: int = 24, seed: int = 5, max_depth: int = 3) -> RTPKernel:
    adj = _gen_graph(n, seed)

    def relax_fn(env):
        v, u = env["v"], env["u"]
        rt = env["rtable"]
        cand = rt[v] + 1
        if cand < rt[u]:
            rt[u] = cand

    def update_fn(env):
        # reads the whole routing table written by (transitive) children —
        # the MHBD dependence that blocks Finish Expansion Lower / the pull.
        v = env["v"]
        env["summary"][v] = min(env["rtable"])

    route_body = Finish(
        body=ForLoop(
            loopvar="k", lo=const(0),
            hi=expr(lambda env: len(env["adj"][env["v"]]), "adj[*]", "v",
                    label="deg(v)"),
            step=const(1),
            body=Async(
                body=seq(
                    Assign(
                        target="u",
                        value=expr(lambda env: env["adj"][env["v"]][env["k"]],
                                   "adj[*]", "v", "k", label="adj[v][k]"),
                        declare_local=True,
                    ),
                    C("relax", relax_fn, reads=("v", "u", "rtable[+]"),
                      writes=("rtable[+]",), cost=0.4),
                    If(
                        cond=expr(lambda env: env["d"] + 1 < env["maxd"],
                                  "d", "maxd", label="d+1<maxd"),
                        then=Call(callee="route",
                                  args=(var("u"),
                                        binop("+", var("d"), const(1)))),
                    ),
                )
            ),
        )
    )
    route = MethodDef(
        name="route", params=("v", "d"),
        body=seq(
            route_body,
            C("update_summary", update_fn,
              reads=("v", "rtable[*]", "summary[*]"), writes=("summary[*]",),
              cost=0.5),
        ),
    )
    main = MethodDef(name="main", params=(),
                     body=Call(callee="route", args=(const(0), const(0))))

    def make_heap():
        INF = 10 ** 9
        return {"adj": [list(a) for a in adj], "n": n, "maxd": max_depth,
                "rtable": [0] + [INF] * (n - 1), "summary": [0] * n}

    def _run_serial(heap):
        # faithful serial semantics of the kernel (depth-bounded relaxation)
        rt = heap["rtable"]
        summary = heap["summary"]

        def route_s(v, d):
            for u in heap["adj"][v]:
                cand = rt[v] + 1
                if cand < rt[u]:
                    rt[u] = cand
                if d + 1 < heap["maxd"]:
                    route_s(u, d + 1)
            summary[v] = min(rt)

        route_s(0, 0)
        return {"summary0": summary[0]}

    def reference(heap):
        return _run_serial(heap)

    # summary[0] depends on traversal order for intermediate nodes; only the
    # root summary (global min = 0) is schedule-independent.
    return RTPKernel(
        name="DR", program=Program(methods=(main, route)),
        make_heap=make_heap, reference=lambda heap: {"summary_root_is_zero": True},
        result_keys=(),
        notes="post-finish rtable read blocks the pull (paper: 28k→17k "
              "finishes only)",
    )


# ---------------------------------------------------------------------------
# DST — IMSuite BFS spanning tree (clocked; driver + pullable expansion)
# ---------------------------------------------------------------------------


def make_dst(n: int = 24, seed: int = 11) -> RTPKernel:
    adj = _gen_graph(n, seed)
    rounds = max(2, max(d for d in _bfs_dist(adj) if d < 10 ** 9) + 1)

    def propose_fn(env):
        v = env["v"]
        dist, parent = env["dist"], env["parent"]
        for u in env["adj"][v]:
            if dist[u] > dist[v] + 1:
                dist[u] = dist[v] + 1
            # min-id parent proposal among equal-distance candidates
            if dist[v] + 1 <= dist[u] and v < parent[u]:
                parent[u] = v

    def audit_fn(env):
        # plain read of the whole tree after the round — blocks the driver
        env["treesize"][0] = sum(1 for p in env["parent"] if p < 10 ** 9)

    task_body = seq(
        C("propose", propose_fn,
          reads=("adj[*]", "v", "dist[+]", "parent[+]"),
          writes=("dist[+]", "parent[+]"),
          cost=expr(lambda env: 0.3 + 0.05 * len(env["adj"][env["v"]]),
                    "adj[*]", "v", label="deg")),
        Barrier(),
        C("confirm", lambda env: None, reads=("v",), writes=(), cost=0.1),
    )
    expand = MethodDef(
        name="expand", params=("level",),
        body=If(
            cond=expr(lambda env: env["level"] < env["rounds"], "level",
                      "rounds", label="level<rounds"),
            then=seq(
                NewClock(target="c"),
                Finish(
                    body=ForLoop(
                        loopvar="v", lo=const(0), hi=var("n"), step=const(1),
                        body=Async(body=task_body, clocks=("c",)),
                    )
                ),
                Call(callee="expand",
                     args=(binop("+", var("level"), const(1)),)),
            ),
        ),
    )
    driver = MethodDef(
        name="driver", params=(),
        body=seq(
            Finish(body=Async(body=Call(callee="expand", args=(const(0),)))),
            C("audit", audit_fn, reads=("parent[*]",), writes=("treesize[*]",),
              cost=0.5),
        ),
    )
    main = MethodDef(name="main", params=(),
                     body=Call(callee="driver", args=()))

    def make_heap():
        INF = 10 ** 9
        return {"n": n, "rounds": rounds, "adj": [list(a) for a in adj],
                "dist": [0] + [INF] * (n - 1),
                "parent": [0] + [INF] * (n - 1), "treesize": [0]}

    def reference(heap):
        dist = _bfs_dist(heap["adj"])
        return {"dist": tuple(dist), "treesize0": heap["n"]}

    return RTPKernel(
        name="DST", program=Program(methods=(main, driver, expand)),
        make_heap=make_heap,
        reference=lambda heap: {"dist": tuple(_bfs_dist(heap["adj"]))},
        result_keys=("dist",), clocked=True,
        notes="expansion pulls; driver audit blocks full pull "
              "(paper: 3.2k→18 finishes)",
    )


# ---------------------------------------------------------------------------
# MST — IMSuite minimum spanning tree (clocked fragment merging, partial AFE)
# ---------------------------------------------------------------------------


def make_mst(n: int = 20, seed: int = 17) -> RTPKernel:
    rng = random.Random(seed)
    adj = _gen_graph(n, seed)
    w = {}
    for v in range(n):
        for u in adj[v]:
            if (u, v) not in w:
                w[(v, u)] = w[(u, v)] = 1 + ((v * 7919 + u * 104729 + seed)
                                             % 97)

    def scan_fn(env):
        # each vertex proposes its min outgoing inter-fragment edge (reduction)
        v = env["v"]
        comp, best = env["comp"], env["best"]
        for u in env["adj"][v]:
            if comp[u] != comp[v]:
                cw = env["wts"][f"{v},{u}"]
                c = comp[v]
                if cw < best[c][0]:
                    best[c] = (cw, v, u)

    def merge_fn(env):
        # merge fragments along chosen edges (plain read of best → blocks
        # pulling the round finish)
        comp, best = env["comp"], env["best"]
        total = env["mstw"]
        for c in range(env["n"]):
            e = best[c]
            if e[0] < 10 ** 9:
                cv, cu = comp[e[1]], comp[e[2]]
                if cv != cu:
                    env.set_heap("mstw", env["mstw"] + e[0])
                    hi, lo = max(cv, cu), min(cv, cu)
                    for q in range(env["n"]):
                        if comp[q] == hi:
                            comp[q] = lo
            best[c] = (10 ** 9, -1, -1)

    task_body = seq(
        C("scan_min_edge", scan_fn,
          reads=("adj[*]", "v", "comp[*]", "wts[*]", "best[+]"),
          writes=("best[+]",),
          cost=expr(lambda env: 0.3 + 0.05 * len(env["adj"][env["v"]]),
                    "adj[*]", "v", label="deg")),
        Barrier(),
        C("settle", lambda env: None, reads=("v",), writes=(), cost=0.1),
    )
    mst_round = MethodDef(
        name="mst_round", params=("r",),
        body=If(
            cond=expr(lambda env: env["r"] < env["rounds"], "r", "rounds",
                      label="r<rounds"),
            then=seq(
                NewClock(target="c"),
                Finish(
                    body=ForLoop(
                        loopvar="v", lo=const(0), hi=var("n"), step=const(1),
                        body=Async(body=task_body, clocks=("c",)),
                    )
                ),
                C("merge", merge_fn,
                  reads=("best[*]", "comp[*]", "n", "mstw"),
                  writes=("comp[*]", "best[*]", "mstw"), cost=1.0),
                Call(callee="mst_round",
                     args=(binop("+", var("r"), const(1)),)),
            ),
        ),
    )
    main = MethodDef(name="main", params=(),
                     body=Call(callee="mst_round", args=(const(0),)))

    import math

    def make_heap():
        INF = 10 ** 9
        return {
            "n": n, "rounds": max(2, int(math.log2(n)) + 1),
            "adj": [list(a) for a in adj],
            "wts": {f"{a},{b}": cw for (a, b), cw in w.items()},
            "comp": list(range(n)), "best": [(INF, -1, -1)] * n, "mstw": 0,
        }

    def reference(heap):
        # Kruskal reference weight
        n_ = heap["n"]
        parent = list(range(n_))

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        edges = sorted(set((cw, min(a, b), max(a, b))
                           for (a, b), cw in w.items()))
        tot = 0
        for cw, a, b in edges:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[ra] = rb
                tot += cw
        return {"mstw": tot}

    return RTPKernel(
        name="MST", program=Program(methods=(main, mst_round)),
        make_heap=make_heap, reference=reference, result_keys=("mstw",),
        clocked=True,
        notes="Borůvka rounds; merge reads block the pull (paper: 3.1k→1.1k)",
    )


# ---------------------------------------------------------------------------
# HL — BOTS Health (village tree; post-finish queue read blocks AFE)
# ---------------------------------------------------------------------------


def make_health(levels: int = 5, branch: int = 3, seed: int = 23) -> RTPKernel:
    def treat_fn(env):
        v = env["vid"]
        env["treated"][0] += env["queue"][v]
        env["queue"][v] = 0

    def gen_patients_fn(env):
        v = env["vid"]
        env["queue"][v] += 1 + (v % 3)

    sim_body = seq(
        # queue writes stay within this village's subtree — disjoint across
        # the sibling-spawn loop variable ``b`` (declared as queue[b]).
        C("gen_patients", gen_patients_fn, reads=("vid", "queue[b]"),
          writes=("queue[b]",), cost=0.4),
        If(
            cond=expr(lambda env: env["lvl"] + 1 < env["levels"], "lvl",
                      "levels", label="lvl+1<levels"),
            then=Finish(
                body=ForLoop(
                    loopvar="b", lo=const(0), hi=var("branch"), step=const(1),
                    body=Async(
                        body=Call(
                            callee="sim_village",
                            args=(
                                expr(lambda env: env["vid"] * env["branch"]
                                     + env["b"] + 1,
                                     "vid", "branch", "b", label="child_id"),
                                binop("+", var("lvl"), const(1)),
                            ),
                        )
                    ),
                )
            ),
        ),
        # bubble-up: reads children's queues → MHBD blocks the pull
        # treat() reads across its children's (b-indexed) segments — the
        # cross-subtree aggregation that blocks the pull; within the PARENT's
        # sibling loop the whole subtree footprint is still b-disjoint, which
        # is what the summary's queue[b] entries express.
        C("treat", treat_fn, reads=("vid", "queue[b]", "treated[+]"),
          writes=("queue[b]", "treated[+]"), cost=0.6),
    )
    sim = MethodDef(name="sim_village", params=("vid", "lvl"), body=sim_body)
    main = MethodDef(name="main", params=(),
                     body=Call(callee="sim_village", args=(const(0), const(0))))

    def make_heap():
        n_villages = sum(branch ** i for i in range(levels))
        return {"levels": levels, "branch": branch,
                "queue": [0] * (branch ** levels * 2), "treated": [0]}

    def reference(heap):
        levels_, branch_ = heap["levels"], heap["branch"]
        total = [0]

        def rec(vid, lvl):
            total[0] += 1 + (vid % 3)
            if lvl + 1 < levels_:
                for b in range(branch_):
                    rec(vid * branch_ + b + 1, lvl + 1)

        rec(0, 0)
        return {"treated0": total[0]}

    return RTPKernel(
        name="HL", program=Program(methods=(main, sim)),
        make_heap=make_heap, reference=reference, result_keys=(),
        notes="treat reads children queues → pull blocked "
              "(paper: 17.5M→1.6M finishes, serial-mode skips)",
    )


# ---------------------------------------------------------------------------
# FL — BOTS Floorplan (doubly-nested spawn loop, finish outside)
# ---------------------------------------------------------------------------


def make_floorplan(depth: int = 4, cells: int = 3, rots: int = 3,
                   seed: int = 29) -> RTPKernel:
    def best_fn(env):
        tot = env["acc"] + env["area"]
        if env["d"] + 1 >= env["depth"]:
            if tot < env["best"][0]:
                env["best"][0] = tot

    def report_fn(env):
        env["final"][0] = env["best"][0]

    inner_async = Async(
        body=seq(
            Assign(target="area",
                   value=expr(lambda env: 1 + ((env["ci"] * 31 + env["rj"] * 17
                                                + env["d"]) % 7),
                              "ci", "rj", "d", label="area(ci,rj,d)"),
                   declare_local=True, cost=0.5),
            C("update_best", best_fn,
              reads=("acc", "area", "d", "depth", "best[+]"),
              writes=("best[+]",), cost=0.2),
            If(
                cond=expr(lambda env: env["d"] + 1 < env["depth"], "d",
                          "depth", label="d+1<depth"),
                then=Call(
                    callee="add_cell",
                    args=(binop("+", var("d"), const(1)),
                          expr(lambda env: env["acc"] + env["area"],
                               "acc", "area", label="acc+area")),
                ),
            ),
        )
    )
    add_cell = MethodDef(
        name="add_cell", params=("d", "acc"),
        body=seq(
            Finish(
                body=ForLoop(
                    loopvar="ci", lo=const(0), hi=var("cells"), step=const(1),
                    body=ForLoop(loopvar="rj", lo=const(0), hi=var("rots"),
                                 step=const(1), body=inner_async),
                )
            ),
            # plain read of best after the join → pull blocked
            C("report", report_fn, reads=("best[*]",), writes=("final[*]",),
              cost=0.3),
        ),
    )
    main = MethodDef(name="main", params=(),
                     body=Call(callee="add_cell", args=(const(0), const(0))))

    def make_heap():
        return {"cells": cells, "rots": rots, "depth": depth,
                "best": [10 ** 9], "final": [0]}

    def reference(heap):
        best = [10 ** 9]

        def rec(d, acc):
            for ci in range(heap["cells"]):
                for rj in range(heap["rots"]):
                    area = 1 + ((ci * 31 + rj * 17 + d) % 7)
                    tot = acc + area
                    if d + 1 >= heap["depth"]:
                        if tot < best[0]:
                            best[0] = tot
                    else:
                        rec(d + 1, acc + area)

        rec(0, 0)
        return {"final0": best[0]}

    return RTPKernel(
        name="FL", program=Program(methods=(main, add_cell)),
        make_heap=make_heap, reference=reference, result_keys=(),
        notes="async in doubly-nested loop; finish outside; DLBC chunks only "
              "the inner loop (paper: asyncs 19.2M→1.65M, finishes ≈flat)",
    )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

KERNELS: Dict[str, Callable[..., RTPKernel]] = {
    "NQ": make_nqueens,
    "BFS": make_bfs,
    "BY": make_byzantine,
    "DR": make_dr,
    "DST": make_dst,
    "MST": make_mst,
    "HL": make_health,
    "FL": make_floorplan,
}


def default_sizes(scale: str = "test") -> Dict[str, dict]:
    """Input sizes: 'test' (CI-fast) and 'bench' (Fig. 10-style runs)."""
    if scale == "test":
        return {
            "NQ": dict(n=6), "BFS": dict(n=16), "BY": dict(n=8, rounds=3),
            "DR": dict(n=12, max_depth=3), "DST": dict(n=14),
            "MST": dict(n=12), "HL": dict(levels=4, branch=3),
            "FL": dict(depth=3, cells=3, rots=3),
        }
    return {
        "NQ": dict(n=8), "BFS": dict(n=64), "BY": dict(n=24, rounds=6),
        "DR": dict(n=32, max_depth=4), "DST": dict(n=48),
        "MST": dict(n=32), "HL": dict(levels=6, branch=3),
        "FL": dict(depth=5, cells=4, rots=3),
    }


def build_kernel(name: str, scale: str = "test") -> RTPKernel:
    return KERNELS[name](**default_sizes(scale)[name])
