"""Exception value model shared by the IR transforms and the runtime.

X10 semantics (paper §2.1): an exception thrown inside an ``async`` is
caught by its Immediately Enclosing Finish; the finish waits for the
remaining tasks, packages everything thrown as a ``MultipleExceptions``
(here: an :class:`ExcValue` with ``is_me=True``) and rethrows.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Tuple


@dataclass(frozen=True)
class ExcValue:
    """A first-class exception value (storable in IR variables)."""

    type_name: str = "Exception"
    payload: Any = None
    is_me: bool = False
    inner: Tuple["ExcValue", ...] = ()

    def matches(self, catch_types: tuple) -> bool:
        if self.type_name in catch_types:
            return True
        if "Exception" in catch_types:
            return True  # Exception is the root supertype
        if self.is_me and "ME" in catch_types:
            return True
        return False

    def flatten(self) -> Tuple["ExcValue", ...]:
        """All non-ME leaf exceptions inside this value."""
        if not self.is_me:
            return (self,)
        out: tuple = ()
        for e in self.inner:
            out = out + e.flatten()
        return out


def make_me(*excs: ExcValue) -> ExcValue:
    return ExcValue(type_name="ME", is_me=True, inner=tuple(excs))


class SimException(Exception):
    """Python carrier for an :class:`ExcValue` inside the interpreter."""

    def __init__(self, value: ExcValue):
        super().__init__(value.type_name)
        self.value = value
