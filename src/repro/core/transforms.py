"""The DCAFE mini-transformations (paper Figs. 2, 4, 8 and 9).

Each rule is a function ``rule(stmt, ctx) -> Stmt | None`` that matches at a
single node, checks the paper's preconditions, and returns the transformed
node (or ``None`` when it does not apply).  :func:`rewrite_fixpoint` applies
the rule set bottom-up to a fixpoint — the paper notes the rules may be
applied in any order; we use a fixed deterministic order for reproducibility.

Exception handling: when ``ctx.exceptions_possible`` finds a statement that
may throw, the exception-extended variants of Figs. 8/9 are generated
(pending-exception lists carried on ``Finish.exlist``, ME re-wrapping for
tail elimination, try-guards for expansion rules).  When nothing can throw,
the plain Fig. 2/4 forms are produced.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Optional

from .analysis import (
    Summaries, depends_on_easyncs, loop_carried_dependence, stmt_reads,
    stmt_writes,
)
from .errors import ExcValue, make_me
from .ir import (
    Assign, Async, Barrier, Break, Call, Compute, Continue, Expr, Finish,
    ForLoop, If, Seq, Skip, Stmt, Throw, TryCatch, While, children, const,
    expr, fresh, rebuild, seq, var, walk,
)

# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------


@dataclass
class Ctx:
    summaries: Summaries
    assume_no_exceptions: bool = False
    stats: dict = field(default_factory=dict)
    # Names bound method/task-locally in the method under rewrite —
    # by-value captured, so excluded from cross-task dependence checks.
    private: frozenset = frozenset()

    def bump(self, rule: str):
        self.stats[rule] = self.stats.get(rule, 0) + 1

    def may_throw(self, s: Stmt) -> bool:
        if self.assume_no_exceptions:
            return False
        return self.summaries.stmt_may_throw(s)

    def escaping(self, s: Stmt):
        return self.summaries.stmt_escaping_effects(s)


# ---------------------------------------------------------------------------
# Small codegen helpers (exception plumbing)
# ---------------------------------------------------------------------------


def assign_null(v: str) -> Stmt:
    return Assign(target=v, value=const(None), declare_local=True)


def catch_into(body: Stmt, v: str, types: tuple = ("Exception",)) -> Stmt:
    """``try { body } catch(e1:types) { v = e1 }``"""
    e1 = fresh("e")
    return TryCatch(
        body=body,
        exc_var=e1,
        handler=Assign(target=v, value=var(e1)),
        exc_types=types,
    )


def if_null(v: str, then: Stmt, els: Stmt = Skip()) -> Stmt:
    return If(
        cond=expr(lambda env, _v=v: env[_v] is None, v, label=f"{v}==null"),
        then=then,
        els=els,
    )


def throw_var(v: str) -> Stmt:
    return Compute(
        fn=lambda env, _v=v: env.rethrow(env[_v]),
        reads=frozenset({v}),
        writes=frozenset(),
        cost=0.0,
        label=f"throw {v}",
    )


def throw_me_of(v: str) -> Stmt:
    """``throw new ME(v)`` — rewrap an exception value (Fig. 9 #3, Fig. 8 #5)."""
    return Compute(
        fn=lambda env, _v=v: env.rethrow(make_me(env[_v])),
        reads=frozenset({v}),
        writes=frozenset(),
        cost=0.0,
        label=f"throw ME({v})",
    )


def exlist_guard(exlist: tuple, sink: str) -> Stmt:
    """``try { exlist } catch(e1) { sink = e1 }`` with short-circuit.

    Evaluates the pending-exception checks; the first pending exception is
    captured into ``sink`` instead of being thrown.
    """
    checks = []
    for v in exlist:
        checks.append(
            If(
                cond=expr(
                    lambda env, _v=v, _s=sink: env[_v] is not None
                    and env[_s] is None,
                    v,
                    sink,
                    label=f"{v}!=null&&{sink}==null",
                ),
                then=Assign(target=sink, value=var(v)),
            )
        )
    return seq(*checks)


def all_null_cond(names: tuple) -> Expr:
    return expr(
        lambda env, _ns=tuple(names): all(env[n] is None for n in _ns),
        *names,
        label="&&".join(f"{n}==null" for n in names) or "true",
    )


# ---------------------------------------------------------------------------
# Rule 1 (Fig. 2): Loop-Finish Interchange
# ---------------------------------------------------------------------------


def loop_finish_interchange(s: Stmt, ctx: Ctx) -> Optional[Stmt]:
    """``for(...) { finish S3 }  ⇒  finish { for(...) { S3 } }``"""
    if not (isinstance(s, ForLoop) and isinstance(s.body, Finish)):
        return None
    inner: Finish = s.body
    eff = ctx.escaping(inner.body)
    if not eff.escapes:
        return None  # nothing to gain, and Finish Elimination handles it
    # Precondition: loop condition must not depend on e-asyncs; no
    # loop-carried dependence through the e-asyncs.
    if loop_carried_dependence(s, ctx.summaries, ctx.private):
        return None
    from .analysis import drop_private
    from .ir import sets_conflict

    bound_reads = drop_private(s.lo.reads | s.hi.reads | s.step.reads,
                               ctx.private)
    if sets_conflict(drop_private(eff.writes, ctx.private), bound_reads):
        return None
    if not ctx.may_throw(inner.body) and not inner.exlist:
        ctx.bump("loop_finish_interchange")
        return Finish(body=replace(s, body=inner.body))
    # Exception-extended variant (Fig. 9 #1).  Loop bounds here are pure, so
    # only S3/exlist can throw synchronously.
    if eff.may_throw:
        return None  # precondition: e-asyncs do not throw
    me = fresh("me")
    e = fresh("e")
    # Build:  try { S3 } catch(ex) { me = ME(ex); break }  ; exlist-guard→e,break
    ex = fresh("ex")
    loop_body = seq(
        TryCatch(
            body=inner.body,
            exc_var=ex,
            handler=seq(
                Assign(
                    target=me,
                    value=expr(
                        lambda env, _x=ex: make_me(env[_x]), ex, label=f"ME({ex})"
                    ),
                ),
                Break(),
            ),
        ),
        exlist_guard(inner.exlist, e),
        If(
            cond=expr(lambda env, _e=e: env[_e] is not None, e, label=f"{e}!=null"),
            then=Break(),
        ),
    )
    ctx.bump("loop_finish_interchange_exc")
    return seq(
        assign_null(me),
        assign_null(e),
        Finish(body=replace(s, body=loop_body)),
        If(
            cond=expr(lambda env, _e=e: env[_e] is not None, e, label=f"{e}!=null"),
            then=throw_var(e),
        ),
        If(
            cond=expr(lambda env, _m=me: env[_m] is not None, me, label=f"{me}!=null"),
            then=throw_var(me),
        ),
    )


# ---------------------------------------------------------------------------
# Rule 2 (Fig. 2): Finish Fusion — applied to adjacent Seq elements
# ---------------------------------------------------------------------------


def finish_fusion_pair(a: Finish, b: Finish, ctx: Ctx) -> Optional[Stmt]:
    effA = ctx.escaping(a.body)
    if depends_on_easyncs(b.body, effA.reads, effA.writes, ctx.summaries,
                          private=ctx.private):
        return None
    clean = (
        not ctx.may_throw(a.body)
        and not ctx.may_throw(b.body)
        and not a.exlist
        and not effA.may_throw
    )
    if clean:
        ctx.bump("finish_fusion")
        return Finish(body=seq(a.body, b.body), exlist=b.exlist)
    # Exception-extended (Fig. 9 #2): S2 runs only if exlist1 is clean; the
    # pending exceptions of S1 remain pending after the fused finish.
    effB = ctx.escaping(b.body)
    if effA.may_throw or effB.may_throw:
        return None  # precondition: e-asyncs of S1 and S2 do not throw
    guard = If(cond=all_null_cond(a.exlist), then=b.body) if a.exlist else b.body
    ctx.bump("finish_fusion_exc")
    return Finish(body=seq(a.body, guard), exlist=a.exlist + b.exlist)


# ---------------------------------------------------------------------------
# Rule 3 (Fig. 2): Tail Finish Elimination
# ---------------------------------------------------------------------------


def tail_finish_elimination(s: Stmt, ctx: Ctx) -> Optional[Stmt]:
    """``finish { finish S1 }  ⇒  finish S1`` (+ ME rewrap when throwing)."""
    if not isinstance(s, Finish):
        return None
    inner = s.body
    if isinstance(inner, Seq) and len(inner.stmts) == 1:
        inner = inner.stmts[0]
    if not isinstance(inner, Finish):
        return None
    if not ctx.may_throw(inner) and not inner.exlist:
        ctx.bump("tail_finish_elimination")
        return Finish(body=inner.body, exlist=s.exlist)
    # Fig. 9 #3: keep the double ME-wrapping the nested finish produced.
    e = fresh("e")
    from .ir import lower_pending

    inner_lowered = lower_pending(inner)
    ctx.bump("tail_finish_elimination_exc")
    return Finish(
        body=TryCatch(
            body=inner_lowered,
            exc_var=e,
            handler=throw_me_of(e),
            exc_types=("Exception",),
        ),
        exlist=s.exlist,
    )


# ---------------------------------------------------------------------------
# Rule 4 (Fig. 4 #1 / Fig. 8 #1): Finish-If Interchange
# ---------------------------------------------------------------------------


def finish_if_interchange(s: Stmt, ctx: Ctx) -> Optional[Stmt]:
    if not isinstance(s, If):
        return None
    then_f = s.then if isinstance(s.then, Finish) else None
    els_f = s.els if isinstance(s.els, Finish) else None
    if then_f is None and els_f is None:
        return None
    if s.cond.intrinsic:
        return None  # hoisting an intrinsic read changes its sample point

    def branch_ok(branch: Stmt) -> bool:
        """A non-finish branch may be pulled inside the new finish when its
        escaping asyncs are unclocked (early join is a legal strengthening
        in the async-finish model) and it cannot throw (the finish would
        re-wrap the exception as ME)."""
        if isinstance(branch, (Break, Continue)):
            return False
        if ctx.escaping(branch).clocked:
            return False
        if ctx.may_throw(branch):
            return False
        return True

    if then_f is None and not branch_ok(s.then):
        return None
    if els_f is None and not branch_ok(s.els):
        return None
    v = fresh("c")
    new_then = then_f.body if then_f else s.then
    new_els = els_f.body if els_f else s.els
    exlist = (then_f.exlist if then_f else ()) + (els_f.exlist if els_f else ())
    ctx.bump("finish_if_interchange")
    return seq(
        Assign(target=v, value=s.cond, declare_local=True),
        Finish(body=If(cond=var(v), then=new_then, els=new_els), exlist=exlist),
    )


# ---------------------------------------------------------------------------
# Rule 5 (Fig. 4 #2 / Fig. 8 #2): Finish Expansion Upper
# ---------------------------------------------------------------------------


def _bad_stmt_to_absorb(s: Stmt) -> bool:
    return isinstance(s, (Break, Continue))


def finish_expansion_upper(s1: Stmt, f: Finish, ctx: Ctx) -> Optional[Stmt]:
    """``S1; finish{S2}  ⇒  finish{S1; S2}`` — S1 has no clocked e-asyncs."""
    if _bad_stmt_to_absorb(s1) or isinstance(s1, Finish):
        return None
    eff1 = ctx.escaping(s1)
    if eff1.clocked:
        return None
    if not ctx.may_throw(s1):
        ctx.bump("finish_expansion_upper")
        return Finish(body=seq(s1, f.body), exlist=f.exlist)
    if eff1.may_throw:
        return None  # precondition (Fig. 8 #2): e-asyncs in S1 do not throw
    e = fresh("e")
    ctx.bump("finish_expansion_upper_exc")
    return seq(
        assign_null(e),
        Finish(
            body=seq(catch_into(s1, e), if_null(e, f.body)),
            exlist=(e,) + f.exlist,
        ),
    )


# ---------------------------------------------------------------------------
# Rule 6 (Fig. 4 #3 / Fig. 8 #3): Finish Expansion Lower
# ---------------------------------------------------------------------------


def finish_expansion_lower(f: Finish, s2: Stmt, ctx: Ctx) -> Optional[Stmt]:
    """``finish{S1}; S2  ⇒  finish{S1; S2}``"""
    if _bad_stmt_to_absorb(s2) or isinstance(s2, Finish):
        return None
    eff1 = ctx.escaping(f.body)
    if depends_on_easyncs(s2, eff1.reads, eff1.writes, ctx.summaries,
                          private=ctx.private):
        return None
    if ctx.summaries.stmt_has_barrier(s2):
        return None
    eff2 = ctx.escaping(s2)
    if eff2.clocked:
        return None
    if not ctx.may_throw(s2) and not f.exlist and not eff1.may_throw:
        ctx.bump("finish_expansion_lower")
        return Finish(body=seq(f.body, s2), exlist=())
    if eff1.may_throw or eff2.may_throw:
        return None  # precondition: e-asyncs of S1 and S2 do not throw
    e = fresh("e")
    ctx.bump("finish_expansion_lower_exc")
    return seq(
        assign_null(e),
        Finish(
            body=seq(
                f.body,
                exlist_guard(f.exlist, e),
                if_null(e, catch_into(s2, e)),
            ),
            exlist=(e,),
        ),
    )


# ---------------------------------------------------------------------------
# Rule 7 (Fig. 4 #4 / Fig. 8 #4): Async-Finish Interchange
# ---------------------------------------------------------------------------


def async_finish_interchange(s: Stmt, ctx: Ctx) -> Optional[Stmt]:
    """``async { finish S1 }  ⇒  finish { async S1 }``"""
    if not isinstance(s, Async):
        return None
    inner = s.body
    if isinstance(inner, Seq) and len(inner.stmts) == 1:
        inner = inner.stmts[0]
    if not isinstance(inner, Finish):
        return None
    if inner.exlist:
        return None  # Fig. 8 #4: requires no pending exceptions
    if ctx.may_throw(inner.body) or ctx.escaping(inner.body).may_throw:
        if not ctx.assume_no_exceptions:
            return None  # precondition: S1 throws no exceptions
    ctx.bump("async_finish_interchange")
    return Finish(body=Async(body=inner.body, clocks=s.clocks))


# ---------------------------------------------------------------------------
# Rule 8 (Fig. 8 #5): Try-Finish Exchange
# ---------------------------------------------------------------------------


def try_finish_exchange(s: Stmt, ctx: Ctx) -> Optional[Stmt]:
    """``try { finish{S1}<ex> } catch(e:Ex){ S2 }``  ⇒  hoisted form."""
    if not isinstance(s, TryCatch):
        return None
    inner = s.body
    if isinstance(inner, Seq) and len(inner.stmts) == 1:
        inner = inner.stmts[0]
    if not isinstance(inner, Finish):
        return None
    if ctx.escaping(inner.body).may_throw:
        return None  # precondition: e-asyncs in S1 do not throw
    e = fresh("e")
    e1 = fresh("e")
    wrapped = TryCatch(
        body=inner.body,
        exc_var=e1,
        handler=throw_me_of(e1),
        exc_types=("Exception",),
    )
    ctx.bump("try_finish_exchange")
    return seq(
        assign_null(e),
        Finish(
            body=TryCatch(
                body=seq(wrapped, exlist_guard(inner.exlist, e)),
                exc_var=e1,
                handler=Assign(target=e, value=var(e1)),
                exc_types=s.exc_types,
            ),
        ),
        If(
            cond=expr(lambda env, _e=e: env[_e] is not None, e, label=f"{e}!=null"),
            then=seq(
                Assign(target=s.exc_var, value=var(e)),
                s.handler,
            ),
        ),
    )


# ---------------------------------------------------------------------------
# Seq-level driver: fusion + expansion need adjacency
# ---------------------------------------------------------------------------


def _try_seq_rules(s: Seq, ctx: Ctx) -> Optional[Stmt]:
    stmts = list(s.stmts)
    # Finish Fusion on adjacent pairs.
    for i in range(len(stmts) - 1):
        a, b = stmts[i], stmts[i + 1]
        if isinstance(a, Finish) and isinstance(b, Finish):
            fused = finish_fusion_pair(a, b, ctx)
            if fused is not None:
                return seq(*stmts[:i], fused, *stmts[i + 2 :])
    # Finish Expansion Upper: S1; finish{S2}
    for i in range(len(stmts) - 1):
        a, b = stmts[i], stmts[i + 1]
        if not isinstance(a, Finish) and isinstance(b, Finish):
            out = finish_expansion_upper(a, b, ctx)
            if out is not None:
                return seq(*stmts[:i], out, *stmts[i + 2 :])
    # Finish Expansion Lower: finish{S1}; S2
    for i in range(len(stmts) - 1):
        a, b = stmts[i], stmts[i + 1]
        if isinstance(a, Finish) and not isinstance(b, Finish):
            out = finish_expansion_lower(a, b, ctx)
            if out is not None:
                return seq(*stmts[:i], out, *stmts[i + 2 :])
    return None


NODE_RULES = (
    tail_finish_elimination,
    loop_finish_interchange,
    finish_if_interchange,
    async_finish_interchange,
    try_finish_exchange,
)


def rewrite_once(s: Stmt, ctx: Ctx) -> Optional[Stmt]:
    """Try one rule application anywhere in the tree (bottom-up)."""
    kids = children(s)
    for i, c in enumerate(kids):
        out = rewrite_once(c, ctx)
        if out is not None:
            new_kids = list(kids)
            new_kids[i] = out
            return rebuild(s, new_kids)
    if isinstance(s, Seq):
        out = _try_seq_rules(s, ctx)
        if out is not None:
            return out
    for rule in NODE_RULES:
        out = rule(s, ctx)
        if out is not None:
            return out
    return None


def rewrite_fixpoint(s: Stmt, ctx: Ctx, max_steps: int = 400) -> Stmt:
    cur = s
    for _ in range(max_steps):
        out = rewrite_once(cur, ctx)
        if out is None:
            return cur
        cur = out
        # Summaries may be stale after rewriting; the facts we rely on
        # (escaping effects / may-throw) only shrink under these rules, so
        # reusing them stays conservative.
    return cur
