"""DCAFE paper core: async-finish task IR, AFE + LC + DLBC transformations,
exception extensions, and the deterministic multi-worker runtime simulator.

Public API:

    from repro.core import (
        ir, analysis, transforms, afe, lc, dlbc, runtime, schemes,
        kernels_rtp,
    )
    prog_dcafe, report = dlbc.apply_dcafe(prog)
    result = runtime.run_program(prog_dcafe, n_workers=16, heap=...)
"""

from . import analysis, errors, ir, runtime  # noqa: F401
from .afe import AFEReport, apply_afe  # noqa: F401
from .dlbc import apply_dcafe, apply_dlbc  # noqa: F401
from .kernels_rtp import KERNELS, RTPKernel, build_kernel  # noqa: F401
from .lc import apply_lc  # noqa: F401
from .runtime import CostModel, SimResult, run_program  # noqa: F401
from .schemes import SCHEMES, SchemeRun, run_scheme  # noqa: F401
