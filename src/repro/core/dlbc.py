"""Dynamic Load-Balanced loop Chunking — DLBC codegen (paper §3.2, Figs. 6/7(c)).

For each parallel loop ``[finish] { for (i=lo; i<hi; i++) async [clocked] B }``
emit the three-block structure:

* **chunked block** — spawned only when ``Runtime.retIdleWorkers() > 0``;
  the remaining iterations are divided *equally among idle workers + the
  current worker* with the current worker receiving the **smallest** chunk:
  ``eqChunk = actualn / totWorkers``, remainder distributed one-per-chunk
  from the front via ``rem = actualn % totWorkers + workers`` and
  ``kx = ii + eqChunk + rem / totWorkers; rem--`` (Fig. 6 lines 7–16);
* **parent block** — the current worker executes its own (smallest) chunk
  serially before waiting at the join (Fig. 6 lines 21–24);
* **serial block** — when no workers are idle, execute iterations serially,
  re-reading the idle count after *each* iteration; when ≥1 worker frees up
  and ≥2 iterations remain, jump back to the parallel path (Fig. 6 lines
  26–31).

Clocked loops (Fig. 7(c)) get a ``phase`` counter: the serial block runs a
whole phase over all iterations, advances the clock, then re-checks for
idle workers; chunked/parent blocks guard each phase with ``phase <= p``
(the switch-with-fallthrough of the paper) so already-executed phases are
skipped.

When AFE has already removed the enclosing finish (DCAFE), the chunked and
parent blocks are emitted WITHOUT a finish — the spawned tasks escape to
the single outer join, which is precisely how DCAFE reaches "1 finish,
~1000× fewer tasks" on NQ-style kernels.

The chunk arithmetic itself (totWorkers / eqChunk / chunkEnd / rem / kx)
is NOT re-derived here: the emitted expressions call the canonical
``fig6_*`` helpers of :mod:`repro.sched.policy`, the single owner of the
remainder-spread recurrence shared with the host pools and the serving
batcher.
"""

from __future__ import annotations

from dataclasses import replace
from typing import List, Optional

from .analysis import Summaries
from .ir import (
    Assign, Async, Barrier, Break, Call, Continue, Finish, ForLoop, If,
    MethodDef, Program, Seq, Skip, Stmt, While, binop, children, const, expr,
    fresh, idle_workers, rebuild, seq, var, walk,
)
from .lc import ParallelLoop, chunkable, match_parallel_loop, split_phases
from ..sched.policy import (
    fig6_chunk_end, fig6_eq, fig6_next, fig6_rem0, fig6_tot,
)


def _phase_guard(phase_var: str, p: int, body: Stmt) -> Stmt:
    return If(
        cond=expr(
            lambda env, _v=phase_var, _p=p: env[_v] <= _p,
            phase_var,
            label=f"{phase_var}<={p}",
        ),
        then=body,
    )


def dlbc_loop(pl: ParallelLoop, *, with_finish: bool,
              serial_check_every: int = 1,
              min_parallel: bool = False) -> Stmt:
    """Emit the DLBC structure for one parallel loop.

    The paper's §6 design alternatives are selectable for the design-choice
    study (benchmarks/bench_design_choices.py):

    * ``serial_check_every=k`` — re-check for idle workers only every k-th
      serial iteration (paper §6(b): "the complexity of the additional
      checks did not pay off");
    * ``min_parallel=True`` — instead of full serialization, always split
      the remaining iterations into one spawned task + the current worker
      (paper §6(c): "may end up creating more tasks than required ...
      the cons outweighed the pros").
    """
    i = pl.loop.loopvar
    lo, hi = pl.loop.lo, pl.loop.hi
    clocked = pl.clocked
    nphases = len(pl.phases)

    ii = fresh("ii")
    workers = fresh("workers")
    tot = fresh("totWorkers")
    actualn = fresh("actualn")
    eqc = fresh("eqChunk")
    chunk_end = fresh("chunkEnd")
    rem = fresh("rem")
    ni = fresh("ni")
    kx = fresh("kx")
    phase = fresh("phase")
    resume = fresh("resume")
    si = fresh("si")

    def iter_loop(lo_e, hi_e, body: Stmt) -> Stmt:
        return ForLoop(loopvar=i, lo=lo_e, hi=hi_e, step=const(1), body=body)

    # ---- chunked block (spawned tasks) --------------------------------------
    async_phases: List[Stmt] = []
    for p, ph in enumerate(pl.phases):
        blk = iter_loop(var(ni), var(kx), ph)
        if clocked:
            parts: List[Stmt] = [blk]
            if p < nphases - 1:
                parts.append(Barrier())
            async_phases.append(_phase_guard(phase, p, seq(*parts)))
        else:
            async_phases.append(blk)
    chunk_async = Async(body=seq(*async_phases), clocks=pl.async_.clocks)

    chunked_block = While(
        cond=expr(
            lambda env, _ii=ii, _ce=chunk_end: env[_ii] < env[_ce],
            ii, chunk_end, label=f"{ii}<{chunk_end}",
        ),
        body=seq(
            Assign(
                target=kx,
                value=expr(
                    lambda env, _ii=ii, _e=eqc, _r=rem, _t=tot: fig6_next(
                        env[_ii], env[_e], env[_r], env[_t]),
                    ii, eqc, rem, tot,
                    label=f"{ii}+{eqc}+{rem}/{tot}",
                ),
                declare_local=True,
            ),
            Assign(target=ni, value=var(ii), declare_local=True),
            Assign(target=rem, value=binop("-", var(rem), const(1))),
            Assign(target=ii, value=var(kx)),
            chunk_async,
        ),
    )

    # ---- parent block (current worker's smallest chunk) ----------------------
    parent_phases: List[Stmt] = []
    for p, ph in enumerate(pl.phases):
        blk = iter_loop(var(chunk_end), hi, ph)
        if clocked:
            parts = [blk]
            if p < nphases - 1:
                parts.append(Barrier())
            parent_phases.append(_phase_guard(phase, p, seq(*parts)))
        else:
            parent_phases.append(blk)
    parent_block = seq(*parent_phases)

    par_body = seq(chunked_block, parent_block)
    if with_finish:
        par_body = Finish(body=par_body)

    parallel_arm = seq(
        Assign(target=tot,
               value=expr(lambda env, _w=workers: fig6_tot(env[_w]),
                          workers, label=f"{workers}+1"),
               declare_local=True),
        Assign(target=actualn, value=binop("-", hi, var(ii)),
               declare_local=True),
        Assign(target=eqc,
               value=expr(
                   lambda env, _a=actualn, _t=tot: fig6_eq(env[_a], env[_t]),
                   actualn, tot, label=f"{actualn}//{tot}"),
               declare_local=True),
        Assign(
            target=chunk_end,
            value=expr(
                lambda env, _ii=ii, _a=actualn, _e=eqc: fig6_chunk_end(
                    env[_ii], env[_a], env[_e]),
                ii, actualn, eqc, label=f"{ii}+{actualn}-{eqc}",
            ),
            declare_local=True,
        ),
        Assign(
            target=rem,
            value=expr(
                lambda env, _a=actualn, _t=tot, _w=workers: fig6_rem0(
                    env[_a], env[_t], env[_w]),
                actualn, tot, workers, label=f"{actualn}%{tot}+{workers}",
            ),
            declare_local=True,
        ),
        par_body,
        Break(),
    )

    # ---- serial block ---------------------------------------------------------
    if not clocked:
        # Re-check idle workers after each iteration (Fig. 6).
        serial_arm = seq(
            Assign(target=resume, value=const(False), declare_local=True),
            Assign(target=si, value=var(ii), declare_local=True),
            While(
                cond=expr(
                    lambda env, _s=si: env[_s] < hi.fn(env),
                    si, *hi.reads, label=f"{si}<{hi.label}",
                ),
                body=seq(
                    iter_loop(var(si), binop("+", var(si), const(1)),
                              pl.async_.body),
                    Assign(target=si, value=binop("+", var(si), const(1))),
                    Assign(target=workers, value=idle_workers()),
                    If(
                        cond=expr(
                            lambda env, _w=workers, _s=si,
                            _k=serial_check_every: env[_w] > 0
                            and (hi.fn(env) - env[_s]) >= 2
                            and env[_s] % _k == 0,
                            workers, si, *hi.reads,
                            label=f"{workers}>0&&left>=2&&si%k==0",
                        ),
                        then=seq(
                            Assign(target=ii, value=var(si)),
                            Assign(target=resume, value=const(True)),
                            Break(),
                        ),
                    ),
                ),
            ),
            If(
                cond=expr(lambda env, _r=resume: not env[_r], resume,
                          label=f"!{resume}"),
                then=Break(),
            ),
        )
    else:
        # Fig. 7(c): run a whole phase serially, advance, then re-check once
        # per phase boundary (the paper deliberately does NOT re-check per
        # iteration here, §3.2.3 last paragraph).
        serial_parts: List[Stmt] = [
            Assign(target=resume, value=const(False), declare_local=True),
        ]
        for p, ph in enumerate(pl.phases):
            run_phase = seq(
                _phase_guard(
                    phase, p,
                    seq(
                        iter_loop(lo, hi, ph),
                        *( [Barrier()] if p < nphases - 1 else [] ),
                        *(
                            [
                                Assign(target=workers, value=idle_workers()),
                                If(
                                    cond=expr(
                                        lambda env, _w=workers: env[_w] > 0,
                                        workers, label=f"{workers}>0",
                                    ),
                                    then=seq(
                                        Assign(target=phase,
                                               value=const(p + 1)),
                                        Assign(target=resume,
                                               value=const(True)),
                                        Break(),
                                    ),
                                ),
                            ]
                            if p < nphases - 1
                            else []
                        ),
                    ),
                )
            )
            serial_parts.append(run_phase)
        # Wrap phases in a one-shot loop so Break above exits cleanly.
        serial_arm = seq(
            Assign(target=resume, value=const(False), declare_local=True),
            While(
                cond=expr(lambda env: True, label="true"),
                body=seq(*serial_parts[1:], Break()),
            ),
            If(
                cond=expr(lambda env, _r=resume: not env[_r], resume,
                          label=f"!{resume}"),
                then=Break(),
            ),
        )

    if min_parallel and not clocked:
        # §6(c): no idle workers → still split into (spawned, parent) halves.
        mid = fresh("mid")
        split_body = iter_loop(var(ii), var(mid), pl.async_.body)
        parent_half = iter_loop(var(mid), hi, pl.async_.body)
        two_way = seq(
            Assign(
                target=mid,
                value=expr(lambda env, _i=ii: (env[_i] + hi.fn(env)) // 2,
                           ii, *hi.reads, label=f"({ii}+{hi.label})/2"),
                declare_local=True,
            ),
            Async(body=split_body, clocks=pl.async_.clocks),
            parent_half,
            Break(),
        )
        serial_arm_final = Finish(body=two_way) if with_finish else two_way
        if not with_finish:
            serial_arm_final = seq(two_way)
    else:
        serial_arm_final = serial_arm

    out = seq(
        Assign(target=ii, value=lo, declare_local=True),
        Assign(target=phase, value=const(0), declare_local=True),
        Assign(target=workers, value=idle_workers(), declare_local=True),
        While(
            cond=expr(lambda env: True, label="true"),
            body=seq(
                If(
                    cond=expr(lambda env, _w=workers: env[_w] > 0, workers,
                              label=f"{workers}>0"),
                    then=parallel_arm,
                    els=serial_arm_final,
                ),
                # Re-entering the parallel arm: refresh the worker count the
                # serial block observed (it stored it in ``workers``).
            ),
        ),
    )
    return out


# ---------------------------------------------------------------------------
# Whole-program application
# ---------------------------------------------------------------------------


def apply_dlbc(prog: Program, *, serial_check_every: int = 1,
               min_parallel: bool = False) -> Program:
    """Apply DLBC to every chunkable parallel loop.

    Two patterns are handled:

    * ``Finish(for(async B))`` — DLBC emits its own finish around the
      chunked+parent blocks (Fig. 6, DLBC applied alone);
    * a bare ``for(async B)`` whose tasks escape (AFE already pulled the
      finish) — no new finish is emitted; spawned chunks escape to the one
      outer join (the DCAFE composition).
    """
    from .analysis import bound_locals

    summaries = Summaries.compute(prog)

    def rw_method(m: MethodDef) -> MethodDef:
        private = frozenset(m.params) | bound_locals(m.body)

        def rw(s: Stmt) -> Stmt:
            # Pattern 1: finish { for { async } }  (match before recursing so
            # the finish and loop are consumed together).
            if isinstance(s, Finish) and not s.exlist:
                inner = s.body
                while isinstance(inner, Seq) and len(inner.stmts) == 1:
                    inner = inner.stmts[0]
                pl = match_parallel_loop(inner)
                if pl is not None and chunkable(pl, summaries, private):
                    pl = replace(pl,
                                 async_=replace(pl.async_,
                                                body=rw(pl.async_.body)))
                    pl.phases[:] = split_phases(pl.async_.body)
                    return dlbc_loop(pl, with_finish=True,
                                     serial_check_every=serial_check_every,
                                     min_parallel=min_parallel)
            pl = match_parallel_loop(s)
            if pl is not None and chunkable(pl, summaries, private):
                pl = replace(pl,
                             async_=replace(pl.async_, body=rw(pl.async_.body)))
                pl.phases[:] = split_phases(pl.async_.body)
                return dlbc_loop(pl, with_finish=False,
                                 serial_check_every=serial_check_every,
                                 min_parallel=min_parallel)
            kids = [rw(c) for c in children(s)]
            return rebuild(s, kids) if kids else s

        return replace(m, body=rw(m.body))

    return Program(
        methods=tuple(rw_method(m) for m in prog.methods),
        main=prog.main,
    )


def apply_dcafe(prog: Program, *, assume_no_exceptions: bool = False):
    """DCAFE = AFE ∘ DLBC (paper Fig. 3: MHP → AFE → DLBC → codegen)."""
    from .afe import apply_afe

    afe_prog, report = apply_afe(prog, assume_no_exceptions=assume_no_exceptions)
    return apply_dlbc(afe_prog), report
