"""Async-finish task IR for recursive task-parallel (RTP) programs.

This is the faithful substrate for the DCAFE paper (Gupta, Shrivastava,
Nandivada 2015): an X10-like mini-language with ``async`` / ``finish`` /
clocks / exceptions, rich enough to express the paper's eight mini-
transformations (Figs. 2/4/8/9), the LC and DLBC code-generation schemes
(Figs. 1/6/7) and the eight RTP benchmark kernels.

Design notes
------------
* Nodes are frozen dataclasses → transformations build new trees; rollback
  (the paper's all-or-nothing strategy) is a pointer swap.
* Expressions carry an explicit ``reads`` set so the dependence analysis in
  :mod:`repro.core.analysis` stays purely structural.
* Memory locations are strings.  The convention ``"arr[i]"`` denotes an
  array element indexed by the *loop variable* ``i``; two accesses
  ``arr[i]`` from different iterations of the same counted loop are
  disjoint (X10 ``Rail`` element writes by iteration index).  ``"arr[*]"``
  is an unknown index and conflicts with every ``arr[...]`` access.
* X10 ``val`` capture semantics: an ``Async`` body executes with a by-value
  snapshot of the spawner's local frame (this is why LC emits
  ``val ni = ii`` — the same pattern works unchanged here).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Optional

# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Expr:
    """An opaque expression: a pure function of the environment.

    ``reads`` lists every location the expression may read.  ``intrinsic``
    marks runtime intrinsics (``idle_workers`` / ``n_threads``) that read
    scheduler state instead of the heap.
    """

    fn: Callable[["EnvView"], Any]
    reads: frozenset = frozenset()
    label: str = ""
    intrinsic: str = ""

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Expr({self.label or self.intrinsic or 'λ'})"


def const(v: Any) -> Expr:
    return Expr(fn=lambda env, _v=v: _v, reads=frozenset(), label=repr(v))


def var(name: str) -> Expr:
    return Expr(fn=lambda env, _n=name: env[_n], reads=frozenset({name}), label=name)


def expr(fn: Callable[["EnvView"], Any], *reads: str, label: str = "") -> Expr:
    return Expr(fn=fn, reads=frozenset(reads), label=label)


def binop(op: str, a: Expr, b: Expr) -> Expr:
    import operator

    ops = {
        "+": operator.add, "-": operator.sub, "*": operator.mul,
        "//": operator.floordiv, "%": operator.mod,
        "<": operator.lt, "<=": operator.le, ">": operator.gt,
        ">=": operator.ge, "==": operator.eq, "!=": operator.ne,
        "and": lambda x, y: x and y, "or": lambda x, y: x or y,
        "min": min, "max": max,
    }
    f = ops[op]
    return Expr(
        fn=lambda env, _f=f, _a=a, _b=b: _f(_a.fn(env), _b.fn(env)),
        reads=a.reads | b.reads,
        label=f"({a.label}{op}{b.label})",
    )


def idle_workers() -> Expr:
    """``Runtime.retIdleWorkers()`` — deliberately non-atomic (paper §3.2.1)."""
    return Expr(fn=lambda env: env.runtime_idle_workers(), reads=frozenset(),
                label="retIdleWorkers()", intrinsic="idle_workers")


def n_threads() -> Expr:
    """``Runtime.retNthreads()`` — initial worker count (paper Fig. 1(b))."""
    return Expr(fn=lambda env: env.runtime_n_threads(), reads=frozenset(),
                label="retNthreads()", intrinsic="n_threads")


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------


class Stmt:
    """Base class for IR statements (all subclasses are frozen dataclasses)."""

    __slots__ = ()


@dataclass(frozen=True)
class Skip(Stmt):
    pass


@dataclass(frozen=True)
class Seq(Stmt):
    stmts: tuple = ()

    def __post_init__(self):
        assert all(isinstance(s, Stmt) for s in self.stmts)


@dataclass(frozen=True)
class Assign(Stmt):
    """``var = expr`` — writes a single location."""

    target: str
    value: Expr
    cost: float = 0.0
    declare_local: bool = False  # X10 ``val``/``var`` declaration (task-local)


@dataclass(frozen=True)
class Compute(Stmt):
    """Opaque computation with declared read/write sets and a cost.

    ``fn(env)`` mutates the environment (only locations in ``writes``).
    ``cost`` may be a float or an Expr evaluated at runtime (simulated
    work units).
    """

    fn: Callable[["EnvView"], None]
    reads: frozenset = frozenset()
    writes: frozenset = frozenset()
    cost: Any = 1.0
    label: str = ""


@dataclass(frozen=True)
class Async(Stmt):
    body: Stmt = Skip()
    clocks: tuple = ()  # names of clock-valued locals the task registers on


@dataclass(frozen=True)
class Finish(Stmt):
    body: Stmt = Skip()
    # Pending-exception list (paper §4): sequence of local variable names;
    # lowered by ``lower_pending`` into ``if (v != null) throw v`` trailers.
    exlist: tuple = ()


@dataclass(frozen=True)
class ForLoop(Stmt):
    """Counted loop ``for (var v = lo; v < hi; v += step) body``."""

    loopvar: str
    lo: Expr = const(0)
    hi: Expr = const(0)
    step: Expr = const(1)
    body: Stmt = Skip()


@dataclass(frozen=True)
class While(Stmt):
    cond: Expr = const(True)
    body: Stmt = Skip()


@dataclass(frozen=True)
class Break(Stmt):
    pass


@dataclass(frozen=True)
class Continue(Stmt):
    pass


@dataclass(frozen=True)
class If(Stmt):
    cond: Expr = const(True)
    then: Stmt = Skip()
    els: Stmt = Skip()


@dataclass(frozen=True)
class Call(Stmt):
    callee: str
    args: tuple = ()  # tuple[Expr, ...] — by-value (X10 val) parameters


@dataclass(frozen=True)
class NewClock(Stmt):
    """``val c = Clock.make()`` — creator task is registered on the clock."""

    target: str


@dataclass(frozen=True)
class Barrier(Stmt):
    """``Clock.advanceAll()`` — advance every clock this task is registered on."""

    pass


@dataclass(frozen=True)
class Throw(Stmt):
    exc_type: str = "Exception"
    payload: Expr = const(None)


@dataclass(frozen=True)
class TryCatch(Stmt):
    body: Stmt = Skip()
    exc_var: str = "e"
    handler: Stmt = Skip()
    exc_types: tuple = ("Exception",)  # "ME" catches MultipleExceptions


@dataclass(frozen=True)
class MethodDef:
    name: str
    params: tuple = ()
    body: Stmt = Skip()
    # Set by AFE when Finish-Method Pull has been applied (halting guard).
    finish_pulled: bool = False


@dataclass(frozen=True)
class Program:
    methods: tuple = ()  # tuple[MethodDef, ...]
    main: str = "main"

    def method(self, name: str) -> MethodDef:
        for m in self.methods:
            if m.name == name:
                return m
        raise KeyError(name)

    def with_method(self, m: MethodDef) -> "Program":
        return Program(
            methods=tuple(m if x.name == m.name else x for x in self.methods),
            main=self.main,
        )

    def names(self):
        return [m.name for m in self.methods]


# ---------------------------------------------------------------------------
# Structural helpers
# ---------------------------------------------------------------------------


def children(s: Stmt):
    """Immediate child statements of ``s``."""
    if isinstance(s, Seq):
        return list(s.stmts)
    if isinstance(s, (Async, Finish)):
        return [s.body]
    if isinstance(s, ForLoop):
        return [s.body]
    if isinstance(s, While):
        return [s.body]
    if isinstance(s, If):
        return [s.then, s.els]
    if isinstance(s, TryCatch):
        return [s.body, s.handler]
    return []


def rebuild(s: Stmt, new_children) -> Stmt:
    if isinstance(s, Seq):
        return Seq(tuple(new_children))
    if isinstance(s, Async):
        return replace(s, body=new_children[0])
    if isinstance(s, Finish):
        return replace(s, body=new_children[0])
    if isinstance(s, ForLoop):
        return replace(s, body=new_children[0])
    if isinstance(s, While):
        return replace(s, body=new_children[0])
    if isinstance(s, If):
        return replace(s, then=new_children[0], els=new_children[1])
    if isinstance(s, TryCatch):
        return replace(s, body=new_children[0], handler=new_children[1])
    assert not new_children
    return s


def walk(s: Stmt):
    """Pre-order traversal of every statement in the subtree."""
    yield s
    for c in children(s):
        yield from walk(c)


def tree_size(s: Stmt) -> int:
    return sum(1 for _ in walk(s))


def seq(*stmts: Stmt) -> Stmt:
    """Smart Seq constructor: flattens nested Seq, drops Skip."""
    flat = []
    for st in stmts:
        if isinstance(st, Skip):
            continue
        if isinstance(st, Seq):
            flat.extend(x for x in st.stmts if not isinstance(x, Skip))
        else:
            flat.append(st)
    if not flat:
        return Skip()
    if len(flat) == 1:
        return flat[0]
    return Seq(tuple(flat))


_FRESH = itertools.count()


def fresh(prefix: str = "t") -> str:
    return f"__{prefix}{next(_FRESH)}"


# ---------------------------------------------------------------------------
# Location algebra ("arr[i]" / "arr[*]" / scalars)
# ---------------------------------------------------------------------------


def loc_base(loc: str) -> str:
    return loc.split("[", 1)[0]


def loc_index(loc: str) -> Optional[str]:
    if "[" in loc:
        return loc[loc.index("[") + 1 : -1]
    return None


def locs_conflict(a: str, b: str, *, iteration_private: tuple = ()) -> bool:
    """Do locations ``a`` and ``b`` possibly alias?

    ``iteration_private`` lists loop variables for which same-index accesses
    from *different iterations* are known disjoint (used for loop-carried
    dependence tests): ``arr[i]`` vs ``arr[i]`` with i ∈ iteration_private is
    treated as a conflict ONLY when checking same-iteration dependence — the
    caller flips the meaning by passing the private set.
    """
    if loc_base(a) != loc_base(b):
        return False
    ia, ib = loc_index(a), loc_index(b)
    if ia is None or ib is None:
        return True  # scalar vs scalar (same base) or scalar vs array base
    if ia == "+" and ib == "+":
        # Commutative-reduction accesses ("arr[+]"): atomic monotone updates
        # (min/max/sum accumulators) commute with each other, so two
        # reduction accesses to the same base never constitute an ordering
        # dependence.  A reduction access vs a plain read/write DOES conflict
        # (handled below).  This mirrors how X10 dependence analyses treat
        # accumulator idioms.
        return False
    if ia == "*" or ib == "*":
        return True
    if ia == ib and ia in iteration_private:
        # Same symbolic index, privatised per iteration → disjoint across
        # iterations.
        return False
    if ia == ib:
        return True
    # Distinct symbolic indices: conservatively assume they may alias unless
    # both are integer literals.
    try:
        return int(ia) == int(ib)
    except ValueError:
        return True


def sets_conflict(A, B, *, iteration_private: tuple = ()) -> bool:
    return any(
        locs_conflict(a, b, iteration_private=iteration_private)
        for a in A
        for b in B
    )


# ---------------------------------------------------------------------------
# Pending-exception lowering (paper §4: finish{S}<exlist> ⇒ finish{S}; exlist)
# ---------------------------------------------------------------------------


def _throw_if_set(v: str) -> Stmt:
    return If(
        cond=expr(lambda env, _v=v: env[_v] is not None, v, label=f"{v}!=null"),
        then=Compute(
            fn=lambda env, _v=v: env.rethrow(env[_v]),
            reads=frozenset({v}),
            writes=frozenset(),
            cost=0.0,
            label=f"throw {v}",
        ),
    )


def lower_pending(s: Stmt) -> Stmt:
    """Translate away temporary ``finish{S}<exlist>`` constructs."""
    kids = [lower_pending(c) for c in children(s)]
    s2 = rebuild(s, kids) if kids else s
    if isinstance(s2, Finish) and s2.exlist:
        trailers = [_throw_if_set(v) for v in s2.exlist]
        return seq(Finish(body=s2.body), *trailers)
    return s2


def lower_program_pending(p: Program) -> Program:
    return Program(
        methods=tuple(replace(m, body=lower_pending(m.body)) for m in p.methods),
        main=p.main,
    )


# ---------------------------------------------------------------------------
# Pretty printer (debugging / DESIGN docs)
# ---------------------------------------------------------------------------


def pretty(s: Stmt, indent: int = 0) -> str:
    pad = "  " * indent
    if isinstance(s, Skip):
        return pad + "skip;"
    if isinstance(s, Seq):
        return "\n".join(pretty(c, indent) for c in s.stmts)
    if isinstance(s, Assign):
        kw = "val " if s.declare_local else ""
        return f"{pad}{kw}{s.target} = {s.value.label};"
    if isinstance(s, Compute):
        return f"{pad}compute[{s.label or 'work'}](r={sorted(s.reads)}, w={sorted(s.writes)});"
    if isinstance(s, Async):
        ck = f" clocked({','.join(s.clocks)})" if s.clocks else ""
        return f"{pad}async{ck} {{\n{pretty(s.body, indent + 1)}\n{pad}}}"
    if isinstance(s, Finish):
        ex = f"<{','.join(s.exlist)}>" if s.exlist else ""
        return f"{pad}finish {{\n{pretty(s.body, indent + 1)}\n{pad}}}{ex}"
    if isinstance(s, ForLoop):
        return (
            f"{pad}for ({s.loopvar} = {s.lo.label}; {s.loopvar} < {s.hi.label}; "
            f"{s.loopvar} += {s.step.label}) {{\n{pretty(s.body, indent + 1)}\n{pad}}}"
        )
    if isinstance(s, While):
        return f"{pad}while ({s.cond.label}) {{\n{pretty(s.body, indent + 1)}\n{pad}}}"
    if isinstance(s, Break):
        return pad + "break;"
    if isinstance(s, Continue):
        return pad + "continue;"
    if isinstance(s, If):
        out = f"{pad}if ({s.cond.label}) {{\n{pretty(s.then, indent + 1)}\n{pad}}}"
        if not isinstance(s.els, Skip):
            out += f" else {{\n{pretty(s.els, indent + 1)}\n{pad}}}"
        return out
    if isinstance(s, Call):
        return f"{pad}{s.callee}({', '.join(a.label for a in s.args)});"
    if isinstance(s, NewClock):
        return f"{pad}val {s.target} = Clock.make();"
    if isinstance(s, Barrier):
        return pad + "Clock.advanceAll();"
    if isinstance(s, Throw):
        return f"{pad}throw {s.exc_type};"
    if isinstance(s, TryCatch):
        return (
            f"{pad}try {{\n{pretty(s.body, indent + 1)}\n{pad}}} "
            f"catch({s.exc_var}:{'|'.join(s.exc_types)}) {{\n"
            f"{pretty(s.handler, indent + 1)}\n{pad}}}"
        )
    return pad + repr(s)


def pretty_program(p: Program) -> str:
    out = []
    for m in p.methods:
        out.append(f"def {m.name}({', '.join(m.params)}) {{")
        out.append(pretty(m.body, 1))
        out.append("}")
    return "\n".join(out)
