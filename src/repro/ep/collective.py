"""Token exchange collectives over the ``expert`` mesh axis.

The exchange moves capacity-padded lane buffers between expert shards:
every shard holds a ``(S · lane_capacity, ...)`` buffer whose block
``j`` is its outgoing lane for shard ``j``; after the exchange, block
``i`` of the result is the lane *from* source ``i``.  Ragged per-shard
counts are absorbed by the padding (the :mod:`repro.ep.plan` arithmetic
bounds every lane by ``lane_capacity``), so the collective itself is a
static-shape ``jax.lax.all_to_all`` — or an equivalent ``ppermute``
ring for backends where the fused all-to-all is unavailable.  Both run
inside a ``shard_map`` over the ``expert`` axis (see
:mod:`repro.ep.dispatch`).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

try:  # jax >= 0.6 re-exports shard_map at the top level
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # pinned 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

# distributed.sharding owns both the axis name and "does this mesh
# carve it, how wide" (expert_axis_size: 0 when absent); re-exported
# here so EP callers have one import surface.
from ..distributed.sharding import (  # noqa: F401
    EXPERT_AXIS, expert_axis_size,
)


def has_expert_axis(mesh) -> bool:
    return mesh is not None and EXPERT_AXIS in mesh.axis_names


def exchange(buf: jax.Array, n_shards: int, *,
             axis_name: str = EXPERT_AXIS,
             impl: str = "all_to_all") -> jax.Array:
    """All-to-all the lane blocks of ``buf`` (leading dim ``S·C``).

    Outgoing block ``j`` (rows ``[j·C, (j+1)·C)``) goes to shard ``j``;
    incoming block ``i`` of the result came from source ``i``.  The
    exchange is an involution-shaped transpose: applying it twice
    returns every row home, which is exactly how the combine leg reuses
    it.  Must be called inside a ``shard_map`` over ``axis_name``.

    ``impl="all_to_all"`` — the fused collective (one ICI barrier);
    ``impl="ppermute"`` — an ``S - 1``-step rotation ring that moves
    identical bytes for backends without a fused all-to-all lowering.
    """
    if buf.shape[0] % n_shards != 0:
        raise ValueError(
            f"lane buffer dim {buf.shape[0]} not divisible by "
            f"{n_shards} shards")
    if impl == "all_to_all":
        return jax.lax.all_to_all(buf, axis_name, split_axis=0,
                                  concat_axis=0, tiled=True)
    if impl == "ppermute":
        return _exchange_ppermute(buf, n_shards, axis_name)
    raise ValueError(f"unknown exchange impl {impl!r}; "
                     "choose all_to_all or ppermute")


def _exchange_ppermute(buf: jax.Array, n_shards: int,
                       axis_name: str) -> jax.Array:
    """Rotation-ring all-to-all: at offset ``o`` every shard forwards
    the block addressed to ``(me + o) % S`` one hop of a static
    ``i → i + o`` permutation and files what arrives under its source
    ``(me - o) % S``.  Block 0 of the rotation (``o = 0``) stays home."""
    S = n_shards
    C = buf.shape[0] // S
    me = jax.lax.axis_index(axis_name)
    # o = 0: my own lane to myself stays in place (block index == me).
    own = jax.lax.dynamic_slice_in_dim(buf, me * C, C, axis=0)
    out = jax.lax.dynamic_update_slice_in_dim(
        jnp.zeros_like(buf), own, me * C, axis=0)
    for o in range(1, S):
        perm = [(i, (i + o) % S) for i in range(S)]
        block = jax.lax.dynamic_slice_in_dim(
            buf, ((me + o) % S) * C, C, axis=0)
        got = jax.lax.ppermute(block, axis_name, perm)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, got, ((me - o) % S) * C, axis=0)
    return out


def token_shards(T: int, E: int, mesh,
                 axis_name: str = EXPERT_AXIS) -> Optional[int]:
    """How many ways the EP path can shard this call, or ``None`` when
    the mesh has no expert axis or the static shapes don't divide
    (callers fall back to the single-host dispatch rather than
    mis-shard)."""
    S = expert_axis_size(mesh)
    if S <= 1 or T % S != 0 or E % S != 0:
        return None
    return S
