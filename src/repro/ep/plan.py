"""Exchange planning: DLBC chunk arithmetic as an all-to-all send plan.

An expert-parallel dispatch is a loop over (token, choice) pairs whose
"workers" are expert shards: shard ``s`` owns experts
``[s·E/S, (s+1)·E/S)`` and a per-source *lane* of ``lane_capacity``
buffer rows in every other shard's incoming all-to-all block.  The
paper's two moves map directly:

* **DLBC** — the send-count matrix is a capacity-aware chunk plan: each
  source splits its routed pairs across destination lanes, and
  over-capacity residuals are *reassigned* to shards with idle lane
  capacity (via the canonical Fig. 6 ``chunk_plan`` split, re-probing
  residuals like the serial block re-probes idle workers) **before**
  the collective runs — instead of every shard dropping its own
  overflow after the fact.
* **AFE** — the plan prices one barrier per dispatch round; per-shard /
  per-expert joins never appear (see :mod:`repro.ep.dispatch`).

:class:`ExchangePlan` is the host-side artifact (telemetry, benches,
property tests); :func:`plan_exchange` owns the arithmetic, built on
:func:`repro.sched.chunk_plan` and
:class:`repro.sched.ExpertCapacityProvider` — the same residual/clamp
path every other admission surface uses.  The traced jnp form of the
reassignment in :func:`repro.ep.dispatch._ep_shard` is the *single
probe* of the single-host DLBC round 2 (one alternative expert per
token, static shapes oblige); this host plan re-probes until capacity
or overflow runs out, so its drop count is a lower bound on what the
traced round drops under extreme skew.  Each side's conservation
invariant is asserted in ``tests/test_ep.py``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from ..sched import ExpertCapacityProvider, chunk_plan


@dataclass(frozen=True)
class ExchangePlan:
    """The all-to-all plan for one dispatch round.

    ``send[i][j]`` — (token, choice) pairs source shard ``i`` puts in
    its lane to expert shard ``j`` (post-reassignment, ≤
    ``lane_capacity``).  ``recv`` is its transpose — what each shard
    will find in its incoming block.  ``reassigned[i]`` / ``dropped[i]``
    account for source ``i``'s overflow: pairs moved to an idle shard's
    lane before the collective, and pairs no lane had room for.

    Conservation (property-tested): for every source row,
    ``sum(send[i]) + dropped[i] == sum(counts[i])``.
    """

    counts: Tuple[Tuple[int, ...], ...]   # routed (src, dst) pairs
    send: Tuple[Tuple[int, ...], ...]     # planned (src, dst) pairs
    reassigned: Tuple[int, ...]
    dropped: Tuple[int, ...]
    lane_capacity: int

    @property
    def n_shards(self) -> int:
        return len(self.send)

    @property
    def recv(self) -> Tuple[Tuple[int, ...], ...]:
        """recv[j][i] — pairs shard j receives from source i."""
        return tuple(zip(*self.send))

    @property
    def sent_total(self) -> int:
        return sum(map(sum, self.send))

    @property
    def reassigned_total(self) -> int:
        return sum(self.reassigned)

    @property
    def dropped_total(self) -> int:
        return sum(self.dropped)

    def summary(self) -> dict:
        """The SchedTelemetry.exchange vocabulary for this plan."""
        return dict(sent=self.sent_total, received=self.sent_total,
                    reassigned=self.reassigned_total,
                    dropped=self.dropped_total, rounds=1)


def _spread_overflow(overflow: int, residual: List[int]) -> Tuple[List[int], int]:
    """Split ``overflow`` pairs across lanes with ``residual`` idle rows.

    The Fig. 6 arithmetic verbatim: the overflow range is chunk-planned
    over the idle lanes (``idle + 1`` shares, remainder spread from the
    front), each share clamped to its lane's residual, and the loop
    re-probes — the serial block's "re-check for idle workers" — until
    the overflow or the idle capacity runs out.  Returns per-lane
    additions and the dropped remainder (≥ 0 by construction: the
    residual clamp in :meth:`ExpertCapacityProvider.residual` means a
    full lane contributes zero shares, never a negative one).
    """
    add = [0] * len(residual)
    remaining = overflow
    while remaining > 0:
        idle = [j for j, r in enumerate(residual) if r - add[j] > 0]
        if not idle:
            break
        plan = chunk_plan(0, remaining, len(idle) - 1)
        for (a, b), j in zip(plan.chunks, idle):
            take = min(b - a, residual[j] - add[j])
            add[j] += take
            remaining -= take
    return add, remaining


def plan_exchange(counts: Sequence[Sequence[int]],
                  lane_capacity: int) -> ExchangePlan:
    """Build the send plan from routed (src, dst) pair counts.

    ``counts[i][j]`` — pairs source ``i``'s router assigned to experts
    living on shard ``j``.  Each lane admits up to ``lane_capacity``
    pairs (the :class:`ExpertCapacityProvider` admission rule with
    shards as "experts" and lane rows as slots); the overflow is
    reassigned across the same source's idle lanes, and only what no
    lane can hold is dropped.
    """
    S = len(counts)
    if lane_capacity < 0:
        raise ValueError(f"lane_capacity must be >= 0, got {lane_capacity}")
    cap = ExpertCapacityProvider(n_experts=S, slots_per_expert=lane_capacity)
    send: List[Tuple[int, ...]] = []
    reassigned: List[int] = []
    dropped: List[int] = []
    for i, row in enumerate(counts):
        if len(row) != S:
            raise ValueError(f"counts row {i} has {len(row)} lanes, "
                             f"expected {S}")
        row_arr = np.asarray([int(c) for c in row])
        kept = np.minimum(row_arr, lane_capacity).tolist()
        # both sides of the provider's clamp: overflow is what residual
        # swallowed, and what _spread_overflow re-plans across lanes
        overflow = int(np.sum(np.asarray(cap.overflow(row_arr))))
        residual = [int(r) for r in cap.residual(np.asarray(kept))]
        add, remaining = _spread_overflow(overflow, residual)
        send.append(tuple(k + a for k, a in zip(kept, add)))
        reassigned.append(overflow - remaining)
        dropped.append(remaining)
    return ExchangePlan(
        counts=tuple(tuple(int(c) for c in row) for row in counts),
        send=tuple(send), reassigned=tuple(reassigned),
        dropped=tuple(dropped), lane_capacity=lane_capacity)


def lane_capacity(tokens_per_shard: int, top_k: int, n_shards: int,
                  capacity_factor: float) -> int:
    """Rows per (src, dst) lane: the MoE capacity formula with shards as
    the expert dimension — ``ceil(T_local·K/S · cf)`` padded to 8 (TPU
    lane alignment), so ``S`` lanes jointly hold every locally routed
    pair whenever ``capacity_factor >= 1.0``."""
    c = int(math.ceil(tokens_per_shard * top_k / n_shards
                      * capacity_factor))
    return max(8, ((c + 7) // 8) * 8)
