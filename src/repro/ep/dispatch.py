"""``ep_dispatch_combine`` — the expert-parallel dispatch round.

One round, four legs, ONE join (shard-locally: route → pack; globally:
all-to-all → expert FFN → all-to-all back → combine):

1. **Shard-local route** — each expert shard top-k routes its own slice
   of the tokens against the replicated router, then runs the DLBC lane
   admission *in traced form*: over-capacity residuals reassigned to an
   expert on a shard with idle lane capacity **before** the collective
   (the single-probe round-2 re-route of ``models.moe`` lifted from
   experts to expert shards; the host-side
   :func:`repro.ep.plan.plan_exchange` re-probes to exhaustion, so its
   drop count lower-bounds this round's).
2. **Dispatch all-to-all** — capacity-padded lane buffers exchanged
   over the ``expert`` mesh axis (:func:`repro.ep.collective.exchange`).
3. **Per-shard expert FFN** — received pairs admitted into the local
   ``(E/S, C, d)`` capacity buffers (the same
   :class:`~repro.sched.capacity.ExpertCapacityProvider` arithmetic as
   the single-host path) and pushed through ``expert_ffn``.
4. **Combine all-to-all** — expert outputs retrace the exchange home
   and gate-combine in token order.

AFE is the synchronization story: the whole round is one bulk step with
a single logical barrier.  No per-expert or per-shard joins exist to
eliminate — the host wrapper :func:`ep_round` runs each round under a
DCAFE :class:`~repro.sched.executors.FinishScope`, so telemetry shows
exactly ``joins == rounds`` (gated in CI from the ``bench_ep``
artifact).

Numerics: with ample capacity the result equals the single-host
``dispatch_combine`` up to token order (asserted in
``tests/test_ep.py``); under pressure the DLBC plan strictly dominates
per-shard dropping (overflow is reassigned, not dropped).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..kernels.moe_dispatch.ops import (
    combine_tokens, dispatch_tokens, expert_ffn,
)
from ..obs import metrics as obs_metrics
from ..obs import monitor as obs_monitor
from ..obs import trace as obs
from ..models.moe import (
    _expert_load, _positions_in_expert, capacity, dlbc_reroute, route,
)
from ..sched import ExpertCapacityProvider, SchedTelemetry
from ..sched import faults
from ..sched.executors import FinishScope
from ..sched.faults import ShardLossError
from .collective import EXPERT_AXIS, exchange, shard_map, token_shards
from .plan import lane_capacity


def _ep_shard(x, router, w1, w3, w2, *, E: int, S: int, K: int,
              C_lane: int, C_local: int, act: str, use_kernel: bool,
              impl: str, reassign: bool, dead_shards: tuple = ()):
    """One expert shard's slice of the dispatch round (under shard_map).

    Returns ``(y_local, stats_row)`` where ``stats_row`` is the shard's
    ``[sent, received, reassigned, admitted]`` counts — summed over the
    expert axis by the caller.
    """
    Tl, d = x.shape
    E_local = E // S
    lane_cap = ExpertCapacityProvider(n_experts=S, slots_per_expert=C_lane)
    local_cap = ExpertCapacityProvider(n_experts=E_local,
                                       slots_per_expert=C_local)

    # --- leg 1: shard-local route + DLBC lane plan ----------------------
    gates, ids, probs = route(x, router, K)          # (Tl, K)
    dest = ids // E_local                            # destination shard
    pos = _positions_in_expert(dest, S)              # rank in my lane
    # Graceful degradation: a dead shard's lanes are CLOSED at the
    # admission mask, so no pair is ever packed toward it — under
    # ``reassign`` the re-route below moves those pairs onto live
    # shards with lane residual BEFORE the collective (dlbc_reroute,
    # the same round-2 machinery), under LC they drop like any
    # overflow.  ``dead_shards`` is static (a traced attempt per dead
    # set), so XLA sees a constant mask.
    alive_v = jnp.asarray([s not in dead_shards for s in range(S)])
    keep1 = lane_cap.admit_mask(pos) & alive_v[dest]
    # Overflow reassignment, single-probe (static shapes): a pair whose
    # lane is full re-routes ONCE to its best expert on a shard whose
    # lane still has residual rows — reassigned before the collective,
    # so the receiving shard never sees (and never drops) the overflow.
    # Unlike the host-side plan_exchange loop this does not re-probe, so
    # pairs whose probe lands on a lane that fills up are dropped even
    # if another lane still has room (the same trade the single-host
    # DLBC round 2 makes).  The re-route itself IS the single-host
    # round 2 with expert shards as the groups (dlbc_reroute).
    if reassign:
        lane_load = _expert_load(dest, keep1, S)     # (S,) kept per lane
        resid = lane_cap.residual(lane_load)
        ids_f, dest_f, pos_f, keep, gates_f, overflow = dlbc_reroute(
            ids, gates, probs, pos, keep1, lane_load, lane_cap, S,
            expert_open=jnp.repeat((resid > 0) & alive_v, E_local),
            group_of=lambda i: i // E_local)
    else:
        # LC lane semantics (moe_dispatch="lc"): static single-round
        # admission, overflow dropped — the per-shard baseline the DLBC
        # plan is measured against.  overflow == ~keep makes the
        # reassigned stat (overflow & keep) identically zero.
        ids_f, dest_f, pos_f, keep, gates_f = ids, dest, pos, keep1, gates
        overflow = ~keep1

    # --- pack lanes + dispatch all-to-all -------------------------------
    slot = dest_f * C_lane + jnp.minimum(pos_f, C_lane - 1)  # (Tl, K)
    keepf = keep.astype(x.dtype)
    # The local expert id rides the exchange as payload column d,
    # encoded +1 so an untouched row reads 0 ("empty"): kept slots are
    # unique so scatter-add fills them exactly once, dropped pairs add
    # zero, and the dispatch leg stays ONE all-to-all.  Exact in every
    # payload dtype (ep_dispatch_combine bounds E_local + 1 by the
    # mantissa for sub-f32 dtypes).
    meta = (ids_f % E_local + 1).astype(x.dtype) * keepf     # (Tl, K)
    payload = jnp.concatenate(
        [x[:, None, :] * keepf[..., None], meta[..., None]], axis=-1)
    sendx = jnp.zeros((S * C_lane, d + 1), x.dtype).at[
        slot.reshape(-1)].add(payload.reshape(Tl * K, d + 1))
    recv = exchange(sendx, S, impl=impl)
    recvx = recv[:, :d]
    recv_eid = recv[:, d].astype(jnp.int32) - 1      # -1 = empty row

    # --- leg 3: local admission + expert FFN ----------------------------
    valid = recv_eid >= 0
    rids = jnp.maximum(recv_eid, 0)
    rpos = _positions_in_expert(
        jnp.where(valid, recv_eid, E_local)[:, None], E_local + 1)[:, 0]
    keep_loc = (valid & local_cap.admit_mask(rpos))[:, None]
    buf, slot_loc = dispatch_tokens(recvx, keep_loc, rids[:, None],
                                    rpos[:, None], E_local, C_local)
    out = expert_ffn(buf, {"w1": w1, "w3": w3, "w2": w2}, act,
                     use_kernel=use_kernel)
    ones = jnp.ones(keep_loc.shape, recvx.dtype)
    y_recv = combine_tokens(out, slot_loc, ones, keep_loc)   # (S·C_lane, d)

    # --- leg 4: combine all-to-all + gate-combine -----------------------
    # The exchange is its own inverse on lane layout: block i of y_recv
    # holds results for source i's lane, so one more exchange files each
    # shard's own lane results back under the slots it packed them from.
    backx = exchange(y_recv, S, impl=impl)
    gathered = backx[slot.reshape(-1)].reshape(Tl, K, d)
    w = (gates_f * keep).astype(x.dtype)
    y = jnp.einsum("tkd,tk->td", gathered, w)

    stats_row = jnp.stack([
        jnp.sum(keep), jnp.sum(valid), jnp.sum(overflow & keep),
        jnp.sum(keep_loc),
    ]).astype(jnp.int32)[None, :]
    return y, stats_row


def ep_dispatch_combine(p: dict, cfg, x, *, mesh, use_kernel: bool = False,
                        impl: str = "all_to_all",
                        return_stats: bool = False,
                        dead_shards: tuple = ()):
    """Expert-parallel dispatch → FFN → combine over the ``expert`` axis.

    ``x`` is the flattened ``(T, d)`` token matrix; the shard_map
    reshards it ``T``-major onto the expert axis, so callers need no
    special input placement.  Requires ``T % S == 0 and E % S == 0``
    (checked — callers use :func:`repro.ep.collective.token_shards` to
    fall back to the single-host path otherwise).

    ``dead_shards`` runs the round DEGRADED: the listed shards' lanes
    are closed at admission, so their traffic re-routes to live shards
    (DLBC) or drops (LC) before the collective — see
    :func:`ep_round` for the retry loop that discovers the dead set.
    """
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    S = token_shards(T, E, mesh)
    if S is None:
        raise ValueError(
            f"EP dispatch needs an expert axis dividing T={T} and "
            f"E={E}; mesh axes {getattr(mesh, 'axis_names', None)}")
    dead_shards = tuple(sorted({int(s) for s in dead_shards}))
    if dead_shards:
        bad = [s for s in dead_shards if not 0 <= s < S]
        if bad:
            raise ValueError(f"dead_shards {bad} outside [0, {S})")
        if len(dead_shards) >= S:
            raise ValueError(
                f"all {S} shards dead — nothing left to degrade onto")
    C_lane = lane_capacity(T // S, K, S, cfg.moe_capacity_factor)
    # Per-expert capacity matches the single-host formula on the GLOBAL
    # token count, so admission (and numerics) line up shard-for-shard.
    C_local = capacity(T, E, K, cfg.moe_capacity_factor)
    if jnp.issubdtype(x.dtype, jnp.inexact):
        # the expert-id metadata rides as a payload column, +1-encoded:
        # it must be exactly representable in the payload dtype
        max_exact = 2 ** (jnp.finfo(x.dtype).nmant + 1)
        if E // S + 1 > max_exact:
            raise ValueError(
                f"E/S + 1 = {E // S + 1} local expert ids do not fit "
                f"exactly in {x.dtype} (max {max_exact}); cast tokens "
                "to a wider dtype for EP dispatch")
    fn = partial(_ep_shard, E=E, S=S, K=K, C_lane=C_lane, C_local=C_local,
                 act=cfg.act, use_kernel=use_kernel, impl=impl,
                 # "lc" keeps its static single-round semantics on the EP
                 # substrate too (no reassignment) so the LC-vs-DLBC
                 # comparison stays meaningful shard-side
                 reassign=cfg.moe_dispatch != "lc",
                 dead_shards=dead_shards)
    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(P(EXPERT_AXIS, None), P(None, None),
                  P(EXPERT_AXIS, None, None), P(EXPERT_AXIS, None, None),
                  P(EXPERT_AXIS, None, None)),
        out_specs=(P(EXPERT_AXIS, None), P(EXPERT_AXIS, None)),
        check_rep=False)
    y, stats_rows = mapped(x, p["router"].astype(jnp.float32),
                           p["w1"], p["w3"], p["w2"])
    if not return_stats:
        return y
    totals = jnp.sum(stats_rows, axis=0)             # (4,)
    sent, received, reassigned, admitted = (totals[0], totals[1],
                                            totals[2], totals[3])
    total_pairs = T * K
    stats = {
        # the shared moe_apply vocabulary (spawns + dropped == T·K):
        "dropped_frac": (total_pairs - admitted) / total_pairs,
        "spawns": admitted,
        "joins": 1,              # ONE barrier for the whole round (AFE)
        "rounds": 1,
        "total_slots": S * (E // S) * C_local,
        # the exchange vocabulary (SchedTelemetry.exchange):
        "sent": sent,
        "received": received,
        "reassigned": reassigned,
        "dropped": total_pairs - admitted,
        "n_shards": S,
        "lane_capacity": C_lane,
    }
    return y, stats


def ep_round(p: dict, cfg, x, *, mesh,
             telemetry: Optional[SchedTelemetry] = None,
             use_kernel: bool = False, impl: str = "all_to_all"):
    """One dispatch round under a DCAFE :class:`FinishScope`.

    The host-side entry for serving/benchmarks: runs the round, blocks
    on the result (the scope exit IS the round's single barrier), and
    folds the exchange counts into ``telemetry`` — ``spawns`` advance by
    the admitted pairs, ``joins`` by exactly one, and
    ``telemetry.exchange`` by the sent/received/reassigned/dropped
    counts.  Returns ``(y, stats)`` with host-int stats.

    Shard loss degrades, it does not abort: a
    :class:`~repro.sched.faults.ShardLossError` (raised by the
    fault-injection hook before the round posts, or by a caller-side
    health check) adds the shard to the round's dead set, bumps the
    retry telemetry, and re-attempts with that shard's lanes closed —
    the traffic re-routes to live shards via the existing
    ``dlbc_reroute`` before the collective.  A degraded round that
    completes counts ``exchange.degraded_rounds`` (and the stats carry
    ``degraded``/``dead_shards``); losing the LAST live shard, or the
    same shard twice, re-raises.  The loss check runs before ``posted``
    is counted, so posted == completed holds under degradation.
    """
    telemetry = telemetry if telemetry is not None else SchedTelemetry()
    plan = faults.active()
    dead: set = set()
    S = token_shards(x.shape[0], cfg.n_experts, mesh)
    # obs round edges (cat="ep"): ``round_posted`` when the round's
    # collectives are launched, ``round_completed`` when its single
    # barrier lands — the same two edges ``ExchangeCounters.posted`` /
    # ``completed`` count, so the trace↔telemetry cross-check covers
    # them.  Today the round blocks before returning (posted ==
    # completed at quiescence); the double-buffered overlap (ROADMAP)
    # will separate the edges without touching this vocabulary.
    # The in-jit legs (dispatch a2a → expert FFN → combine a2a) are one
    # XLA computation and not separately host-visible — the host phases
    # are launch (trace+compile+enqueue) and barrier (device work).
    while True:
        try:
            if plan is not None:
                shard = plan.lost_shard("ep.round")
                if shard is not None:
                    raise ShardLossError(shard)
            with obs.trace_span("ep", "round"):
                with FinishScope(telemetry):
                    obs.instant("ep", "round_posted")
                    telemetry.record_exchange(posted=1)
                    with obs.trace_span("ep", "launch"):
                        y, stats = ep_dispatch_combine(
                            p, cfg, x, mesh=mesh, use_kernel=use_kernel,
                            impl=impl, return_stats=True,
                            dead_shards=tuple(sorted(dead)))
                    with obs.trace_span("ep", "barrier"):
                        y = jax.block_until_ready(y)
                    stats = {k: (float(v) if k == "dropped_frac"
                                 else int(v))
                             for k, v in stats.items()}
            break
        except ShardLossError as e:
            sh = int(getattr(e, "shard", -1))
            if sh in dead or (S is not None and len(dead) + 1 >= S):
                raise  # same shard twice, or no live shard left
            dead.add(sh)
            telemetry.record_retry("ep.round")
            obs.instant("sched", "retry", args={"site": "ep.round"})
    obs.instant("ep", "round_completed")
    with telemetry.lock:
        telemetry.spawns += stats["spawns"]
    obs.instant("sched", "spawn", n=stats["spawns"])
    telemetry.record_exchange(
        sent=stats["sent"], received=stats["received"],
        reassigned=stats["reassigned"], dropped=stats["dropped"],
        completed=1, degraded=1 if dead else 0)
    obs_metrics.counter("ep.rounds").inc()
    # scalar stats only (benches/tests cast every value): degraded is a
    # 0/1 flag, dead_shards the count of lanes closed this round
    stats["degraded"] = int(bool(dead))
    stats["dead_shards"] = len(dead)
    if dead:
        obs_metrics.counter("ep.degraded_rounds").inc()
        # flight-recorder trigger: the round COMPLETED, but it ran with
        # lanes closed — dump the window while the evidence is fresh
        obs_monitor.on_ep_degraded(dead)
    return y, stats
