"""repro.ep — expert-parallel all-to-all dispatch.

The DCAFE ideas generalised across expert shards (ROADMAP: the
dispatch-buffer lever left open after the token-dim-sharding hypothesis
was refuted — EXPERIMENTS.md §Perf):

* :mod:`repro.ep.plan` — **DLBC as exchange planning**: the all-to-all
  send/recv count matrix (`ExchangePlan`) built from router assignments
  with the canonical ``chunk_plan`` arithmetic; over-capacity residuals
  are *reassigned* to expert shards with idle lane capacity before the
  collective instead of dropped per-shard.
* :mod:`repro.ep.collective` — the token exchange over the ``expert``
  mesh axis: capacity-padded lane buffers through ``jax.lax.all_to_all``
  (or a ``ppermute`` rotation ring), inside ``shard_map``.
* :mod:`repro.ep.dispatch` — **AFE as the round barrier**:
  ``ep_dispatch_combine`` (shard-local route → all-to-all → per-shard
  expert FFN → all-to-all combine) synchronises ONCE per dispatch round
  — ``ep_round`` runs it under a DCAFE ``FinishScope`` so telemetry
  proves ``joins == rounds``, with no per-expert or per-shard joins.

Consumers: ``repro.models.moe.moe_apply`` selects this path when the
config sets ``expert_parallel`` and the mesh carves an ``expert`` axis
(``launch.mesh.make_production_mesh(expert=...)``); ``bench_ep`` gates
the AFE join invariant and the zero-drop balanced-router claim in CI.
"""

from .collective import (  # noqa: F401
    EXPERT_AXIS, exchange, expert_axis_size, has_expert_axis, token_shards,
)
from .plan import ExchangePlan, lane_capacity, plan_exchange  # noqa: F401
from .dispatch import ep_dispatch_combine, ep_round  # noqa: F401
