"""Deterministic synthetic LM data pipeline with DLBC host scheduling.

Tokens are a pure function of (seed, step, shard) — restart-safe: resuming
from checkpoint step k regenerates exactly the batches k, k+1, …  Shard
preparation runs on the DLBC worker pool; batches are double-buffered
(prefetch thread) so host time hides behind device steps.

Multi-host: each process materialises only its addressable shard rows
(``process_index``-strided), matching the batch PartitionSpec.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from ..sched.executors import ThreadExecutor
from .pool import global_pool


def _shard_tokens(seed: int, step: int, shard: int, rows: int, seq: int,
                  vocab: int) -> np.ndarray:
    """Deterministic pseudo-token block (counter-based, restart-safe)."""
    rng = np.random.Philox(key=np.uint64(seed)
                           + (np.uint64(step) << np.uint64(20))
                           + np.uint64(shard))
    gen = np.random.Generator(rng)
    return gen.integers(0, vocab, size=(rows, seq), dtype=np.int32)


@dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 1234
    n_shards: int = 8          # host-side preparation parallelism
    prefetch: int = 2


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig, pool: Optional[ThreadExecutor] = None):
        self.cfg = cfg
        self.pool = pool or global_pool()
        assert cfg.global_batch % cfg.n_shards == 0
        self._buf: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def batch_at(self, step: int) -> dict:
        """Materialise the batch for a given step (restart-safe)."""
        c = self.cfg
        rows = c.global_batch // c.n_shards
        out = np.empty((c.global_batch, c.seq_len), np.int32)

        def fill(shard):
            out[shard * rows:(shard + 1) * rows] = _shard_tokens(
                c.seed, step, shard, rows, c.seq_len, c.vocab)

        self.pool.run_loop(list(range(c.n_shards)), fill)
        labels = np.roll(out, -1, axis=1)
        return {"tokens": out, "labels": labels}

    # -- prefetching iterator ---------------------------------------------------

    def start(self, first_step: int = 0):
        self._stop.clear()

        def producer():
            step = first_step
            while not self._stop.is_set():
                batch = self.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._buf.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=producer, daemon=True)
        self._thread.start()
        return self

    def __iter__(self) -> Iterator:
        while True:
            yield self._buf.get()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)
