"""DLBC worker pool — the paper's runtime policy on real host threads.

This is where DCAFE applies *literally* in a TPU stack: host-side work
(data shard preparation, checkpoint I/O, request batching) is CPU
task-parallelism.  The pool schedules a loop of ``n`` work items with the
paper's DLBC policy:

* read the idle-worker count (no lock — the paper's benign race);
* if idle workers exist, split the remaining items into
  ``eqChunk = remaining // (idle+1)`` chunks with the remainder spread
  one-per-chunk from the front and the **smallest chunk kept by the
  calling thread** (Fig. 6 lines 7–16);
* if none are idle, execute items serially, re-checking after each item
  and re-entering the parallel path when a worker frees up and ≥2 items
  remain (the serial block, Fig. 6 lines 26–31).

Counters mirror Fig. 10: ``tasks_spawned`` (async analogue) and
``joins`` (finish analogue) are exposed for the benchmarks.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass
class PoolStats:
    tasks_spawned: int = 0
    joins: int = 0
    serial_items: int = 0
    parallel_items: int = 0


class DLBCPool:
    def __init__(self, n_workers: int = 4):
        self.n_workers = n_workers
        self._q: "queue.Queue" = queue.Queue()
        self._idle = n_workers  # racy read by design (paper §3.2.1)
        self._idle_lock = threading.Lock()
        self.stats = PoolStats()
        self._threads = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(n_workers)
        ]
        for t in self._threads:
            t.start()

    # -- worker loop ---------------------------------------------------------

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, done = item
            with self._idle_lock:
                self._idle -= 1
            try:
                fn()
            finally:
                with self._idle_lock:
                    self._idle += 1
                done.set()

    def idle_workers(self) -> int:
        return self._idle  # intentionally unlocked read

    def shutdown(self):
        for _ in self._threads:
            self._q.put(None)

    # -- DLBC loop execution ---------------------------------------------------

    def run_loop(self, items: List, fn: Callable) -> None:
        """Execute ``fn(item)`` for every item under the DLBC policy."""
        i = 0
        n = len(items)
        while True:
            workers = self.idle_workers()
            if workers > 0:
                tot = workers + 1
                actualn = n - i
                eq = actualn // tot
                chunk_end = i + actualn - eq
                rem = actualn % tot + workers
                events = []
                while i < chunk_end:
                    kx = i + eq + rem // tot
                    ni, rem, i = i, rem - 1, kx

                    def task(lo=ni, hi=kx):
                        for j in range(lo, hi):
                            fn(items[j])

                    ev = threading.Event()
                    self._q.put((task, ev))
                    events.append(ev)
                    self.stats.tasks_spawned += 1
                    self.stats.parallel_items += kx - ni
                # parent block: the smallest chunk
                for j in range(chunk_end, n):
                    fn(items[j])
                    self.stats.parallel_items += 1
                for ev in events:
                    ev.wait()
                self.stats.joins += 1
                return
            # serial block with per-item re-check
            resumed = False
            while i < n:
                fn(items[i])
                self.stats.serial_items += 1
                i += 1
                if self.idle_workers() > 0 and (n - i) >= 2:
                    resumed = True
                    break
            if not resumed:
                return


_GLOBAL: Optional[DLBCPool] = None


def global_pool(n_workers: int = 4) -> DLBCPool:
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = DLBCPool(n_workers)
    return _GLOBAL
