"""Back-compat host worker pool — now a thin wrapper over ``repro.sched``.

The DLBC policy (idle-count read, Fig. 6 remainder-spread chunking,
re-probing serial fallback) lives in :mod:`repro.sched.policy`; the
thread pool itself is :class:`repro.sched.executors.ThreadExecutor`.
This module only keeps the historical ``DLBCPool`` name and its
``stats`` field shape (``tasks_spawned``/``joins``/``serial_items``/
``parallel_items``) alive for existing callers.
"""

from __future__ import annotations

from typing import Optional

from ..sched.executors import ThreadExecutor
from ..sched.telemetry import SchedTelemetry

# Old name for the stats record: SchedTelemetry carries the same fields
# (``tasks_spawned`` is an alias of ``spawns``).
PoolStats = SchedTelemetry


class DLBCPool(ThreadExecutor):
    """Deprecated alias of :class:`repro.sched.executors.ThreadExecutor`
    (DLBC is that executor's default policy)."""

    @property
    def stats(self) -> SchedTelemetry:
        return self.telemetry


_GLOBAL: Optional[DLBCPool] = None


def global_pool(n_workers: int = 4) -> DLBCPool:
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = DLBCPool(n_workers)
    return _GLOBAL
