"""Back-compat host worker pool — now a thin wrapper over ``repro.sched``.

The DLBC policy (idle-count read, Fig. 6 remainder-spread chunking,
re-probing serial fallback) lives in :mod:`repro.sched.policy`; the
thread pool itself is :class:`repro.sched.executors.ThreadExecutor`.
This module only keeps the historical ``DLBCPool`` name and its
``stats`` field shape (``tasks_spawned``/``joins``/``serial_items``/
``parallel_items``) alive for existing callers.

The pool can also run on the adaptive work-stealing substrate
(:class:`repro.sched.executors.WorkStealingExecutor`): ranges start
coarse and split on steal, with the grain decided by the scheduling
policy's :class:`~repro.sched.policy.GrainController` — no grain
arithmetic lives here.  Opt in per call (``stealing=True``) or
process-wide with ``REPRO_POOL_STEALING=1``.
"""

from __future__ import annotations

import os
from typing import Optional, Union

from ..sched.executors import ThreadExecutor, WorkStealingExecutor
from ..sched.telemetry import SchedTelemetry

# Old name for the stats record: SchedTelemetry carries the same fields
# (``tasks_spawned`` is an alias of ``spawns``).
PoolStats = SchedTelemetry


class DLBCPool(ThreadExecutor):
    """Deprecated alias of :class:`repro.sched.executors.ThreadExecutor`
    (DLBC is that executor's default policy)."""

    @property
    def stats(self) -> SchedTelemetry:
        return self.telemetry


class StealingPool(WorkStealingExecutor):
    """:class:`DLBCPool` on the adaptive work-stealing substrate: same
    ``run_loop``/policy surface, same ``stats`` shape, but committed
    chunks stay stealable (steal-driven splitting, helping joins)."""

    @property
    def stats(self) -> SchedTelemetry:
        return self.telemetry


_GLOBAL: Optional[Union[DLBCPool, StealingPool]] = None


def global_pool(n_workers: int = 4,
                stealing: Optional[bool] = None
                ) -> Union[DLBCPool, StealingPool]:
    """The process-wide host pool.  ``stealing`` picks the substrate for
    the pool's *creation* (first caller wins); ``None`` defers to the
    ``REPRO_POOL_STEALING`` environment switch."""
    global _GLOBAL
    if _GLOBAL is None:
        if stealing is None:
            stealing = os.environ.get("REPRO_POOL_STEALING", "0") == "1"
        _GLOBAL = StealingPool(n_workers) if stealing else DLBCPool(n_workers)
    return _GLOBAL
