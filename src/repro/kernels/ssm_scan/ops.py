"""Jit'd wrapper for the selective-scan kernel (interpret on CPU)."""

from __future__ import annotations

from functools import partial

import jax

from .ssm_scan import ssm_scan


@partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def ssm_scan_op(dA, dBx, C, *, chunk=128, block_d=256, interpret=False):
    return ssm_scan(dA, dBx, C, chunk=chunk, block_d=block_d,
                    interpret=interpret)


def ssm_scan_auto(dA, dBx, C, *, chunk=128, block_d=256):
    return ssm_scan_op(dA, dBx, C, chunk=chunk, block_d=block_d,
                       interpret=jax.default_backend() != "tpu")
