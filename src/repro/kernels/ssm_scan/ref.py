"""Pure-jnp oracle for the selective-scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(dA, dBx, C):
    """dA/dBx: (B, L, Di, N); C: (B, L, N) → y: (B, L, Di)."""

    def step(h, args):
        a, bx, c = args
        h = a * h + bx                       # (B, Di, N)
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    B, L, Di, N = dA.shape
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(dA, 1, 0).astype(jnp.float32),
         jnp.moveaxis(dBx, 1, 0).astype(jnp.float32),
         jnp.moveaxis(C, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(ys, 0, 1)  # (B, L, Di)
