"""Selective-scan (Mamba-1) Pallas TPU kernel.

Computes ``h_t = dA_t ⊙ h_{t-1} + dBx_t;  y_t = ⟨h_t, C_t⟩`` over the
sequence, with the recurrence carried across sequence chunks in VMEM
scratch: the grid's last dimension walks chunks **sequentially** on TPU,
so the (block_d, N) state persists between grid steps — HBM traffic is
exactly one read of (dA, dBx, C) and one write of y per chunk
(roofline-minimal for this memory-bound op).

Grid: (B, d_inner/block_d, L/chunk); within a chunk the recurrence is an
in-VMEM ``fori_loop`` over time (the (block_d, N) inner tile is
VPU-aligned; the chunk size is the DLBC eqChunk analogue balancing VMEM
footprint against grid-step overhead — hillclimbed in §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Pallas renamed TPUCompilerParams → CompilerParams across jax releases;
# resolve whichever versioned class the installed jax exposes.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _ssm_kernel(dA_ref, dBx_ref, C_ref, y_ref, h_scratch, *, chunk: int):
    """One (b, d-block, chunk) cell.

    dA_ref/dBx_ref: (chunk, block_d, N); C_ref: (chunk, N);
    y_ref: (chunk, block_d); h_scratch: (block_d, N) persistent state.
    """
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    dA = dA_ref[...].astype(jnp.float32)
    dBx = dBx_ref[...].astype(jnp.float32)
    C = C_ref[...].astype(jnp.float32)

    def body(t, h):
        h = dA[t] * h + dBx[t]                    # (block_d, N)
        y_ref[t, :] = jnp.sum(h * C[t][None, :], axis=-1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_scratch[...])
    h_scratch[...] = h


def ssm_scan(
    dA: jnp.ndarray,    # (B, L, Di, N) fp32
    dBx: jnp.ndarray,   # (B, L, Di, N) fp32
    C: jnp.ndarray,     # (B, L, N) fp32
    *,
    chunk: int = 128,
    block_d: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns y: (B, L, Di) fp32 (caller adds the D·x skip and gating)."""
    B, L, Di, N = dA.shape
    chunk = min(chunk, L)
    block_d = min(block_d, Di)
    assert L % chunk == 0 and Di % block_d == 0, (L, chunk, Di, block_d)
    grid = (B, Di // block_d, L // chunk)
    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, block_d, N),
                         lambda b, d, c: (b, c, d, 0)),
            pl.BlockSpec((None, chunk, block_d, N),
                         lambda b, d, c: (b, c, d, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, d, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, block_d),
                               lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, L, Di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(dA, dBx, C)
