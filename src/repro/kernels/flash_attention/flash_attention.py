"""Flash-attention forward Pallas TPU kernel with DLBC-balanced causal
scheduling.

TPU adaptation of the paper's load-balancing insight: causal attention is
an unbalanced triangular loop (query block i needs i+1 KV blocks).  The
``masked`` XLA path does the full rectangle and masks (2× FLOP waste —
the LC-style static chunking).  This kernel bounds the KV loop *per query
block* (``hi = i+1`` blocks) so every grid step does exactly the useful
work — the DLBC "spawn work only where it exists" policy on the MXU grid.
Sliding-window attention additionally lower-bounds the loop
(``lo = i - w/blk``), making long-context cells O(S·w).

Grid: (batch·kv_heads, q_blocks); the KV loop runs inside the kernel via
``jax.lax.fori_loop`` over VMEM blocks fetched with explicit BlockSpec
index maps.  Online softmax state (m, l, acc) lives in VMEM scratch.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_k: int,
                 causal: bool, window: int, sm_scale: float):
    """One (bh, q_block) grid cell.

    q_ref: (block_q, G, dh) — G = query heads per kv head (GQA folded).
    k_ref/v_ref: (seq_k, dh) — full KV stream for this bh (VMEM-resident
    blocks are sliced inside the loop).
    """
    block_q, G, dh = q_ref.shape
    qi = pl.program_id(1)
    q_lo = qi * block_q

    q = q_ref[...].astype(jnp.float32) * sm_scale  # (bq, G, dh)

    nk = seq_k // block_k
    if causal:
        # DLBC-balanced bound: only blocks that intersect the triangle.
        hi = jnp.minimum((q_lo + block_q + block_k - 1) // block_k + 0, nk)
        hi = (q_lo + block_q + block_k - 1) // block_k
        hi = jnp.minimum(hi, nk)
    else:
        hi = nk
    if window > 0:
        lo = jnp.maximum((q_lo - (window - 1)) // block_k, 0)
    else:
        lo = 0

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(
            q.reshape(block_q * G, dh), k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(block_q, G, block_k)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, 1, block_k), 0)
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, 1, block_k), 2)
        mask = jnp.ones_like(qpos, dtype=jnp.bool_)
        if causal:
            mask = mask & (qpos >= kpos)
        if window > 0:
            mask = mask & (qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.reshape(block_q * G, block_k), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(block_q, G, dh)
        acc_new = acc * scale[..., None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, G), jnp.float32)
    a0 = jnp.zeros((block_q, G, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, S, H, dh)
    k: jnp.ndarray,  # (B, T, KV, dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    sm_scale = dh ** -0.5

    # Layout: (B·KV, S, G, dh) so each grid row owns one kv-head stream.
    qr = q.reshape(B, S, KV, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B * KV, S, G, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, T, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, T, dh)

    grid = (B * KV, S // block_q)
    kernel = functools.partial(
        _attn_kernel, block_k=block_k, seq_k=T, causal=causal,
        window=window, sm_scale=sm_scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, G, dh), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((None, T, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, T, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, G, dh),
                               lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, S, G, dh), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KV, S, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, dh)
