"""Jit'd public wrapper for the flash-attention kernel.

On CPU (this container) the kernel runs in interpret mode; on TPU it
compiles to Mosaic.  ``flash_attention_auto`` picks per backend.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention_op(q, k, v, *, causal=True, window=0, block_q=128,
                       block_k=128, interpret=False):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)


def flash_attention_auto(q, k, v, *, causal=True, window=0,
                         block_q=128, block_k=128):
    return flash_attention_op(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=not _on_tpu())
