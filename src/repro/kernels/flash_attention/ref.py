"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,S,H,dh); k/v: (B,T,KV,dh) — naive full-matrix softmax."""
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bskgt", qg, kf) * dh ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (qpos >= kpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgt,btkd->bskgd", p, vf)
    return o.reshape(B, S, H, dh).astype(q.dtype)
