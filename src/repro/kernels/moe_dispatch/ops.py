"""Jit'd wrapper for the grouped expert-FFN kernel (interpret on CPU)."""

from __future__ import annotations

from functools import partial

import jax

from .moe_gmm import moe_gmm


@partial(jax.jit, static_argnames=("block_c", "block_f", "interpret"))
def moe_gmm_op(buf, w1, w3, w2, *, block_c=128, block_f=128,
               interpret=False):
    return moe_gmm(buf, w1, w3, w2, block_c=block_c, block_f=block_f,
                   interpret=interpret)


def moe_gmm_auto(buf, w1, w3, w2, *, block_c=128, block_f=128):
    return moe_gmm_op(buf, w1, w3, w2, block_c=block_c, block_f=block_f,
                      interpret=jax.default_backend() != "tpu")
