"""MoE dispatch ops: token routing into capacity buffers + expert FFN.

This module owns the *mechanics* of MoE dispatch — scattering admitted
(token, choice) pairs into per-expert ``(E, C, d)`` capacity buffers,
running the expert FFN (XLA einsum or the Pallas grouped-matmul kernel),
and gathering/combining the results.  The *admission decision* (which
pairs get a slot, which overflow) is made by the caller through
:class:`repro.sched.capacity.ExpertCapacityProvider` — the one DLBC/LC
drop arithmetic shared with every other execution surface; no private
drop policy lives here or in :mod:`repro.models.moe` anymore.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .moe_gmm import moe_gmm


@partial(jax.jit, static_argnames=("block_c", "block_f", "interpret"))
def moe_gmm_op(buf, w1, w3, w2, *, block_c=128, block_f=128,
               interpret=False):
    return moe_gmm(buf, w1, w3, w2, block_c=block_c, block_f=block_f,
                   interpret=interpret)


def moe_gmm_auto(buf, w1, w3, w2, *, block_c=128, block_f=128):
    return moe_gmm_op(buf, w1, w3, w2, block_c=block_c, block_f=block_f,
                      interpret=jax.default_backend() != "tpu")


def dispatch_tokens(x, keep, ids, pos, E: int, C: int):
    """Scatter admitted tokens into (E, C, d) buffers.

    ``keep`` is the admission mask from the capacity provider; dropped
    pairs scatter a zero contribution (their slot index is clamped).
    Returns (buf, slot) — ``slot`` is reused by :func:`combine_tokens`.
    """
    T, d = x.shape
    K = ids.shape[1]
    slot = ids * C + jnp.minimum(pos, C - 1)  # (T, K)
    keepf = keep.astype(x.dtype)
    buf = jnp.zeros((E * C, d), x.dtype)
    # Slots are unique per (expert, pos) by construction → add == set.
    buf = buf.at[slot.reshape(-1)].add(
        (x[:, None, :] * keepf[..., None]).reshape(T * K, d))
    return buf.reshape(E, C, d), slot


def combine_tokens(out, slot, gates, keep, gate_dtype=None):
    """Gather expert outputs back to token order and gate-combine."""
    E, C, d = out.shape
    T, K = slot.shape
    gathered = out.reshape(E * C, d)[slot.reshape(-1)].reshape(T, K, d)
    w = (gates * keep).astype(gate_dtype or gathered.dtype)
    return jnp.einsum("tkd,tk->td", gathered, w)


def _tile(n: int, cap: int = 128) -> int:
    """Largest block size ≤ cap that divides n (n ≥ 1 ⇒ always exists)."""
    for b in range(min(cap, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def expert_ffn(buf, p: dict, act: str, use_kernel: bool = False):
    """The (E, C, d) × expert-weights contraction: XLA einsum by default,
    the Pallas grouped-matmul kernel when ``use_kernel`` (SwiGLU only —
    gelu experts fall back to einsum)."""
    E, C, d = buf.shape
    if use_kernel and act == "swiglu":
        f = p["w1"].shape[-1]
        return moe_gmm_auto(buf, p["w1"].astype(buf.dtype),
                            p["w3"].astype(buf.dtype),
                            p["w2"].astype(buf.dtype),
                            block_c=_tile(C), block_f=_tile(f))
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"])) * \
            jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w1"]))
    return jnp.einsum("ecf,efd->ecd", h, p["w2"])


def dispatch_combine(x, gates, ids, pos, keep, E: int, C: int, p: dict,
                     act: str, use_kernel: bool = False):
    """dispatch → expert FFN → combine, for pre-decided admissions."""
    buf, slot = dispatch_tokens(x, keep, ids, pos, E, C)
    out = expert_ffn(buf, p, act, use_kernel=use_kernel)
    return combine_tokens(out, slot, gates, keep, gate_dtype=x.dtype)
