"""Pure-jnp oracle for the grouped expert-FFN kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gmm_ref(buf, w1, w3, w2):
    """buf: (E, C, d); w1/w3: (E, d, f); w2: (E, f, d)."""
    x = buf.astype(jnp.float32)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w1.astype(jnp.float32))) \
        * jnp.einsum("ecd,edf->ecf", x, w3.astype(jnp.float32))
    return jnp.einsum("ecf,efd->ecd", h,
                      w2.astype(jnp.float32)).astype(buf.dtype)
