"""Grouped expert-FFN Pallas kernel: fused SwiGLU over capacity buffers.

Computes ``out[e] = (silu(buf[e]·w1[e]) ⊙ (buf[e]·w3[e])) · w2[e]`` for
every expert — the compute hot-spot behind the DLBC/LC MoE dispatch
(repro/models/moe.py builds the (E, C, d) buffers; this kernel is the
(E,C,d)×(E,d,f)×(E,f,d) contraction with explicit VMEM tiling).

Grid: (E, C/block_c).  Per grid cell the full (d, f_blk) weight slices
stream through VMEM via an inner fori loop over f blocks, accumulating
the down-projection in fp32 scratch — d and f block sizes are chosen so
the working set  block_c·d + d·block_f + block_c·block_f  fits VMEM with
MXU-aligned (×128) dims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(buf_ref, w1_ref, w3_ref, w2_ref, o_ref, *, block_f: int,
                d_ff: int):
    """buf_ref: (block_c, d); w*_ref: (d, f)/(f, d); o_ref: (block_c, d)."""
    x = buf_ref[...].astype(jnp.float32)
    nf = d_ff // block_f
    d = x.shape[-1]

    def body(j, acc):
        w1 = pl.load(w1_ref, (slice(None), pl.dslice(j * block_f, block_f))
                     ).astype(jnp.float32)
        w3 = pl.load(w3_ref, (slice(None), pl.dslice(j * block_f, block_f))
                     ).astype(jnp.float32)
        w2 = pl.load(w2_ref, (pl.dslice(j * block_f, block_f), slice(None))
                     ).astype(jnp.float32)
        h = jax.nn.silu(x @ w1) * (x @ w3)       # (block_c, block_f)
        return acc + h @ w2                      # (block_c, d)

    acc = jnp.zeros_like(x)
    acc = jax.lax.fori_loop(0, nf, body, acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def moe_gmm(
    buf: jnp.ndarray,   # (E, C, d)
    w1: jnp.ndarray,    # (E, d, f)
    w3: jnp.ndarray,    # (E, d, f)
    w2: jnp.ndarray,    # (E, f, d)
    *,
    block_c: int = 128,
    block_f: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    E, C, d = buf.shape
    f = w1.shape[-1]
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    assert C % block_c == 0 and f % block_f == 0, (C, f, block_c, block_f)
    kernel = functools.partial(_gmm_kernel, block_f=block_f, d_ff=f)
    return pl.pallas_call(
        kernel,
        grid=(E, C // block_c),
        in_specs=[
            pl.BlockSpec((None, block_c, d), lambda e, c: (e, c, 0)),
            pl.BlockSpec((None, d, f), lambda e, c: (e, 0, 0)),
            pl.BlockSpec((None, d, f), lambda e, c: (e, 0, 0)),
            pl.BlockSpec((None, f, d), lambda e, c: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_c, d), lambda e, c: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), buf.dtype),
        interpret=interpret,
    )(buf, w1, w3, w2)
