from . import blocks, layers, moe, model, ssm  # noqa: F401
from .model import (  # noqa: F401
    cache_shapes, decode_step, forward, init_cache, init_params, loss_fn,
    param_shapes,
)
