"""Mixture-of-Experts with DLBC-balanced dispatch.

The paper's DLBC policy, mapped onto MoE token routing (DESIGN.md §2.2):

* **LC dispatch** (`moe_dispatch="lc"`) — the static-chunking baseline:
  classic GShard top-k with fixed per-expert capacity
  ``C = ceil(T·top_k/E)·cf``; tokens whose position in their chosen expert
  exceeds C are **dropped** (the residual/identity path carries them).
  This is the "chunking oblivious to actual load" failure mode the paper
  attributes to LC.

* **DLBC dispatch** (`moe_dispatch="dlbc"`) — two-round load balancing:
  round 1 fills the eqChunk-balanced capacity; overflow tokens are
  *re-routed* in round 2 to their next-choice expert against the residual
  capacity — the "re-check for idle workers after serial iterations"
  mechanism in static-shape SPMD form.  Same total buffer, strictly fewer
  dropped tokens (measured in tests/benchmarks).

Admission (who gets a slot, who overflows) is decided by
:class:`repro.sched.capacity.ExpertCapacityProvider` — the shared
DLBC/LC engine's view of per-expert slots; this module no longer owns
any drop arithmetic.  The dispatch/FFN/combine mechanics live next to
the Pallas kernel in :mod:`repro.kernels.moe_dispatch.ops` (einsum on
the XLA path, the grouped-matmul kernel with ``use_kernel=True``).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..kernels.moe_dispatch.ops import dispatch_combine
from ..sched import ExpertCapacityProvider
from .layers import _norm_init


def moe_shapes(cfg, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": jax.ShapeDtypeStruct((d, E), jnp.float32),
        "w1": jax.ShapeDtypeStruct((E, d, f), dtype),
        "w3": jax.ShapeDtypeStruct((E, d, f), dtype),
        "w2": jax.ShapeDtypeStruct((E, f, d), dtype),
    }


def moe_init(key, cfg, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": _norm_init(k0, (d, E), d ** -0.5, jnp.float32),
        "w1": _norm_init(k1, (E, d, f), d ** -0.5, dtype),
        "w3": _norm_init(k3, (E, d, f), d ** -0.5, dtype),
        "w2": _norm_init(k2, (E, f, d), f ** -0.5, dtype),
    }


def capacity(T: int, E: int, top_k: int, cf: float) -> int:
    c = int(math.ceil(T * top_k / E * cf))
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for TPU lane alignment


def _positions_in_expert(expert_ids: jnp.ndarray, E: int,
                         base: jnp.ndarray = None) -> jnp.ndarray:
    """Running slot index of each (token, choice) within its expert.

    expert_ids: (T, K) int32.  Counts in choice-major order (all k=0 first)
    so primary choices win slots — the paper's "current worker gets the
    smallest chunk" priority rule for remainder distribution.
    ``base``: (E,) pre-occupied slots per expert (round 2).
    """
    T, K = expert_ids.shape
    flat = expert_ids.T.reshape(-1)  # choice-major (K*T,)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)  # (K*T, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position among same-expert slots
    pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    if base is not None:
        pos = pos + base[flat]
    return pos.reshape(K, T).T  # (T, K)


def _expert_load(expert_ids: jnp.ndarray, mask: jnp.ndarray, E: int):
    flat = expert_ids.reshape(-1)
    return jnp.sum(
        jax.nn.one_hot(flat, E, dtype=jnp.int32)
        * mask.reshape(-1)[:, None], axis=0)


def dlbc_reroute(ids, gates, probs, pos1, keep1, load, provider,
                 n_groups: int, expert_open, group_of=None):
    """The DLBC round-2 re-route, shared by single-host dispatch (a
    "group" is an expert) and EP lane planning (a group is an expert
    *shard* — :mod:`repro.ep.dispatch`, where any change to this idiom
    must keep the EP ↔ single-host equivalence tests green).

    Overflow (token, choice) pairs re-route once to the token's best
    expert among ``expert_open`` (the (E,) availability mask derived
    from the provider's residual), take positions after the ``load``
    already admitted per group, and are re-admitted against the same
    provider.  Returns ``(ids_f, group_f, pos_f, keep, gates_f,
    overflow)`` — rerouted pairs weighted by the probability of the
    expert that actually serves them (router-consistent combine).
    """
    group_of = group_of or (lambda i: i)
    overflow = ~keep1                                  # (T, K)
    avail = probs * expert_open[None, :]
    alt_ids = jnp.argmax(avail, axis=-1).astype(jnp.int32)  # (T,)
    ids2 = jnp.where(overflow, alt_ids[:, None], ids)
    group2 = group_of(ids2)
    pos2 = _positions_in_expert(
        jnp.where(overflow, group2, n_groups),  # only overflow counts
        n_groups + 1,
        base=jnp.concatenate([load, jnp.zeros((1,), load.dtype)]))
    ids_f = jnp.where(overflow, ids2, ids)
    group_f = jnp.where(overflow, group2, group_of(ids))
    pos_f = jnp.where(overflow, pos2, pos1)
    keep = provider.admit_mask(pos_f)
    alt_gate = jnp.take_along_axis(probs, ids_f.astype(jnp.int32),
                                   axis=-1).astype(gates.dtype)
    gates_f = jnp.where(overflow, alt_gate, gates)
    return ids_f, group_f, pos_f, keep, gates_f, overflow


def route(x: jnp.ndarray, router_w: jnp.ndarray, top_k: int):
    """x: (T, d) → (gates (T,K) fp32, expert_ids (T,K) int32, full probs)."""
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, ids.astype(jnp.int32), probs


def moe_apply(p: dict, cfg, x: jnp.ndarray,
              return_stats: bool = False, use_kernel: bool = False):
    """x: (B, S, d) or (T, d).  Dispatch per cfg.moe_dispatch."""
    # NOTE (refuted hypothesis — EXPERIMENTS.md §Perf iteration 7):
    # constraining the flattened token dim to (data × model) sharding was
    # expected to shrink dispatch buffers 16×; measured: GSPMD reshards
    # the slot scatter/gather with MORE collectives (mixtral train_4k
    # collective term 62 s → 158 s).  The principled fix is the
    # expert-parallel all-to-all dispatch below (repro.ep): explicit
    # token exchange between expert shards instead of letting the
    # partitioner guess.
    orig_shape = x.shape
    if x.ndim == 3:
        x = x.reshape(-1, x.shape[-1])
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    if cfg.expert_parallel:
        # Expert-parallel all-to-all dispatch (repro.ep): taken when the
        # mesh carves an "expert" axis whose size divides E (the same
        # static predicate that shards expert weights E → "expert", so
        # the single-host gather never runs over expert-sharded weights).
        # A token count not divisible by S — ragged last serving batch —
        # is zero-padded up to the next multiple and sliced back: at
        # most S-1 pad tokens ride the round, a negligible capacity
        # perturbation vs falling back to the resharded gather.
        from ..distributed.sharding import current_mesh, expert_axis_size
        mesh = current_mesh()
        S = expert_axis_size(mesh)
        if S > 1 and E % S == 0:
            from ..ep.dispatch import ep_dispatch_combine
            pad = (-T) % S
            xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
            y, ep_stats = ep_dispatch_combine(
                p, cfg, xp, mesh=mesh, use_kernel=use_kernel,
                return_stats=True)
            y = (y[:T] if pad else y).reshape(orig_shape)
            if return_stats:
                ep_stats["padded_tokens"] = pad
                return y, ep_stats
            return y
    C = capacity(T, E, K, cfg.moe_capacity_factor)
    cap = ExpertCapacityProvider(E, C)
    gates, ids, probs = route(x, p["router"], K)
    rounds = 1

    if cfg.moe_dispatch == "lc":
        # Static chunking: one admission round against fixed capacity;
        # overflow is dropped (the residual path carries those tokens).
        pos = _positions_in_expert(ids, E)
        keep = cap.admit_mask(pos)
        y = dispatch_combine(x, gates, ids, pos, keep, E, C, p, cfg.act,
                             use_kernel=use_kernel)
        dropped = jnp.sum(~keep)
    else:
        # --- DLBC round 1: eqChunk-balanced primary dispatch -------------
        pos1 = _positions_in_expert(ids, E)
        keep1 = cap.admit_mask(pos1)
        # --- round 2: overflow re-routed to the next-best expert --------
        # (the serial block's "re-check for idle workers": tokens that
        # found their expert full try the least-loaded alternative).
        rounds = 2
        load = _expert_load(ids, keep1, E)          # (E,) used slots
        resid = cap.residual(load)                  # idle capacity
        ids_final, _, pos_final, keep, gates_final, _ = dlbc_reroute(
            ids, gates, probs, pos1, keep1, load, cap, E,
            expert_open=resid > 0)
        y = dispatch_combine(x, gates_final, ids_final, pos_final, keep, E,
                             C, p, cfg.act, use_kernel=use_kernel)
        dropped = jnp.sum(~keep)

    y = y.reshape(orig_shape)
    if return_stats:
        frac = dropped / (T * K)
        # SchedTelemetry vocabulary for the host side: an admitted
        # (token, choice) pair is a spawn; the single gate-combine is the
        # join regardless of how many admission rounds ran.
        return y, {"dropped_frac": frac, "spawns": jnp.sum(keep),
                   "joins": 1, "rounds": rounds,
                   "total_slots": cap.total()}
    return y


def moe_ref(p: dict, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Dense oracle: every token through its top-k experts, no capacity.
    The no-drop ground truth that dispatch quality is measured against."""
    orig_shape = x.shape
    if x.ndim == 3:
        x = x.reshape(-1, x.shape[-1])
    gates, ids, _ = route(x, p["router"], cfg.top_k)
    T, d = x.shape
    outs = []
    for e in range(cfg.n_experts):
        if cfg.act == "swiglu":
            h = jax.nn.silu(x @ p["w1"][e]) * (x @ p["w3"][e])
        else:
            h = jax.nn.gelu(x @ p["w1"][e])
        outs.append(h @ p["w2"][e])
    dense = jnp.stack(outs, axis=1)  # (T, E, d)
    sel = jnp.take_along_axis(dense, ids[..., None], axis=1)  # (T, K, d)
    return jnp.einsum("tkd,tk->td", sel, gates.astype(x.dtype)).reshape(
        orig_shape)
