"""Mixture-of-Experts with DLBC-balanced dispatch.

The paper's DLBC policy, mapped onto MoE token routing (DESIGN.md §2.2):

* **LC dispatch** (`moe_dispatch="lc"`) — the static-chunking baseline:
  classic GShard top-k with fixed per-expert capacity
  ``C = ceil(T·top_k/E)·cf``; tokens whose position in their chosen expert
  exceeds C are **dropped** (the residual/identity path carries them).
  This is the "chunking oblivious to actual load" failure mode the paper
  attributes to LC.

* **DLBC dispatch** (`moe_dispatch="dlbc"`) — two-round load balancing:
  round 1 fills the eqChunk-balanced capacity; overflow tokens are
  *re-routed* in round 2 to their next-choice expert against the residual
  capacity — the "re-check for idle workers after serial iterations"
  mechanism in static-shape SPMD form.  Same total buffer, strictly fewer
  dropped tokens (measured in tests/benchmarks).

Admission (who gets a slot, who overflows) is decided by
:class:`repro.sched.capacity.ExpertCapacityProvider` — the shared
DLBC/LC engine's view of per-expert slots; this module no longer owns
any drop arithmetic.  The dispatch/FFN/combine mechanics live next to
the Pallas kernel in :mod:`repro.kernels.moe_dispatch.ops` (einsum on
the XLA path, the grouped-matmul kernel with ``use_kernel=True``).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..kernels.moe_dispatch.ops import dispatch_combine
from ..sched import ExpertCapacityProvider
from .layers import _norm_init


def moe_shapes(cfg, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": jax.ShapeDtypeStruct((d, E), jnp.float32),
        "w1": jax.ShapeDtypeStruct((E, d, f), dtype),
        "w3": jax.ShapeDtypeStruct((E, d, f), dtype),
        "w2": jax.ShapeDtypeStruct((E, f, d), dtype),
    }


def moe_init(key, cfg, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": _norm_init(k0, (d, E), d ** -0.5, jnp.float32),
        "w1": _norm_init(k1, (E, d, f), d ** -0.5, dtype),
        "w3": _norm_init(k3, (E, d, f), d ** -0.5, dtype),
        "w2": _norm_init(k2, (E, f, d), f ** -0.5, dtype),
    }


def capacity(T: int, E: int, top_k: int, cf: float) -> int:
    c = int(math.ceil(T * top_k / E * cf))
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for TPU lane alignment


def _positions_in_expert(expert_ids: jnp.ndarray, E: int,
                         base: jnp.ndarray = None) -> jnp.ndarray:
    """Running slot index of each (token, choice) within its expert.

    expert_ids: (T, K) int32.  Counts in choice-major order (all k=0 first)
    so primary choices win slots — the paper's "current worker gets the
    smallest chunk" priority rule for remainder distribution.
    ``base``: (E,) pre-occupied slots per expert (round 2).
    """
    T, K = expert_ids.shape
    flat = expert_ids.T.reshape(-1)  # choice-major (K*T,)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)  # (K*T, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position among same-expert slots
    pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    if base is not None:
        pos = pos + base[flat]
    return pos.reshape(K, T).T  # (T, K)


def _expert_load(expert_ids: jnp.ndarray, mask: jnp.ndarray, E: int):
    flat = expert_ids.reshape(-1)
    return jnp.sum(
        jax.nn.one_hot(flat, E, dtype=jnp.int32)
        * mask.reshape(-1)[:, None], axis=0)


def route(x: jnp.ndarray, router_w: jnp.ndarray, top_k: int):
    """x: (T, d) → (gates (T,K) fp32, expert_ids (T,K) int32, full probs)."""
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, ids.astype(jnp.int32), probs


def moe_apply(p: dict, cfg, x: jnp.ndarray,
              return_stats: bool = False, use_kernel: bool = False):
    """x: (B, S, d) or (T, d).  Dispatch per cfg.moe_dispatch."""
    # NOTE (refuted hypothesis — EXPERIMENTS.md §Perf iteration 7):
    # constraining the flattened token dim to (data × model) sharding was
    # expected to shrink dispatch buffers 16×; measured: GSPMD reshards
    # the slot scatter/gather with MORE collectives (mixtral train_4k
    # collective term 62 s → 158 s).  The principled fix is expert-parallel
    # all-to-all dispatch (tokens exchanged between expert shards), left
    # as the next lever with napkin math in §Perf.
    orig_shape = x.shape
    if x.ndim == 3:
        x = x.reshape(-1, x.shape[-1])
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(T, E, K, cfg.moe_capacity_factor)
    cap = ExpertCapacityProvider(E, C)
    gates, ids, probs = route(x, p["router"], K)
    rounds = 1

    if cfg.moe_dispatch == "lc":
        # Static chunking: one admission round against fixed capacity;
        # overflow is dropped (the residual path carries those tokens).
        pos = _positions_in_expert(ids, E)
        keep = cap.admit_mask(pos)
        y = dispatch_combine(x, gates, ids, pos, keep, E, C, p, cfg.act,
                             use_kernel=use_kernel)
        dropped = jnp.sum(~keep)
    else:
        # --- DLBC round 1: eqChunk-balanced primary dispatch -------------
        pos1 = _positions_in_expert(ids, E)
        keep1 = cap.admit_mask(pos1)
        # --- round 2: overflow re-routed to the next-best expert --------
        # (the serial block's "re-check for idle workers": tokens that
        # found their expert full try the least-loaded alternative).
        rounds = 2
        load = _expert_load(ids, keep1, E)          # (E,) used slots
        resid = cap.residual(load)                  # idle capacity
        overflow = ~keep1                           # (T, K)
        # next-best expert = argmax of probs weighted by residual capacity
        avail = probs * (resid[None, :] > 0)
        alt_ids = jnp.argmax(avail, axis=-1).astype(jnp.int32)  # (T,)
        ids2 = jnp.where(overflow, alt_ids[:, None], ids)
        pos2 = _positions_in_expert(
            jnp.where(overflow, ids2, E),  # only overflow tokens count
            E + 1, base=jnp.concatenate([load, jnp.zeros((1,), jnp.int32)]),
        )
        ids_final = jnp.where(overflow, ids2, ids)
        pos_final = jnp.where(overflow, pos2, pos1)
        keep = cap.admit_mask(pos_final)
        # Rerouted tokens are weighted by the probability of the expert
        # that actually serves them (router-consistent combine).
        alt_gate = jnp.take_along_axis(probs, ids_final.astype(jnp.int32),
                                       axis=-1).astype(gates.dtype)
        gates_final = jnp.where(overflow, alt_gate, gates)
        y = dispatch_combine(x, gates_final, ids_final, pos_final, keep, E,
                             C, p, cfg.act, use_kernel=use_kernel)
        dropped = jnp.sum(~keep)

    y = y.reshape(orig_shape)
    if return_stats:
        frac = dropped / (T * K)
        # SchedTelemetry vocabulary for the host side: an admitted
        # (token, choice) pair is a spawn; the single gate-combine is the
        # join regardless of how many admission rounds ran.
        return y, {"dropped_frac": frac, "spawns": jnp.sum(keep),
                   "joins": 1, "rounds": rounds,
                   "total_slots": cap.total()}
    return y


def moe_ref(p: dict, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Dense oracle: every token through its top-k experts, no capacity.
    The no-drop ground truth that dispatch quality is measured against."""
    orig_shape = x.shape
    if x.ndim == 3:
        x = x.reshape(-1, x.shape[-1])
    gates, ids, _ = route(x, p["router"], cfg.top_k)
    T, d = x.shape
    outs = []
    for e in range(cfg.n_experts):
        if cfg.act == "swiglu":
            h = jax.nn.silu(x @ p["w1"][e]) * (x @ p["w3"][e])
        else:
            h = jax.nn.gelu(x @ p["w1"][e])
        outs.append(h @ p["w2"][e])
    dense = jnp.stack(outs, axis=1)  # (T, E, d)
    sel = jnp.take_along_axis(dense, ids[..., None], axis=1)  # (T, K, d)
    return jnp.einsum("tkd,tk->td", sel, gates.astype(x.dtype)).reshape(
        orig_shape)
