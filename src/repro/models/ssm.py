"""Mamba-1 selective SSM block (falcon-mamba / hymba mamba heads).

TPU adaptation: the recurrence is computed as a *chunked* scan —
sequential ``lax.scan`` over sequence chunks carrying the (d_inner, N)
state, with a parallel ``associative_scan`` inside each chunk.  The chunk
size is the DLBC ``eqChunk`` analogue: it balances VMEM working-set
against scan latency (hillclimbed in EXPERIMENTS.md §Perf).

The same math has a Pallas kernel (repro/kernels/ssm_scan) for the
single-chunk hot loop; this module is the lowering used by the dry-run
and the pure-jnp oracle.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import _norm_init, dense_apply, dense_init, dense_shapes


def ssm_shapes(cfg, dtype) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, cw = cfg.dt_rank, cfg.conv_width
    return {
        "in_proj": dense_shapes(d, 2 * di, False, dtype),
        "conv_w": jax.ShapeDtypeStruct((cw, di), dtype),
        "conv_b": jax.ShapeDtypeStruct((di,), dtype),
        "x_proj": dense_shapes(di, dtr + 2 * n, False, dtype),
        "dt_proj": dense_shapes(dtr, di, True, dtype),
        "A_log": jax.ShapeDtypeStruct((di, n), jnp.float32),
        "D": jax.ShapeDtypeStruct((di,), jnp.float32),
        "out_proj": dense_shapes(di, d, False, dtype),
    }


def ssm_init(key, cfg, dtype) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, cw = cfg.dt_rank, cfg.conv_width
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, False, dtype),
        "conv_w": _norm_init(ks[1], (cw, di), cw ** -0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * n, False, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, True, dtype),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, False, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over time.  x: (B, L, Di); w: (cw, Di).
    state: (B, cw-1, Di) trailing inputs from the previous step (decode).
    Returns (y, new_state)."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, L+cw-1, Di)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(cw):
        y = y + xp[:, i : i + x.shape[1]].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    new_state = xp[:, -(cw - 1):] if cw > 1 else jnp.zeros(
        (x.shape[0], 0, x.shape[2]), x.dtype)
    return y.astype(x.dtype), new_state


def _ssm_params(p: dict, cfg, x: jnp.ndarray):
    """Input-dependent (dt, B, C) and the discretised (dA, dBx)."""
    dtr, n = cfg.dt_rank, cfg.ssm_state
    dbc = dense_apply(p["x_proj"], x)  # (..., dtr + 2n)
    dt, Bc, Cc = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dense_apply(p["dt_proj"], dt).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])  # (Di, N)
    dA = jnp.exp(dt[..., None] * A)                       # (..., Di, N)
    dBx = (dt * x.astype(jnp.float32))[..., None] * \
        Bc[..., None, :].astype(jnp.float32)              # (..., Di, N)
    return dA, dBx, Cc.astype(jnp.float32)


def ssm_scan_chunked(p: dict, cfg, x: jnp.ndarray, chunk: int = 256):
    """Selective scan over (B, L, Di) input. Returns (B, L, Di).

    The input-dependent (dA, dBx, C) tensors — (B, L, Di, N) fp32, i.e.
    4·N× the activation size — are computed PER CHUNK inside the scan and
    rematerialised on the backward pass: materialising them for the whole
    sequence is what blew falcon-mamba train_4k past HBM (26.9 GB/device
    → §Perf iteration 3).  Working set: one (B, chunk, Di, N) block.
    """
    B, L, di = x.shape
    n = cfg.ssm_state
    chunk = min(chunk, L)
    assert L % chunk == 0, (L, chunk)
    nchunks = L // chunk
    xc = jnp.moveaxis(x.reshape(B, nchunks, chunk, di), 1, 0)

    def combine(a, b):
        # (A1, X1) ∘ (A2, X2) = (A2·A1, A2·X1 + X2)
        return a[0] * b[0], a[1] * b[0] + b[1]

    @jax.checkpoint
    def chunk_body(h, x_c):
        dA_c, dBx_c, C_c = _ssm_params(p, cfg, x_c)  # (B, chunk, Di, N)
        A_acc, X_acc = jax.lax.associative_scan(
            combine, (dA_c, dBx_c), axis=1)
        hs = A_acc * h[:, None] + X_acc               # (B, chunk, Di, N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, C_c)      # (B, chunk, Di)
        return hs[:, -1], y

    h0 = jnp.zeros((B, di, n), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, h0, xc)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, L, di)
    return y + x.astype(jnp.float32) * p["D"]


def ssm_apply(p: dict, cfg, x: jnp.ndarray, chunk: int = 256) -> jnp.ndarray:
    """Full mamba block: in_proj → conv → selective scan → gate → out."""
    xz = dense_apply(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, _ = _causal_conv(xi, p["conv_w"], p["conv_b"])
    xi = jax.nn.silu(xi)
    y = ssm_scan_chunked(p, cfg, xi, chunk=chunk)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return dense_apply(p["out_proj"], y.astype(x.dtype))


# ---------------------------------------------------------------------------
# Decode (O(1) per token — this is why SSM archs run long_500k)
# ---------------------------------------------------------------------------


def ssm_cache_shapes(cfg, B: int, dtype) -> dict:
    di, n, cw = cfg.d_inner, cfg.ssm_state, cfg.conv_width
    return {
        "conv": jax.ShapeDtypeStruct((B, cw - 1, di), dtype),
        "h": jax.ShapeDtypeStruct((B, di, n), jnp.float32),
    }


def ssm_decode_apply(p: dict, cfg, x: jnp.ndarray, cache: dict):
    """x: (B, 1, D). Returns (y, new_cache)."""
    xz = dense_apply(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"],
                                  state=cache["conv"])
    xi = jax.nn.silu(xi)
    dA, dBx, Cc = _ssm_params(p, cfg, xi[:, 0])  # (B, Di, N), (B, N)
    h = dA * cache["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cc) + xi[:, 0].astype(jnp.float32) * p["D"]
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = dense_apply(p["out_proj"], y.astype(x.dtype))[:, None]
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "h": h}
