"""Unified model: init / param_specs / forward / prefill / decode for all
ten assigned architectures.

Layer stacking: homogeneous layer stacks get a leading (L,) dim and run
under ``jax.lax.scan`` with rematerialisation (compile-time stays flat in
depth; remat bounds activation memory).  Heterogeneous archs decompose
into homogeneous stacks:

* encdec  — encoder stack (bidir) + decoder stack (causal + cross)
* vlm     — groups of (cross_every-1) self layers + 1 cross layer,
            outer scan over groups, inner scan over self layers
* others  — one stack

The dry-run never materialises params: ``param_shapes()`` returns a
ShapeDtypeStruct pytree consumed by ``jax.jit(...).lower()``.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import act_spec, batch_spec, shard, shard_act, shard_logits
from . import blocks as B
from . import layers as L


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def _stack_shapes(shapes: dict, n: int) -> dict:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), shapes)


def _stacked_init(key, cfg, dtype, kind: str, n: int) -> dict:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: B.layer_init(k, cfg, dtype, kind))(keys)


def _plan(cfg: ModelConfig):
    """Stack plan: list of (name, kind, n_layers, nested_inner)."""
    if cfg.family == "dense":
        return [("layers", "dense", cfg.n_layers, 0)]
    if cfg.family == "moe":
        return [("layers", "moe", cfg.n_layers, 0)]
    if cfg.family == "ssm":
        return [("layers", "ssm", cfg.n_layers, 0)]
    if cfg.family == "hybrid":
        return [("layers", "hybrid", cfg.n_layers, 0)]
    if cfg.family == "encdec":
        return [("enc_layers", "enc", cfg.enc_layers, 0),
                ("dec_layers", "dec", cfg.n_layers, 0)]
    if cfg.family == "vlm":
        k = cfg.cross_every
        assert cfg.n_layers % k == 0
        g = cfg.n_layers // k
        return [("self_layers", "dense", g, k - 1),  # (g, k-1, ...)
                ("cross_layers", "cross", g, 0)]
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    out = {"embed": jax.ShapeDtypeStruct((cfg.padded_vocab, cfg.d_model), dt),
           "final_norm": L.norm_shapes(cfg.d_model, cfg.norm, dt)}
    if not cfg.tie_embeddings:
        out["lm_head"] = jax.ShapeDtypeStruct((cfg.d_model, cfg.padded_vocab), dt)
    for name, kind, n, inner in _plan(cfg):
        s = B.layer_shapes(cfg, dt, kind)
        s = _stack_shapes(s, inner) if inner else s
        out[name] = _stack_shapes(s, n)
    if cfg.family == "encdec":
        out["enc_norm"] = L.norm_shapes(cfg.d_model, cfg.norm, dt)
    return out


def init_params(cfg: ModelConfig, key) -> dict:
    dt = _dtype(cfg)
    keys = iter(jax.random.split(key, 8))
    out = {
        "embed": (jax.random.normal(next(keys), (cfg.padded_vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "final_norm": L.norm_init(next(keys), cfg.d_model, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = (jax.random.normal(
            next(keys), (cfg.d_model, cfg.padded_vocab), jnp.float32)
            * cfg.d_model ** -0.5).astype(dt)
    for name, kind, n, inner in _plan(cfg):
        k = next(keys)
        if inner:
            ks = jax.random.split(k, n)
            out[name] = jax.vmap(
                lambda kk: _stacked_init(kk, cfg, dt, kind, inner))(ks)
        else:
            out[name] = _stacked_init(k, cfg, dt, kind, n)
    if cfg.family == "encdec":
        out["enc_norm"] = L.norm_init(next(keys), cfg.d_model, cfg.norm, dt)
    return out


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _scan_stack(stack_params, x, fn, remat: bool = True):
    body = fn
    if remat:
        body = jax.checkpoint(
            fn, policy=jax.checkpoint_policies.nothing_saveable)

    def step(carry, layer_p):
        return body(carry, layer_p), None

    out, _ = jax.lax.scan(step, x, stack_params)
    return out


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            schedule: str = "masked", q_chunk: int = 1024,
            k_chunk: int = 1024, ssm_chunk: int = 256,
            remat: bool = True, last_only: bool = False) -> jnp.ndarray:
    """Logits for (B, S) tokens (training / prefill).

    ``last_only`` (prefill): slice to the final position BEFORE the
    lm_head matmul — the full (B, S, V) logits tensor is never built
    (minitron prefill_32k: 66 GB → fits; §Perf iteration 2)."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard_act(x)

    kw = dict(schedule=schedule, q_chunk=q_chunk, k_chunk=k_chunk,
              ssm_chunk=ssm_chunk)

    ctx = None
    if cfg.family == "encdec":
        enc = batch["enc_frames"].astype(x.dtype)
        enc = shard_act(enc)
        enc = _scan_stack(
            params["enc_layers"], enc,
            lambda h, p: B.layer_apply(p, cfg, h, "enc", causal=False, **kw),
            remat=remat)
        ctx = L.norm_apply(params["enc_norm"], enc, cfg.norm)
    if cfg.family == "vlm":
        ctx = shard_act(batch["vis_embed"].astype(x.dtype))

    if cfg.family == "vlm":
        k = cfg.cross_every

        def group(h, gp):
            h = _scan_stack(
                gp["self"], h,
                lambda hh, p: B.layer_apply(p, cfg, hh, "dense", **kw),
                remat=remat)
            fn = lambda hh, p: B.layer_apply(p, cfg, hh, "cross", ctx=ctx,
                                             **kw)
            if remat:
                fn = jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.nothing_saveable)
            return fn(h, gp["cross"])

        def gstep(carry, gp):
            return group(carry, gp), None

        x, _ = jax.lax.scan(
            gstep, x,
            {"self": params["self_layers"], "cross": params["cross_layers"]})
    elif cfg.family == "encdec":
        x = _scan_stack(
            params["dec_layers"], x,
            lambda h, p: B.layer_apply(p, cfg, h, "dec", ctx=ctx, **kw),
            remat=remat)
    else:
        kind = _plan(cfg)[0][1]
        x = _scan_stack(
            params["layers"], x,
            lambda h, p: B.layer_apply(p, cfg, h, kind, **kw),
            remat=remat)

    if last_only:
        x = x[:, -1:]
    x = L.norm_apply(params["final_norm"], x, cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return shard_logits(logits)


def loss_fn(params, cfg, batch, **kw):
    logits = forward(params, cfg, batch, **kw)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        # Mask vocab-padding logits out of the partition function.
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Decode path (serve_step)
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, bsz: int, cache_len: int) -> dict:
    dt = _dtype(cfg)
    out = {}
    for name, kind, n, inner in _plan(cfg):
        if kind == "enc":
            continue
        s = B.layer_cache_shapes(cfg, kind, bsz, cache_len, dt)
        s = _stack_shapes(s, inner) if inner else s
        out[name] = _stack_shapes(s, n)
    if cfg.family == "encdec":
        h, KV = cfg.head_dim, cfg.n_kv_heads
        out["cross_kv"] = {
            "k": jax.ShapeDtypeStruct(
                (cfg.n_layers, bsz, cfg.enc_seq, KV, h), dt),
            "v": jax.ShapeDtypeStruct(
                (cfg.n_layers, bsz, cfg.enc_seq, KV, h), dt),
        }
    if cfg.family == "vlm":
        h, KV = cfg.head_dim, cfg.n_kv_heads
        g = cfg.n_layers // cfg.cross_every
        out["cross_kv"] = {
            "k": jax.ShapeDtypeStruct((g, bsz, cfg.vis_seq, KV, h), dt),
            "v": jax.ShapeDtypeStruct((g, bsz, cfg.vis_seq, KV, h), dt),
        }
    return out


def init_cache(cfg: ModelConfig, bsz: int, cache_len: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, bsz, cache_len))


def _idx(tree, i):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
        tree)


def _dus(tree, upd, i):
    return jax.tree.map(
        lambda a, u: jax.lax.dynamic_update_index_in_dim(
            a, u.astype(a.dtype), i, 0),
        tree, upd)


def _decode_scan(stack_params, cache_stack, x, step_fn):
    """Scan over layers carrying the FULL cache and updating it in place
    (dynamic-update-slice on the carry).  Unlike an xs→ys scan this keeps
    a single cache buffer alive — the xs input + stacked ys output pattern
    double-buffered multi-GB KV caches (phi3 decode_32k: 15.5 GB temp →
    §Perf iteration 4)."""
    n = jax.tree.leaves(stack_params)[0].shape[0]

    def body(carry, l):
        x, cache = carry
        lp = _idx(stack_params, l)
        lc = _idx(cache, l)
        x, nc = step_fn(x, lp, lc, l)
        cache = _dus(cache, nc, l)
        return (x, cache), None

    (x, cache_stack), _ = jax.lax.scan(
        body, (x, cache_stack), jnp.arange(n))
    return x, cache_stack


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                batch: dict) -> tuple:
    """One token for every sequence in the batch against the cache.

    batch = {"tokens": (B, 1), "cache_index": () or (B,)} — returns
    (logits (B, vocab), new_cache).  A per-row cache index lets the
    continuous batcher keep each decode slot at its own position.
    """
    tokens, cache_index = batch["tokens"], batch["cache_index"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard_act(x)
    new_cache = dict(cache)

    if cfg.family == "vlm":
        gp_tree = {"self": params["self_layers"],
                   "cross": params["cross_layers"]}
        g = jax.tree.leaves(params["cross_layers"])[0].shape[0]

        def gbody(carry, gi):
            x, self_cache = carry
            gp = _idx(gp_tree, gi)
            gcache = _idx(self_cache, gi)

            def self_step(xx, lp, lc, _l):
                return B.layer_decode_apply(lp, cfg, xx, lc, cache_index,
                                            "dense")

            x, gcache = _decode_scan(gp["self"], gcache, x, self_step)
            x, _ = B.layer_decode_apply(
                gp["cross"], cfg, x, {}, cache_index, "cross",
                ctx_kv=_idx(cache["cross_kv"], gi))
            self_cache = _dus(self_cache, gcache, gi)
            return (x, self_cache), None

        (x, new_self), _ = jax.lax.scan(
            gbody, (x, cache["self_layers"]), jnp.arange(g))
        new_cache["self_layers"] = new_self
    elif cfg.family == "encdec":
        def dec_step(xx, lp, lc, l):
            return B.layer_decode_apply(
                lp, cfg, xx, lc, cache_index, "dec",
                ctx_kv=_idx(cache["cross_kv"], l))

        x, new_dec = _decode_scan(params["dec_layers"],
                                  cache["dec_layers"], x, dec_step)
        new_cache["dec_layers"] = new_dec
    else:
        kind = _plan(cfg)[0][1]

        def lyr_step(xx, lp, lc, _l):
            return B.layer_decode_apply(lp, cfg, xx, lc, cache_index, kind)

        x, new_layers = _decode_scan(params["layers"], cache["layers"], x,
                                     lyr_step)
        new_cache["layers"] = new_layers

    x = L.norm_apply(params["final_norm"], x, cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0]
    return shard_logits(logits), new_cache


def prefill_step(params: dict, cfg: ModelConfig, cache: dict,
                 batch: dict) -> tuple:
    """Write a span of prompt tokens through the model at per-row cache
    indices — the chunked-prefill primitive for the continuous batcher.

    batch = {"tokens": (B, C), "cache_index": (B,), "count": (B,)} —
    row b's ``tokens[b, :count[b]]`` land at cache positions
    ``cache_index[b] .. cache_index[b]+count[b]-1``.  Rows with
    ``count == 0`` are inert: their cache is untouched bit-for-bit
    (padded lanes scatter out of bounds and are dropped), so slots deep
    in decode can share a launch buffer with prefilling neighbours.

    Returns ``(logits (B, vocab), new_cache)`` where row b's logits are
    taken at its LAST valid lane (``count[b] - 1``) — the same shape
    contract as :func:`decode_step`, so a slot whose prefill just
    finished can seed decode from these logits.  Rows with ``count == 0``
    return garbage logits that callers must not read.

    Because every chunk runs through the same static ``(B, C)`` buffer
    and each query's attention reduces over the full cache, chunked
    prefill is bitwise identical to whole-prompt prefill (pinned by
    tests/test_prefill.py).

    Only full-cache attention families (dense/moe, no sliding window)
    are supported — recurrent and ring-buffer caches have no
    position-indexed span write.
    """
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"prefill_step needs a position-indexed KV cache "
            f"(dense/moe), not family={cfg.family!r}")
    if cfg.sliding_window > 0:
        raise NotImplementedError(
            "prefill_step writes absolute-position spans; ring-buffer "
            "(sliding-window) caches would need modular span writes")
    tokens = batch["tokens"]
    cache_index = jnp.asarray(batch["cache_index"], jnp.int32)
    count = jnp.asarray(batch["count"], jnp.int32)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard_act(x)
    new_cache = dict(cache)
    kind = _plan(cfg)[0][1]

    def lyr_step(xx, lp, lc, _l):
        return B.layer_prefill_apply(lp, cfg, xx, lc, cache_index, count,
                                     kind)

    x, new_layers = _decode_scan(params["layers"], cache["layers"], x,
                                 lyr_step)
    new_cache["layers"] = new_layers
    x = L.norm_apply(params["final_norm"], x, cfg.norm)
    last = jnp.clip(count - 1, 0, tokens.shape[1] - 1)
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0]
    return shard_logits(logits), new_cache
