"""Per-family transformer blocks (pre-norm residual), stacked with
``jax.lax.scan`` over a leading layer dimension + rematerialisation.

Families: dense / moe / ssm (mamba-only, no FFN) / hybrid (parallel
attn+mamba heads, Hymba-style) / encdec (whisper) / vlm (periodic
cross-attention, Llama-3.2-Vision-style).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import act_spec, shard, shard_act
from . import layers as L
from . import moe as M
from . import ssm as S


# ---------------------------------------------------------------------------
# Single-layer shapes / init / apply per family
# ---------------------------------------------------------------------------


def layer_shapes(cfg, dtype, kind: str) -> dict:
    d = cfg.d_model
    nk = cfg.norm
    out = {"ln1": L.norm_shapes(d, nk, dtype)}
    if kind in ("dense", "moe", "hybrid", "enc", "dec", "cross"):
        out["attn"] = L.attn_shapes(cfg, dtype)
    if kind == "hybrid":
        out["ssm"] = S.ssm_shapes(cfg, dtype)
    if kind == "ssm":
        out["ssm"] = S.ssm_shapes(cfg, dtype)
        return out  # mamba block has no FFN (falcon-mamba d_ff=0)
    if kind == "dec":
        out["lnx"] = L.norm_shapes(d, nk, dtype)
        out["cross"] = L.attn_shapes(cfg, dtype)
    if kind == "cross":
        # VLM cross layer: attention reads vision embeddings
        pass
    out["ln2"] = L.norm_shapes(d, nk, dtype)
    if kind == "moe":
        out["moe"] = M.moe_shapes(cfg, dtype)
    else:
        out["mlp"] = L.mlp_shapes(d, cfg.d_ff, cfg.act, dtype)
    return out


def layer_init(key, cfg, dtype, kind: str) -> dict:
    ks = iter(jax.random.split(key, 8))
    d, nk = cfg.d_model, cfg.norm
    out = {"ln1": L.norm_init(next(ks), d, nk, dtype)}
    if kind in ("dense", "moe", "hybrid", "enc", "dec", "cross"):
        out["attn"] = L.attn_init(next(ks), cfg, dtype)
    if kind in ("hybrid", "ssm"):
        out["ssm"] = S.ssm_init(next(ks), cfg, dtype)
        if kind == "ssm":
            return out
    if kind == "dec":
        out["lnx"] = L.norm_init(next(ks), d, nk, dtype)
        out["cross"] = L.attn_init(next(ks), cfg, dtype)
    out["ln2"] = L.norm_init(next(ks), d, nk, dtype)
    if kind == "moe":
        out["moe"] = M.moe_init(next(ks), cfg, dtype)
    else:
        out["mlp"] = L.mlp_init(next(ks), d, cfg.d_ff, cfg.act, dtype)
    return out


def layer_apply(p: dict, cfg, x: jnp.ndarray, kind: str, *,
                ctx: Optional[jnp.ndarray] = None,
                causal: bool = True,
                schedule: str = "masked",
                q_chunk: int = 1024, k_chunk: int = 1024,
                ssm_chunk: int = 256) -> jnp.ndarray:
    """One block forward (training/prefill path)."""
    if kind == "ssm":
        h = L.norm_apply(p["ln1"], x, cfg.norm)
        return x + shard_act(S.ssm_apply(p["ssm"], cfg, h, chunk=ssm_chunk))
    h = L.norm_apply(p["ln1"], x, cfg.norm)
    if kind == "cross":
        a = L.attn_apply(p["attn"], cfg, h, kv_src=ctx, causal=False,
                         schedule=schedule, q_chunk=q_chunk, k_chunk=k_chunk)
    else:
        a = L.attn_apply(p["attn"], cfg, h, causal=causal, schedule=schedule,
                         q_chunk=q_chunk, k_chunk=k_chunk)
    if kind == "hybrid":
        # Hymba: attention and mamba heads in parallel on the same input,
        # outputs mean-fused.
        s_out = S.ssm_apply(p["ssm"], cfg, h, chunk=ssm_chunk)
        a = (a + s_out) * 0.5
    x = x + shard_act(a)
    if kind == "dec":
        h = L.norm_apply(p["lnx"], x, cfg.norm)
        x = x + shard_act(
            L.attn_apply(p["cross"], cfg, h, kv_src=ctx, causal=False,
                         schedule=schedule, q_chunk=q_chunk, k_chunk=k_chunk))
    h = L.norm_apply(p["ln2"], x, cfg.norm)
    if kind == "moe":
        f = M.moe_apply(p["moe"], cfg, h)
    else:
        f = L.mlp_apply(p["mlp"], h, cfg.act)
    return x + shard_act(f)


# ---------------------------------------------------------------------------
# Decode (single token) per family
# ---------------------------------------------------------------------------


def layer_cache_shapes(cfg, kind: str, B: int, cache_len: int, dtype) -> dict:
    out = {}
    h, KV = cfg.head_dim, cfg.n_kv_heads
    if kind in ("dense", "moe", "hybrid", "dec", "cross"):
        T = min(cache_len, cfg.sliding_window) if cfg.sliding_window > 0 \
            else cache_len
        # Windowed archs only materialise the window (ring buffer) — this is
        # what keeps mixtral/hymba long_500k caches small.
        if kind != "cross":
            out["k"] = jax.ShapeDtypeStruct((B, T, KV, h), dtype)
            out["v"] = jax.ShapeDtypeStruct((B, T, KV, h), dtype)
    if kind in ("ssm", "hybrid"):
        out.update(S.ssm_cache_shapes(cfg, B, dtype))
    return out


def layer_decode_apply(p: dict, cfg, x: jnp.ndarray, cache: dict,
                       cache_index, kind: str, *,
                       ctx_kv: Optional[dict] = None):
    """One block, one token.  Returns (x, new_cache).

    For windowed caches the write index wraps (ring buffer) and the
    attention window covers the whole buffer.

    ``cache_index`` is a scalar (every row at the same position) or a
    per-row ``(B,)`` vector — continuous batching tracks each decode
    slot's position independently so a freshly refilled slot writes and
    masks at ITS OWN position, not a neighbour's.
    """
    new_cache = dict(cache)
    if kind == "ssm":
        h = L.norm_apply(p["ln1"], x, cfg.norm)
        y, sc = S.ssm_decode_apply(p["ssm"], cfg, h, cache)
        new_cache.update(sc)
        return x + y, new_cache
    h = L.norm_apply(p["ln1"], x, cfg.norm)
    if kind == "cross":
        a = L.cross_decode_apply(p["attn"], cfg, h, ctx_kv)
    else:
        T = cache["k"].shape[1]
        ci = jnp.asarray(cache_index, jnp.int32)
        idx = jnp.mod(ci, T) if cfg.sliding_window > 0 else ci
        window = 0 if cfg.sliding_window > 0 else 0  # ring buffer = window
        # In the ring buffer every entry is valid once full; effective
        # index for masking is min(cache_index+1, T).
        p_attn = p["attn"]
        q = L.dense_apply(p_attn["wq"], h).reshape(
            x.shape[0], 1, cfg.n_heads, cfg.head_dim)
        k = L.dense_apply(p_attn["wk"], h).reshape(
            x.shape[0], 1, cfg.n_kv_heads, cfg.head_dim)
        v = L.dense_apply(p_attn["wv"], h).reshape(
            x.shape[0], 1, cfg.n_kv_heads, cfg.head_dim)
        if cfg.rope_theta > 0:
            pos = L.decode_positions(ci, x.shape[0])
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
        kc = L.kv_cache_update(cache["k"], k, idx)
        vc = L.kv_cache_update(cache["v"], v, idx)
        valid = jnp.minimum(ci + 1, T)
        a = L.decode_attention(q, kc, vc, valid, window=0)
        a = L.dense_apply(p_attn["wo"], a.reshape(x.shape[0], 1, -1))
        new_cache["k"], new_cache["v"] = kc, vc
    if kind == "hybrid":
        y, sc = S.ssm_decode_apply(p["ssm"], cfg, h, cache)
        a = (a + y) * 0.5
        new_cache.update(sc)
    x = x + a
    if kind == "dec":
        h = L.norm_apply(p["lnx"], x, cfg.norm)
        x = x + L.cross_decode_apply(p["cross"], cfg, h, ctx_kv)
    h = L.norm_apply(p["ln2"], x, cfg.norm)
    if kind == "moe":
        f = M.moe_apply(p["moe"], cfg, h)
    else:
        f = L.mlp_apply(p["mlp"], h, cfg.act)
    return x + f, new_cache


def layer_prefill_apply(p: dict, cfg, x: jnp.ndarray, cache: dict,
                        cache_index, count, kind: str):
    """One block over a ``(B, C)`` token span (chunked prefill).
    Returns ``(x, new_cache)``.

    Only full-cache attention families are supported: recurrent state
    (ssm/hybrid) is not position-indexed, and ring-buffer
    (sliding-window) caches would need modular span writes.  The
    batcher rejects those configs at ``submit()``.
    """
    if kind not in ("dense", "moe"):
        raise NotImplementedError(
            f"span prefill is only defined for dense/moe blocks, "
            f"not kind={kind!r}")
    new_cache = dict(cache)
    h = L.norm_apply(p["ln1"], x, cfg.norm)
    a, kc, vc = L.attn_prefill_apply(p["attn"], cfg, h, cache,
                                     cache_index, count)
    new_cache["k"], new_cache["v"] = kc, vc
    x = x + a
    h = L.norm_apply(p["ln2"], x, cfg.norm)
    if kind == "moe":
        f = M.moe_apply(p["moe"], cfg, h)
    else:
        f = L.mlp_apply(p["mlp"], h, cfg.act)
    return x + f, new_cache
