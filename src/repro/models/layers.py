"""Core NN layers: norms, RoPE, linear, MLP, and memory-efficient attention.

Everything is pure-functional: params are plain dict pytrees, and every
``init_*`` has a matching ``*_shapes`` so the dry-run can build
ShapeDtypeStruct pytrees without allocating (full configs are never
materialised on the CPU host).

Attention is chunked online-softmax ("flash in XLA"): the S×T score matrix
is never materialised.  Three schedules are provided —

* ``masked``   : scan over all KV chunks with a mask (small HLO; causal
                 pays 2× FLOPs — the unbalanced baseline);
* ``tri``      : python-unrolled lower-triangular chunk pairs (exact causal
                 FLOPs; bigger HLO) — the DLBC-balanced schedule on the XLA
                 path (each chunk pair does equal useful work);
* ``window``   : sliding-window attention visits only the O(w) diagonal
                 band (mixtral / hymba), which is what makes long-context
                 cells sub-quadratic.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Param helpers: every init has a shape-only twin
# ---------------------------------------------------------------------------


def _norm_init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_shapes(d_in: int, d_out: int, bias: bool, dtype) -> dict:
    out = {"w": jax.ShapeDtypeStruct((d_in, d_out), dtype)}
    if bias:
        out["b"] = jax.ShapeDtypeStruct((d_out,), dtype)
    return out


def dense_init(key, d_in: int, d_out: int, bias: bool, dtype) -> dict:
    out = {"w": _norm_init(key, (d_in, d_out), d_in ** -0.5, dtype)}
    if bias:
        out["b"] = jnp.zeros((d_out,), dtype)
    return out


def dense_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms (fp32 accumulation)
# ---------------------------------------------------------------------------


def norm_shapes(d: int, kind: str, dtype) -> dict:
    out = {"scale": jax.ShapeDtypeStruct((d,), dtype)}
    if kind == "layernorm":
        out["bias"] = jax.ShapeDtypeStruct((d,), dtype)
    return out


def norm_init(key, d: int, kind: str, dtype) -> dict:
    out = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        out["bias"] = jnp.zeros((d,), dtype)
    return out


def norm_apply(p: dict, x: jnp.ndarray, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, dh); positions: (..., S)."""
    if theta <= 0:
        return x
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_shapes(d: int, f: int, act: str, dtype) -> dict:
    if act == "swiglu":
        return {
            "w1": jax.ShapeDtypeStruct((d, f), dtype),
            "w3": jax.ShapeDtypeStruct((d, f), dtype),
            "w2": jax.ShapeDtypeStruct((f, d), dtype),
        }
    return {
        "w1": jax.ShapeDtypeStruct((d, f), dtype),
        "b1": jax.ShapeDtypeStruct((f,), dtype),
        "w2": jax.ShapeDtypeStruct((f, d), dtype),
        "b2": jax.ShapeDtypeStruct((d,), dtype),
    }


def mlp_init(key, d: int, f: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "w1": _norm_init(k1, (d, f), d ** -0.5, dtype),
            "w3": _norm_init(k3, (d, f), d ** -0.5, dtype),
            "w2": _norm_init(k2, (f, d), f ** -0.5, dtype),
        }
    return {
        "w1": _norm_init(k1, (d, f), d ** -0.5, dtype),
        "b1": jnp.zeros((f,), dtype),
        "w2": _norm_init(k2, (f, d), f ** -0.5, dtype),
        "b2": jnp.zeros((d,), dtype),
    }


def mlp_apply(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]
    h = jax.nn.gelu(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


# ---------------------------------------------------------------------------
# Attention (chunked online softmax)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_chunk(q, k, v, qpos, kpos, *, causal: bool, window: int,
                kv_valid: int = 0):
    """One (q-chunk × kv-chunk) block of online softmax.

    q: (B, qc, KV, G, dh); k/v: (B, kc, KV, dh).
    Returns (scores_max, exp_sum, acc) contributions in fp32.
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgd,bckd->bqkgc", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((q.shape[1], k.shape[1]), dtype=bool)
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window > 0:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)
    if kv_valid:
        mask = mask & (kpos[None, :] < kv_valid)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B,qc,KV,G)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask[None, :, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)                                  # (B,qc,KV,G)
    acc = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return m, l, acc


def _merge_online(carry, new):
    """Merge two online-softmax partials (m, l, acc)."""
    m0, l0, a0 = carry
    m1, l1, a1 = new
    m = jnp.maximum(m0, m1)
    c0 = jnp.exp(m0 - m)
    c1 = jnp.exp(m1 - m)
    return m, l0 * c0 + l1 * c1, a0 * c0[..., None] + a1 * c1[..., None]


def chunked_attention(
    q: jnp.ndarray,       # (B, S, H, dh)
    k: jnp.ndarray,       # (B, T, KV, dh)
    v: jnp.ndarray,       # (B, T, KV, dh)
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
    schedule: str = "masked",   # masked | tri
    q_offset: int = 0,          # absolute position of q[0] (cross/cache)
) -> jnp.ndarray:
    """Memory-efficient multi-head attention with GQA.

    ``schedule='tri'`` unrolls only the lower-triangular (or in-window)
    chunk pairs — the load-balanced schedule (exact FLOPs); ``masked``
    visits every pair with masking (compact HLO, 2× causal FLOP waste).
    """
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    # Ragged lengths (whisper's 1500 frames, vision's 1601 patches): pad to
    # the chunk grid; padded KV is masked via kv_valid, padded q rows are
    # sliced off the output.
    S0, T0 = S, T
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, T)
    kv_valid = 0
    if S % q_chunk:
        pad = q_chunk - S % q_chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S += pad
    if T % k_chunk:
        pad = k_chunk - T % k_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = T0
        T += pad
    q = q.reshape(B, S, KV, G, dh)
    nq = S // q_chunk
    nk = T // k_chunk

    qs = q.reshape(B, nq, q_chunk, KV, G, dh)
    ks = k.reshape(B, nk, k_chunk, KV, dh)
    vs = v.reshape(B, nk, k_chunk, KV, dh)

    # banded window scan needs q/k chunk grids in lockstep
    kv_src_aligned = (q_chunk == k_chunk) and q_offset == 0

    def q_block(i, qi):
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)

        def kv_visible(j):
            # Static reachability for pruning (tri/window schedules).
            q_lo = q_offset + i * q_chunk
            q_hi = q_lo + q_chunk - 1
            k_lo, k_hi = j * k_chunk, (j + 1) * k_chunk - 1
            if causal and k_lo > q_hi:
                return False
            if window > 0 and k_hi < q_lo - (window - 1) - (q_chunk - 1):
                return False
            return True

        if schedule == "tri":
            m = jnp.full((B, q_chunk, KV, G), NEG_INF, jnp.float32)
            l = jnp.zeros((B, q_chunk, KV, G), jnp.float32)
            acc = jnp.zeros((B, q_chunk, KV, G, dh), jnp.float32)
            carry = (m, l, acc)
            for j in range(nk):
                if not kv_visible(j):
                    continue
                kpos = j * k_chunk + jnp.arange(k_chunk)
                part = _attn_chunk(qi, ks[:, j], vs[:, j], qpos, kpos,
                                   causal=causal, window=window,
                                   kv_valid=kv_valid)
                carry = _merge_online(carry, part)
            m, l, acc = carry
        elif window > 0 and causal and kv_src_aligned:
            # Banded scan (DLBC "only do work where it exists", without the
            # unrolled-HLO blow-up of 'tri'): a sliding-window q chunk only
            # sees the diagonal band of ⌈w/kc⌉+1 KV chunks, visited via
            # dynamic indices relative to the q-chunk position.  Duplicate
            # clamped indices at the left edge are masked out (valid flag).
            noff = min(nk, (window + q_chunk - 1) // k_chunk + 1)

            def body(carry, off):
                j_raw = i - off
                j = jnp.clip(j_raw, 0, nk - 1)
                kj = jax.lax.dynamic_index_in_dim(ks, j, 1, keepdims=False)
                vj = jax.lax.dynamic_index_in_dim(vs, j, 1, keepdims=False)
                kpos = j * k_chunk + jnp.arange(k_chunk)
                part = _attn_chunk(qi, kj, vj, qpos, kpos, causal=causal,
                                   window=window, kv_valid=kv_valid)
                valid = (j_raw >= 0).astype(jnp.float32)
                part = (jnp.where(valid > 0, part[0], NEG_INF),
                        part[1] * valid, part[2] * valid)
                return _merge_online(carry, part), None

            init = (
                jnp.full((B, q_chunk, KV, G), NEG_INF, jnp.float32),
                jnp.zeros((B, q_chunk, KV, G), jnp.float32),
                jnp.zeros((B, q_chunk, KV, G, dh), jnp.float32),
            )
            (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(noff))
        else:
            def body(carry, j):
                kj = jax.lax.dynamic_index_in_dim(ks, j, 1, keepdims=False)
                vj = jax.lax.dynamic_index_in_dim(vs, j, 1, keepdims=False)
                kpos = j * k_chunk + jnp.arange(k_chunk)
                part = _attn_chunk(qi, kj, vj, qpos, kpos,
                                   causal=causal, window=window,
                                   kv_valid=kv_valid)
                return _merge_online(carry, part), None

            init = (
                jnp.full((B, q_chunk, KV, G), NEG_INF, jnp.float32),
                jnp.zeros((B, q_chunk, KV, G), jnp.float32),
                jnp.zeros((B, q_chunk, KV, G, dh), jnp.float32),
            )
            (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    if nq == 1:
        out = q_block(0, qs[:, 0])
        return out.reshape(B, S, H, dh)[:, :S0]
    # Unrolled python loop over q chunks in 'tri' (each body differs);
    # scan in 'masked'.
    if schedule == "tri":
        outs = [q_block(i, qs[:, i]) for i in range(nq)]
        out = jnp.stack(outs, axis=1)
    else:
        def qbody(_, i):
            return None, q_block(i, jax.lax.dynamic_index_in_dim(
                qs, i, 1, keepdims=False))

        _, out = jax.lax.scan(qbody, None, jnp.arange(nq))
        out = jnp.moveaxis(out, 0, 1)  # (B, nq, qc, KV, G, dh)
    return out.reshape(B, S, H, dh)[:, :S0]


def decode_attention(
    q: jnp.ndarray,        # (B, 1, H, dh)
    k_cache: jnp.ndarray,  # (B, T, KV, dh)
    v_cache: jnp.ndarray,
    cache_index: jnp.ndarray,  # () or (B,) int32 — valid cache entries
    *,
    window: int = 0,
) -> jnp.ndarray:
    """One-token attention against a (possibly windowed) KV cache.

    ``cache_index`` may be a scalar (every row at the same position —
    training-style decode) or per-row ``(B,)`` (continuous batching,
    where a freshly refilled slot sits at position 0 while its
    neighbours are deep into their sequences).
    """
    B, _, H, dh = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                   preferred_element_type=jnp.float32) * dh ** -0.5
    pos = jnp.arange(T)
    ci = jnp.asarray(cache_index)
    if ci.ndim == 0:
        ci = jnp.full((B,), ci)
    mask = pos[None, :] < ci[:, None]                       # (B, T)
    if window > 0:
        mask = mask & (pos[None, :] >= ci[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + cache handling)
# ---------------------------------------------------------------------------


def attn_shapes(cfg, dtype, cross: bool = False) -> dict:
    d, h = cfg.d_model, cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    bias = cfg.qkv_bias
    return {
        "wq": dense_shapes(d, H * h, bias, dtype),
        "wk": dense_shapes(d, KV * h, bias, dtype),
        "wv": dense_shapes(d, KV * h, bias, dtype),
        "wo": dense_shapes(H * h, d, False, dtype),
    }


def attn_init(key, cfg, dtype, cross: bool = False) -> dict:
    d, h = cfg.d_model, cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    kq, kk, kv_, ko = jax.random.split(key, 4)
    bias = cfg.qkv_bias
    return {
        "wq": dense_init(kq, d, H * h, bias, dtype),
        "wk": dense_init(kk, d, KV * h, bias, dtype),
        "wv": dense_init(kv_, d, KV * h, bias, dtype),
        "wo": dense_init(ko, H * h, d, False, dtype),
    }


def attn_apply(
    p: dict, cfg, x: jnp.ndarray, *,
    kv_src: Optional[jnp.ndarray] = None,   # cross-attention source
    causal: bool = True,
    positions: Optional[jnp.ndarray] = None,
    schedule: str = "masked",
    q_chunk: int = 1024, k_chunk: int = 1024,
) -> jnp.ndarray:
    B, S, d = x.shape
    H, KV, h = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_src is None else kv_src
    T = src.shape[1]
    q = dense_apply(p["wq"], x).reshape(B, S, H, h)
    k = dense_apply(p["wk"], src).reshape(B, T, KV, h)
    v = dense_apply(p["wv"], src).reshape(B, T, KV, h)
    if kv_src is None and cfg.rope_theta > 0:
        pos = positions if positions is not None else jnp.arange(S)[None, :]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    # Context-parallel attention: q stays sequence-sharded over the model
    # axis (matching the SP residual stream); k/v are gathered ONCE per
    # layer.  Without these constraints GSPMD reshards per KV-chunk inside
    # the online-softmax scan (an all-to-all every chunk — §Perf iter. 5).
    from ..distributed.sharding import current_mesh, fsdp_axes
    import jax as _jax
    from jax.sharding import NamedSharding as _NS, PartitionSpec as _P

    mesh = current_mesh()
    if mesh is not None and S > 1:
        fa = fsdp_axes(mesh)
        msize = mesh.shape["model"]
        dsize = 1
        for a in fa:
            dsize *= mesh.shape[a]
        b_ax = fa if B % dsize == 0 else None
        s_ax = "model" if S % msize == 0 and S >= q_chunk * msize else None
        q = _jax.lax.with_sharding_constraint(
            q, _NS(mesh, _P(b_ax, s_ax, None, None)))
        k = _jax.lax.with_sharding_constraint(
            k, _NS(mesh, _P(b_ax, None, None, None)))
        v = _jax.lax.with_sharding_constraint(
            v, _NS(mesh, _P(b_ax, None, None, None)))
    out = chunked_attention(
        q, k, v, causal=causal and kv_src is None,
        window=cfg.sliding_window if kv_src is None else 0,
        q_chunk=q_chunk, k_chunk=k_chunk, schedule=schedule,
    )
    return dense_apply(p["wo"], out.reshape(B, S, H * h))


def decode_positions(cache_index, B: int) -> jnp.ndarray:
    """Normalise a scalar-or-``(B,)`` cache index to per-row positions
    ``(B, 1)`` (rope / masking)."""
    ci = jnp.asarray(cache_index, jnp.int32)
    if ci.ndim == 0:
        return jnp.full((B, 1), ci, dtype=jnp.int32)
    return ci[:, None]


def kv_cache_update(cache_arr: jnp.ndarray, new: jnp.ndarray,
                    idx) -> jnp.ndarray:
    """Write a one-token K/V slice ``new`` (B, 1, KV, h) into the cache at
    ``idx`` — a scalar (every row at the same position) or per-row
    ``(B,)`` (continuous batching: each slot writes at ITS OWN position,
    so a refill mid-decode cannot clobber or land past a neighbour)."""
    idx = jnp.asarray(idx, jnp.int32)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            cache_arr, new.astype(cache_arr.dtype), idx, axis=1)
    B, T = cache_arr.shape[0], cache_arr.shape[1]
    # match the scalar path's overflow semantics: dynamic_update_slice
    # clamps to the last position, whereas an out-of-bounds scatter
    # under jit silently DROPS the write — clamp so both paths overwrite
    # position T-1 when a caller runs past the cache
    idx = jnp.minimum(idx, T - 1)
    return cache_arr.at[jnp.arange(B), idx].set(
        new[:, 0].astype(cache_arr.dtype))


def kv_cache_update_span(cache_arr: jnp.ndarray, new: jnp.ndarray,
                         idx: jnp.ndarray, count: jnp.ndarray) -> jnp.ndarray:
    """Write a K/V span ``new`` (B, C, KV, h) into the cache starting at
    per-row indices ``idx`` (B,) — the multi-token generalisation of
    :func:`kv_cache_update` for chunked prefill.

    Only the first ``count[b]`` lanes of row b are written: padding
    lanes (and any lane that would land past the cache end) are routed
    to index ``T`` and DROPPED by the scatter, so a masked row's cache
    is untouched bit-for-bit.  That drop is what isolates a prefilling
    slot's padded launch buffer from its neighbours in the batch."""
    B, T = cache_arr.shape[0], cache_arr.shape[1]
    C = new.shape[1]
    idx = jnp.asarray(idx, jnp.int32)
    tgt = idx[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]   # (B, C)
    valid = (jnp.arange(C)[None, :] < count[:, None]) & (tgt < T)
    tgt = jnp.where(valid, tgt, T)  # T is out of bounds -> dropped
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
    return cache_arr.at[rows, tgt].set(new.astype(cache_arr.dtype),
                                       mode="drop")


def prefill_attention(
    q: jnp.ndarray,        # (B, C, H, dh)
    k_cache: jnp.ndarray,  # (B, T, KV, dh)
    v_cache: jnp.ndarray,
    cache_index: jnp.ndarray,  # (B,) absolute position of q[:, 0]
) -> jnp.ndarray:
    """Causal attention of a C-token span against the full KV cache.

    Query ``j`` of row ``b`` sits at absolute position
    ``cache_index[b] + j`` and sees every cache position ``<=`` its own
    — which, with the span's own K/V already written, is exactly the
    full-softmax semantics of :func:`decode_attention` applied per lane.
    Because each query's scores reduce over the same (dh, T) axes
    regardless of where the chunk boundary falls, the outputs are
    BITWISE identical across chunkings of the same prompt (the chunked
    == whole-prompt exactness the serving tests pin).

    Padded lanes (callers mask them via the span write's ``count``)
    produce garbage that callers must never read; their KV writes are
    dropped and their logits are never consumed.
    """
    B, C, H, dh = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, C, KV, G, dh)
    s = jnp.einsum("bckgd,btkd->bckgt", qg, k_cache,
                   preferred_element_type=jnp.float32) * dh ** -0.5
    ci = jnp.asarray(cache_index, jnp.int32)
    qpos = ci[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]   # (B, C)
    mask = jnp.arange(T)[None, None, :] <= qpos[..., None]         # (B, C, T)
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bckgt,btkd->bckgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, C, H, dh).astype(q.dtype)


def attn_prefill_apply(p: dict, cfg, x: jnp.ndarray, cache: dict,
                       cache_index, count) -> tuple:
    """Span prefill: project a (B, C, d) chunk, write its K/V at per-row
    cache indices (``count`` masks each row's valid lanes), attend
    causally over the cache.  Returns ``(out, k_cache, v_cache)``.

    RoPE is applied at the absolute positions ``cache_index + lane``,
    so a chunk boundary never shifts a token's rotary phase."""
    B, C, d = x.shape
    H, KV, h = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense_apply(p["wq"], x).reshape(B, C, H, h)
    k = dense_apply(p["wk"], x).reshape(B, C, KV, h)
    v = dense_apply(p["wv"], x).reshape(B, C, KV, h)
    ci = jnp.asarray(cache_index, jnp.int32)
    if ci.ndim == 0:
        ci = jnp.full((B,), ci, jnp.int32)
    cnt = jnp.asarray(count, jnp.int32)
    if cfg.rope_theta > 0:
        pos = ci[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    k_cache = kv_cache_update_span(cache["k"], k, ci, cnt)
    v_cache = kv_cache_update_span(cache["v"], v, ci, cnt)
    out = prefill_attention(q, k_cache, v_cache, ci)
    y = dense_apply(p["wo"], out.reshape(B, C, H * h))
    return y, k_cache, v_cache


def attn_decode_apply(
    p: dict, cfg, x: jnp.ndarray, cache: dict, cache_index,
    *, layer_window: int = -1,
) -> tuple:
    """One-token decode; cache = {"k": (B,T,KV,h), "v": ...}. Returns
    (out, new_cache).  ``cache_index`` scalar or per-row ``(B,)``."""
    B, _, d = x.shape
    H, KV, h = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.sliding_window if layer_window < 0 else layer_window
    q = dense_apply(p["wq"], x).reshape(B, 1, H, h)
    k = dense_apply(p["wk"], x).reshape(B, 1, KV, h)
    v = dense_apply(p["wv"], x).reshape(B, 1, KV, h)
    if cfg.rope_theta > 0:
        pos = decode_positions(cache_index, B)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    k_cache = kv_cache_update(cache["k"], k, cache_index)
    v_cache = kv_cache_update(cache["v"], v, cache_index)
    out = decode_attention(q, k_cache, v_cache,
                           jnp.asarray(cache_index) + 1, window=window)
    y = dense_apply(p["wo"], out.reshape(B, 1, H * h))
    return y, {"k": k_cache, "v": v_cache}


def cross_decode_apply(p: dict, cfg, x: jnp.ndarray, cross_kv: dict):
    """Decode-time cross attention against precomputed encoder K/V."""
    B = x.shape[0]
    H, KV, h = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense_apply(p["wq"], x).reshape(B, 1, H, h)
    T = cross_kv["k"].shape[1]
    out = decode_attention(q, cross_kv["k"], cross_kv["v"],
                           jnp.asarray(T, jnp.int32), window=0)
    return dense_apply(p["wo"], out.reshape(B, 1, H * h))
