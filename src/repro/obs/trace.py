"""Low-overhead span/instant tracing for the DCAFE runtime.

The paper's evaluation is *dynamic* evidence — #async/#finish counts and
wall-time distributions.  ``SchedTelemetry`` reproduces the counts but
throws away the *when*; this module keeps the when, cheaply enough to be
compiled into every hot path:

* **Default-off costs ~nothing.**  Every emit site starts with one read
  of the module flag ``_ENABLED`` (a plain global: no lock, no attribute
  chain).  ``trace_span`` returns a shared no-op context manager when
  disabled — no allocation, no clock read.
* **No locks or allocation churn on the hot path when enabled.**  Each
  thread owns a bounded ring (:class:`Ring`) reached through a
  ``threading.local``; an event is one tuple append (or slot store once
  the ring wraps).  The only lock is taken once per *thread lifetime*,
  to register a new ring.
* **Bounded memory.**  Rings hold at most ``capacity`` events; older
  events are overwritten and counted in ``Ring.dropped`` — a tracer must
  never be the thing that OOMs the job it is observing.

Event vocabulary (what the exporter and the CI conservation gate rely
on): *instants* are emitted exactly where the matching
:class:`~repro.sched.telemetry.SchedTelemetry` counter is bumped —
``spawn``/``join``/``steal``/``split``/``complete``/``error``/``admit``
(each carries an integer weight ``n`` so batched bumps stay one event)
— and *spans* mark occupancy and stalls: ``cat="worker"`` spans
(``task``/``drain``/``shard_write``) are a worker's busy time,
``cat="sched"`` spans (``join_stall``/``park``/``steal``) are waiting,
and surface categories (``serve``/``train``/``ckpt``/``ep``) break a
step into phases.  See ``docs/obs.md``.

Environment wiring: ``REPRO_TRACE=/path/out.json`` enables tracing at
import and registers an ``atexit`` export, so any entry point (pytest,
launchers, benches) can be traced without code changes.
``REPRO_TRACE_CAP`` overrides the default per-thread ring capacity.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

perf_counter_ns = time.perf_counter_ns

#: THE module flag — read (unsynchronised, GIL-consistent) at the top of
#: every emit path.  Rebinding a module global is atomic, so enable/
#: disable need no lock either.
_ENABLED = False

#: Default per-thread ring capacity (events).  ~56 bytes/tuple → a few
#: MB per busy thread at the default; REPRO_TRACE_CAP overrides.
DEFAULT_CAPACITY = int(os.environ.get("REPRO_TRACE_CAP", 65536))

_capacity = DEFAULT_CAPACITY

#: ring registry: every ring ever created this process (rings of dead
#: threads stay — their events are part of the trace).  Guarded by
#: ``_reg_lock``; touched once per thread lifetime, never per event.
_rings: List["Ring"] = []
_reg_lock = threading.Lock()
_tls = threading.local()
#: epoch counter: ``clear()`` bumps it so threads holding a stale tls
#: ring re-register after a clear-and-restart (e.g. between benches)
_epoch = 0


class Ring:
    """One thread's bounded event buffer.

    An event is the tuple ``(ph, ts_ns, dur_ns, cat, name, n, args)``
    with ``ph`` in ``{"X", "i"}`` (Chrome trace-event phase codes:
    complete span / instant).  Append-until-full, then overwrite oldest
    (``dropped`` counts overwrites) — emit is O(1) and allocation-free
    beyond the event tuple itself.
    """

    __slots__ = ("events", "capacity", "idx", "dropped", "tid", "name",
                 "open_spans")

    def __init__(self, capacity: int, tid: int, name: str):
        self.events: List[Tuple] = []
        self.capacity = capacity
        self.idx = 0          # next overwrite slot once wrapped
        self.dropped = 0
        self.tid = tid
        self.name = name
        #: spans entered but not yet exited on this thread (LIFO).  An
        #: export sweeps these into truncated spans so a crash/incident
        #: dump shows what was in flight, instead of dropping them.
        self.open_spans: List["_Span"] = []

    def emit(self, ev: Tuple):
        evs = self.events
        if len(evs) < self.capacity:
            evs.append(ev)
        else:
            evs[self.idx] = ev
            self.idx = (self.idx + 1) % self.capacity
            self.dropped += 1

    def ordered(self) -> List[Tuple]:
        """Events oldest-first (un-wrapping the overwrite cursor)."""
        if len(self.events) < self.capacity or self.idx == 0:
            return list(self.events)
        return self.events[self.idx:] + self.events[: self.idx]

    def reset(self):
        self.events = []
        self.idx = 0
        self.dropped = 0


def _ring() -> Ring:
    r = getattr(_tls, "ring", None)
    if r is not None and getattr(_tls, "epoch", None) == _epoch:
        return r
    t = threading.current_thread()
    r = Ring(_capacity, t.ident or 0, t.name)
    with _reg_lock:
        _rings.append(r)
    _tls.ring = r
    _tls.epoch = _epoch
    return r


# -- control -----------------------------------------------------------------

def enabled() -> bool:
    return _ENABLED


def enable(capacity: Optional[int] = None):
    """Turn the tracer on process-wide.  ``capacity`` applies to rings
    created from now on (existing rings keep theirs)."""
    global _ENABLED, _capacity
    if capacity is not None:
        _capacity = capacity
    _ENABLED = True


def disable():
    global _ENABLED
    _ENABLED = False


def clear():
    """Drop every buffered event (all rings, all threads).  Threads
    re-register their ring on next emit (epoch bump), so a bench can
    trace several isolated passes in one process."""
    global _epoch
    with _reg_lock:
        _epoch += 1
        _rings.clear()
    # the calling thread's stale tls ring is invalidated by the epoch


# -- emit --------------------------------------------------------------------

def instant(cat: str, name: str, n: int = 1,
            args: Optional[Dict[str, Any]] = None):
    """Record an instant event.  ``n`` is the event's integer weight: a
    batched counter bump (``spawns += len(tasks)``) stays ONE event, and
    the conservation cross-check sums weights, not rows."""
    if not _ENABLED:
        return
    _ring().emit(("i", perf_counter_ns(), 0, cat, name, n, args))


def complete_span(cat: str, name: str, t0_ns: int,
                  args: Optional[Dict[str, Any]] = None):
    """Record a span that started at ``t0_ns`` and ends now — for sites
    that only want the event on one outcome (e.g. a *successful* steal:
    the caller reads the clock up front, and failed scans emit nothing).
    """
    if not _ENABLED:
        return
    _ring().emit(("X", t0_ns, perf_counter_ns() - t0_ns, cat, name, 1, args))


class _Span:
    __slots__ = ("cat", "name", "args", "t0", "_ring")

    def __init__(self, cat: str, name: str, args):
        self.cat = cat
        self.name = name
        self.args = args

    def __enter__(self):
        r = _ring()
        self._ring = r
        self.t0 = perf_counter_ns()
        r.open_spans.append(self)
        return self

    def __exit__(self, *exc):
        # De-register from the ring we registered on (a clear() between
        # enter and exit leaves a stale ring — removal is then a no-op on
        # a discarded object, which is the right outcome: cleared spans
        # are gone).  Spans nest LIFO per thread, so pop is the fast path.
        ops = self._ring.open_spans
        if ops and ops[-1] is self:
            ops.pop()
        else:  # clear() raced us, or exit out of order
            try:
                ops.remove(self)
            except ValueError:
                pass
        if _ENABLED:  # re-check: disable() mid-span drops the event
            _ring().emit(("X", self.t0, perf_counter_ns() - self.t0,
                          self.cat, self.name, 1, self.args))
        return False


class _NoopSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


def trace_span(cat: str, name: str,
               args: Optional[Dict[str, Any]] = None):
    """Context manager timing a span.  Disabled: returns a shared no-op
    (one global read, zero allocation)."""
    if not _ENABLED:
        return _NOOP
    return _Span(cat, name, args)


# -- reading -----------------------------------------------------------------

def snapshot() -> List[Dict[str, Any]]:
    """All buffered events as dicts (oldest-first per thread), each
    carrying its thread identity — the exporter's input."""
    with _reg_lock:
        rings = list(_rings)
    out = []
    for r in rings:
        for ph, ts, dur, cat, name, n, args in r.ordered():
            out.append(dict(ph=ph, ts_ns=ts, dur_ns=dur, cat=cat,
                            name=name, n=n, args=args, tid=r.tid,
                            thread=r.name))
    return out


def open_span_events(end_ns: Optional[int] = None) -> List[Dict[str, Any]]:
    """Spans currently in flight, as *truncated* span events: same shape
    as :func:`snapshot` entries plus ``trunc=True``, with the end forced
    to now (or ``end_ns``).  An export that only read the rings would
    silently drop whatever was mid-flight at shutdown or at an incident
    — exactly the spans a crash dump needs most."""
    end = perf_counter_ns() if end_ns is None else end_ns
    with _reg_lock:
        rings = list(_rings)
    out = []
    for r in rings:
        for sp in list(r.open_spans):
            out.append(dict(ph="X", ts_ns=sp.t0, dur_ns=max(0, end - sp.t0),
                            cat=sp.cat, name=sp.name, n=1, args=sp.args,
                            tid=r.tid, thread=r.name, trunc=True))
    return out


def ring_stats() -> List[Dict[str, Any]]:
    """Per-ring occupancy/drop accounting (the bound tests read this)."""
    with _reg_lock:
        rings = list(_rings)
    return [dict(thread=r.name, tid=r.tid, n_events=len(r.events),
                 capacity=r.capacity, dropped=r.dropped) for r in rings]


# -- env wiring --------------------------------------------------------------

_ENV_TRACE = os.environ.get("REPRO_TRACE")
if _ENV_TRACE:
    import atexit

    enable()

    def _export_at_exit(path=_ENV_TRACE):
        from .export import write_chrome_trace

        write_chrome_trace(path)

    atexit.register(_export_at_exit)
