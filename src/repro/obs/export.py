"""Trace export + trace-derived metrics.

Two consumers, one format:

* **Perfetto / ``chrome://tracing``** — :func:`chrome_trace` merges the
  per-thread rings into Chrome trace-event JSON (``traceEvents`` with
  ``ph="X"`` complete spans and ``ph="i"`` instants, one track per
  worker thread via ``thread_name`` metadata).  Extra top-level keys
  (the Chrome format explicitly allows them) carry the telemetry
  summary and the derived metrics, so one artifact is both loadable in
  a viewer and machine-checkable in CI.
* **CI conservation gates** — :func:`counts_from_chrome` re-derives the
  spawn/join/steal/split/complete/error counts from the instant events
  (summing each event's integer weight ``n``) and :func:`crosscheck`
  asserts they equal ``SchedTelemetry.summary()``.  The trace cannot
  silently lie about the counts the paper's Fig. 10 argument rests on.

Derived metrics (:func:`derived_metrics`), all computed *from the
trace*: per-worker occupancy/idle fractions (busy = ``cat="worker"``
span time), park time, and per-span-name duration breakdowns
(``join_stall``, ``steal``, serve/train/ckpt/ep phases) with
p50/p99/max — the queue-wait and join-stall story ``report()``'s
medians cannot tell.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from ..sched.telemetry import percentile
from . import trace as _trace

#: instant-event names whose weights must reconcile with the
#: SchedTelemetry counter of the same name (the conservation contract)
COUNTER_EVENTS = ("spawns", "joins", "steals", "splits", "completions",
                  "errors", "cancelled", "retries", "worker_deaths")
#: instant name (singular, as emitted) → telemetry summary key
_EVENT_TO_COUNTER = {
    "spawn": "spawns", "join": "joins", "steal": "steals",
    "split": "splits", "complete": "completions", "error": "errors",
    "cancel": "cancelled", "retry": "retries",
    "worker_death": "worker_deaths",
}
#: span categories counted as worker *busy* time (occupancy numerator);
#: these spans never nest within each other by construction
WORKER_CATS = ("worker",)


def chrome_trace(events: Optional[List[Dict]] = None,
                 extra: Optional[Dict[str, Any]] = None,
                 include_open: bool = True) -> Dict[str, Any]:
    """Snapshot (or take) raw events and render Chrome trace-event JSON.

    Timestamps are microseconds (the format's unit), rebased to the
    earliest event so traces start near t=0 in a viewer.

    When snapshotting (``events is None``), spans still open at export
    time are swept in as truncated spans (``"trunc": true``, end = now)
    — an atexit/incident export must show what was in flight, not drop
    it.  Truncated spans never carry counter instants, so they cannot
    disturb the conservation cross-check.
    """
    if events is None:
        events = _trace.snapshot()
        if include_open:
            events = events + _trace.open_span_events()
    t0 = min((e["ts_ns"] for e in events), default=0)
    out: List[Dict[str, Any]] = []
    threads = {}
    for e in events:
        threads.setdefault(e["tid"], e["thread"])
        rec: Dict[str, Any] = {
            "name": e["name"], "cat": e["cat"], "ph": e["ph"],
            "ts": (e["ts_ns"] - t0) / 1e3, "pid": 0, "tid": e["tid"],
            "args": dict(e["args"] or {}, n=e["n"]),
        }
        if e["ph"] == "X":
            rec["dur"] = e["dur_ns"] / 1e3
            if e.get("trunc"):
                rec["trunc"] = True
                rec["args"]["trunc"] = True  # survives viewer round-trips
        else:
            rec["s"] = "t"  # instant scope: thread
        out.append(rec)
    # one named track per thread (workers are named by their executor)
    for tid, name in sorted(threads.items()):
        out.append({"name": "thread_name", "ph": "M", "pid": 0,
                    "tid": tid, "args": {"name": name}})
    doc: Dict[str, Any] = {"traceEvents": out, "displayTimeUnit": "ms"}
    if extra:
        doc.update(extra)
    return doc


def write_chrome_trace(path: str,
                       events: Optional[List[Dict]] = None,
                       extra: Optional[Dict[str, Any]] = None,
                       derive: bool = True) -> Dict[str, Any]:
    """Export to ``path`` (Perfetto-loadable), embedding the derived
    metrics (and any ``extra`` keys, e.g. ``{"telemetry": summary}``)
    as top-level siblings of ``traceEvents``."""
    doc = chrome_trace(events, extra)
    if derive:
        doc["derived"] = derived_metrics(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return doc


def _trace_events(doc_or_events) -> List[Dict]:
    if isinstance(doc_or_events, dict):
        return doc_or_events["traceEvents"]
    return doc_or_events


def counts_from_chrome(doc_or_events) -> Dict[str, int]:
    """Re-derive the telemetry counters from the exported instants —
    each counter is the sum of its events' integer weights."""
    counts = {k: 0 for k in COUNTER_EVENTS}
    for e in _trace_events(doc_or_events):
        if e.get("ph") != "i":
            continue
        key = _EVENT_TO_COUNTER.get(e["name"])
        if key is not None:
            counts[key] += int(e.get("args", {}).get("n", 1))
    return counts


def errors_by_site_from_chrome(doc_or_events) -> Dict[str, int]:
    """Per-site error counts re-derived from the ``error`` instants'
    ``site`` args — the error-instant conservation side of
    ``SchedTelemetry.errors_by_site``.  Events without a site (legacy
    traces) land under ``"?"``."""
    out: Dict[str, int] = {}
    for e in _trace_events(doc_or_events):
        if e.get("ph") != "i" or e.get("name") != "error":
            continue
        args = e.get("args") or {}
        site = args.get("site", "?")
        out[site] = out.get(site, 0) + int(args.get("n", 1))
    return out


def exchange_counts_from_chrome(doc_or_events) -> Dict[str, int]:
    """EP round edges re-derived from the ``round_posted`` /
    ``round_completed`` instants (cat ``ep``)."""
    posted = completed = 0
    for e in _trace_events(doc_or_events):
        if e.get("ph") != "i" or e.get("cat") != "ep":
            continue
        if e["name"] == "round_posted":
            posted += int(e.get("args", {}).get("n", 1))
        elif e["name"] == "round_completed":
            completed += int(e.get("args", {}).get("n", 1))
    return {"posted": posted, "completed": completed}


def _span_stats(durs_us: List[float]) -> Dict[str, float]:
    ms = [d / 1e3 for d in durs_us]
    return dict(count=len(ms), total_ms=round(sum(ms), 3),
                p50_ms=round(percentile(ms, 50), 4),
                p99_ms=round(percentile(ms, 99), 4),
                max_ms=round(max(ms), 4))


def derived_metrics(doc_or_events) -> Dict[str, Any]:
    """Metrics computed purely from the trace: wall span, per-worker
    occupancy/idle/park fractions, per-name span breakdowns, and the
    re-derived counts."""
    events = _trace_events(doc_or_events)
    xs = [e for e in events if e.get("ph") == "X"]
    all_ts = [e["ts"] for e in events if e.get("ph") in ("X", "i")]
    if not all_ts:
        return {"wall_ms": 0.0, "per_worker": {}, "span_stats": {},
                "counts": counts_from_chrome(events)}
    end = max((e["ts"] + e.get("dur", 0)) for e in events
              if e.get("ph") in ("X", "i"))
    wall_us = max(end - min(all_ts), 1e-9)

    per_worker: Dict[str, Dict[str, float]] = {}
    busy: Dict[Any, float] = {}
    park: Dict[Any, float] = {}
    names: Dict[str, List[float]] = {}
    for e in xs:
        key = f"{e.get('cat', '')}.{e['name']}"
        names.setdefault(key, []).append(e.get("dur", 0.0))
        if e.get("cat") in WORKER_CATS:
            busy[e["tid"]] = busy.get(e["tid"], 0.0) + e.get("dur", 0.0)
        elif e["name"] == "park":
            park[e["tid"]] = park.get(e["tid"], 0.0) + e.get("dur", 0.0)
    for tid in sorted(set(busy) | set(park), key=str):
        b = busy.get(tid, 0.0)
        per_worker[str(tid)] = dict(
            busy_ms=round(b / 1e3, 3),
            occupancy=round(b / wall_us, 4),
            idle_frac=round(1.0 - min(b / wall_us, 1.0), 4),
            park_ms=round(park.get(tid, 0.0) / 1e3, 3))
    return {
        "wall_ms": round(wall_us / 1e3, 3),
        "per_worker": per_worker,
        "span_stats": {k: _span_stats(v) for k, v in sorted(names.items())},
        "counts": counts_from_chrome(events),
        "exchange": exchange_counts_from_chrome(events),
    }


def crosscheck(doc_or_events, telemetry_summary: Dict[str, Any]
               ) -> Dict[str, Any]:
    """Compare trace-derived counts with a ``SchedTelemetry.summary()``.

    Returns ``{"ok", "mismatches", "trace", "telemetry"}``; callers
    (benches, CI gates, tests) assert on ``ok``.  Only counters present
    in the summary are compared — a surface that never steals is not
    penalised for a zero.
    """
    tcounts = counts_from_chrome(doc_or_events)
    mismatches = []
    checked = {}
    for key in COUNTER_EVENTS:
        if key not in telemetry_summary:
            continue
        want = int(telemetry_summary[key])
        got = tcounts[key]
        checked[key] = want
        if got != want:
            mismatches.append(f"{key}: trace={got} telemetry={want}")
    by_site = telemetry_summary.get("errors_by_site")
    if by_site:
        got_site = errors_by_site_from_chrome(doc_or_events)
        for site, want in sorted(by_site.items()):
            checked[f"errors_by_site.{site}"] = want
            if got_site.get(site, 0) != int(want):
                mismatches.append(f"errors_by_site.{site}: "
                                  f"trace={got_site.get(site, 0)} "
                                  f"telemetry={want}")
    ex = telemetry_summary.get("exchange")
    if ex:
        got_ex = exchange_counts_from_chrome(doc_or_events)
        for key in ("posted", "completed"):
            if key in ex:
                checked[f"exchange.{key}"] = ex[key]
                if got_ex[key] != int(ex[key]):
                    mismatches.append(f"exchange.{key}: "
                                      f"trace={got_ex[key]} "
                                      f"telemetry={ex[key]}")
    return {"ok": not mismatches, "mismatches": mismatches,
            "trace": tcounts, "telemetry": checked}
