"""Always-on metrics plane: counters, gauges, and log-histograms with
windowed snapshot *deltas*.

Tracing (:mod:`repro.obs.trace`) answers *when* and is default-off; the
metrics registry answers *how much, lately* and is **default-on** — the
streaming visibility a serving fleet needs while the process runs.  The
cost model that makes always-on viable:

* **Emit is lock-free.**  A handle (:class:`Counter`/:class:`Gauge`/
  :class:`Histogram`) is looked up once (one registry-lock acquisition
  per metric *lifetime*) and then bumped with plain attribute writes —
  the same single-writer-per-surface discipline ``SchedTelemetry``
  already relies on.  Like the tracer, every bump starts with one read
  of a module flag, so :func:`disable` exists for A/B overhead
  measurement (``bench_grain`` gates the enabled cost ≤ 5% on the
  uniform micro-loop).
* **Readers never reset writers.**  Per-interval rates and windowed
  p50/p99 come from *diffing two cumulative snapshots*
  (:meth:`MetricsSnapshot.delta`, backed by ``LogHistogram.diff``) —
  never from zeroing live state under a writer's feet.
* **Bounded retention.**  The background :class:`Snapshotter` samples
  the registry into a deque of per-interval records (and optionally
  streams them as JSON lines): ``REPRO_METRICS=/path/metrics.jsonl``
  on any entry point, or ``--metrics-json`` on the launchers.

Metric naming: ``<surface>.<noun>[_<unit>]`` — e.g. ``sched.loops``,
``serve.queue_depth``, ``train.step_s``.  See docs/obs.md ("Online
metrics, SLOs, and the flight recorder").
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from ..sched.telemetry import LogHistogram

#: THE module flag — read at the top of every bump.  Metrics are
#: ALWAYS-ON by default (the opposite of the tracer): ``disable()`` is
#: for overhead A/B measurement and tests, not for production.
_ENABLED = True


def enabled() -> bool:
    return _ENABLED


def enable():
    global _ENABLED
    _ENABLED = True


def disable():
    global _ENABLED
    _ENABLED = False


class Counter:
    """Monotone counter.  Single-writer discipline (or tolerable races
    on a GIL runtime): the bump is a plain attribute add, no lock."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1):
        if _ENABLED:
            self.value += n


class Gauge:
    """Last-write-wins instantaneous value (queue depth, in-flight)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float):
        if _ENABLED:
            self.value = v


class Histogram:
    """Cumulative :class:`LogHistogram` of positive samples (seconds by
    convention — name the metric ``*_s``).  Windowed percentiles come
    from snapshot diffing, never from resetting this object."""

    __slots__ = ("name", "hist")

    def __init__(self, name: str):
        self.name = name
        self.hist = LogHistogram()

    def observe(self, seconds: float):
        if _ENABLED:
            self.hist.add(seconds)


class MetricsSnapshot:
    """Point-in-time copy of a registry.  Cheap: counters/gauges are
    scalar copies, histograms copy their 64-int bucket list."""

    __slots__ = ("t_ns", "t_wall", "counters", "gauges", "hists")

    def __init__(self, t_ns: int, t_wall: float, counters: Dict[str, int],
                 gauges: Dict[str, float], hists: Dict[str, LogHistogram]):
        self.t_ns = t_ns
        self.t_wall = t_wall
        self.counters = counters
        self.gauges = gauges
        self.hists = hists

    def delta(self, older: "MetricsSnapshot") -> Dict[str, Any]:
        """The per-interval record between two snapshots: counter deltas
        and rates over the interval, windowed histogram percentiles via
        ``LogHistogram.diff``, and the gauges' latest values."""
        dt_s = max((self.t_ns - older.t_ns) / 1e9, 1e-9)
        counters = {k: v - older.counters.get(k, 0)
                    for k, v in sorted(self.counters.items())}
        hists = {}
        for name, h in sorted(self.hists.items()):
            old = older.hists.get(name)
            w = h.diff(old) if old is not None else h
            hists[name] = dict(n=w.n,
                               p50_ms=round(w.percentile(50) * 1e3, 4),
                               p99_ms=round(w.percentile(99) * 1e3, 4),
                               max_ms=round(w.max * 1e3, 4) if w.n else 0.0)
        return {
            "t": round(self.t_wall, 6),
            "dt_s": round(dt_s, 6),
            "counters": counters,
            "rates": {k: round(v / dt_s, 3) for k, v in counters.items()},
            "gauges": dict(sorted(self.gauges.items())),
            "hists": hists,
        }

    def summary(self) -> Dict[str, Any]:
        """Cumulative view (incident reports embed before/after pairs)."""
        return {
            "t": round(self.t_wall, 6),
            "counters": dict(sorted(self.counters.items())),
            "gauges": dict(sorted(self.gauges.items())),
            "hists": {k: dict(n=h.n,
                              p50_ms=round(h.percentile(50) * 1e3, 4),
                              p99_ms=round(h.percentile(99) * 1e3, 4))
                      for k, h in sorted(self.hists.items())},
        }


class MetricsRegistry:
    """Named metric handles, created on first use.  The registry lock is
    taken only at handle creation and at snapshot time — never on the
    bump path."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        #: pull sources: ``name -> fn()`` returning a flat numeric dict,
        #: sampled into gauges at snapshot time (lets surfaces that
        #: already keep counters — SchedTelemetry, ServeStats — show up
        #: in the stream without double instrumentation on hot paths).
        self._sources: Dict[str, Callable[[], Dict[str, float]]] = {}

    def _get(self, store: Dict, name: str, cls):
        m = store.get(name)
        if m is None:
            with self._lock:
                m = store.get(name)
                if m is None:
                    m = store[name] = cls(name)
        return m

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._hists, name, Histogram)

    def add_source(self, name: str, fn: Callable[[], Dict[str, float]]):
        with self._lock:
            self._sources[name] = fn

    def remove_source(self, name: str):
        with self._lock:
            self._sources.pop(name, None)

    def snapshot(self) -> MetricsSnapshot:
        with self._lock:
            counters = {k: c.value for k, c in self._counters.items()}
            gauges = {k: g.value for k, g in self._gauges.items()}
            hists = {k: h.hist.copy() for k, h in self._hists.items()}
            sources = list(self._sources.items())
        for prefix, fn in sources:
            try:
                for k, v in (fn() or {}).items():
                    gauges[f"{prefix}.{k}"] = v
            except Exception:  # a broken source must not kill sampling
                gauges[f"{prefix}.source_error"] = 1.0
        return MetricsSnapshot(time.perf_counter_ns(), time.time(),
                               counters, gauges, hists)

    def reset(self):
        """Tests only — production readers diff snapshots instead."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._sources.clear()


#: the process-wide default registry (surfaces bump this one)
REGISTRY = MetricsRegistry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def snapshot() -> MetricsSnapshot:
    return REGISTRY.snapshot()


class Snapshotter:
    """Background sampler: every ``interval_s`` it snapshots the
    registry, diffs against the previous snapshot, keeps the interval
    record in a bounded ring, and (optionally) appends it as one JSON
    line to ``path``.  ``sample()`` is public so tests and single-step
    callers can drive it deterministically without the thread."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 interval_s: float = 1.0, path: Optional[str] = None,
                 capacity: int = 512):
        self.registry = registry if registry is not None else REGISTRY
        self.interval_s = interval_s
        self.path = path
        self.capacity = capacity
        self.records: List[Dict[str, Any]] = []
        self._prev = self.registry.snapshot()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._file = None

    def sample(self) -> Dict[str, Any]:
        cur = self.registry.snapshot()
        rec = cur.delta(self._prev)
        self._prev = cur
        self.records.append(rec)
        if len(self.records) > self.capacity:  # bounded time-series ring
            del self.records[: len(self.records) - self.capacity]
        if self._file is not None:
            self._file.write(json.dumps(rec) + "\n")
            self._file.flush()
        return rec

    def _loop(self):
        while not self._stop.wait(self.interval_s):
            self.sample()

    def start(self) -> "Snapshotter":
        if self.path is not None and self._file is None:
            self._file = open(self.path, "w")
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop,
                                            name="metrics-snapshotter",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self, final_sample: bool = True):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if final_sample:
            self.sample()  # flush the tail window
        if self._file is not None:
            self._file.close()
            self._file = None

    def __enter__(self) -> "Snapshotter":
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False


# -- env wiring --------------------------------------------------------------

_ENV_METRICS = os.environ.get("REPRO_METRICS")
if _ENV_METRICS:
    import atexit

    _ENV_SNAPSHOTTER = Snapshotter(
        interval_s=float(os.environ.get("REPRO_METRICS_INTERVAL", "1.0")),
        path=_ENV_METRICS).start()
    atexit.register(_ENV_SNAPSHOTTER.stop)
