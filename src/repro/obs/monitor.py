"""Incident flight recorder, per-tenant SLO burn-rate monitor, and the
finish-scope stall watchdog.

The PR-6 trace rings keep recording cheaply; what changes here is *when
the export happens*: not at atexit, but the moment something goes wrong
— an SLO error budget burning out, a ``FinishScope`` join pending past
its deadline, a join surfacing ``MultipleExceptions``, or an EP round
running degraded.  Each trigger dumps a structured **incident report**:

* ``trigger`` / ``reason`` / the implicated tenant, scope, shard, site;
* ``metrics_before`` / ``metrics_after`` — registry snapshots from the
  last arm point and from the moment of the incident;
* ``telemetry_window`` — the counter *delta* since the recorder was
  armed (:meth:`SchedTelemetry.counters_snapshot` diffing);
* ``trace`` — the trace window since arm, spans still in flight swept
  in as truncated spans (``"trunc": true``);
* ``crosscheck`` — the PR-6 conservation contract applied to exactly
  that window: the instants in the dumped trace must re-derive the
  counter deltas.  An incident report that lies about its own window is
  itself a failure (``gates slo`` replays this in CI).

Wiring is the faults-harness idiom: a module-level recorder installed
with :func:`install` (default ``None`` = every hook is one global read
and out), consulted by the executors (join failures/timeouts), the
batcher (:class:`SloMonitor`), EP dispatch (degraded rounds), and the
:class:`StallWatchdog` thread.  See docs/obs.md ("Online metrics, SLOs,
and the flight recorder").
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

from ..sched.telemetry import diff_counters
from . import export as _export
from . import metrics as _metrics
from . import trace as _trace

#: incident triggers (the report's ``trigger`` field)
TRIGGERS = ("slo_burn", "join_stall", "multiple_exceptions", "ep_degraded")

INCIDENT_SCHEMA = 1


class FlightRecorder:
    """Triggered trace export + structured incident reports.

    ``arm()`` marks the window start: it clears the trace rings (when
    tracing is on) and snapshots the telemetry counters and the metrics
    registry.  ``record()`` dumps everything since — so the embedded
    trace window and the embedded counter delta describe the *same*
    interval and must reconcile under ``crosscheck()``.
    """

    def __init__(self, telemetry=None, out_dir: Optional[str] = None,
                 registry: Optional[_metrics.MetricsRegistry] = None,
                 capacity: int = 64, min_interval_s: float = 0.0):
        self.telemetry = telemetry
        self.out_dir = out_dir
        self.registry = registry if registry is not None else _metrics.REGISTRY
        self.capacity = capacity
        self.min_interval_s = min_interval_s
        self.incidents: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._baseline: Optional[Dict] = None
        self._metrics_before: Optional[_metrics.MetricsSnapshot] = None
        self._last_fire: Dict[str, float] = {}
        self._seq = 0

    def arm(self, clear_trace: bool = True) -> "FlightRecorder":
        """Start a fresh window.  With tracing enabled the rings are
        cleared so events-since-arm is exactly what the rings hold."""
        if clear_trace and _trace.enabled():
            _trace.clear()
        if self.telemetry is not None:
            self._baseline = self.telemetry.counters_snapshot()
        self._metrics_before = self.registry.snapshot()
        return self

    def record(self, trigger: str, reason: str, *,
               tenant: Optional[str] = None, scope: Optional[str] = None,
               shard: Optional[Any] = None, site: Optional[str] = None,
               extra: Optional[Dict[str, Any]] = None,
               ) -> Optional[Dict[str, Any]]:
        """Fire one incident.  Returns the report, or ``None`` when the
        per-trigger rate limit suppressed it.  Never raises: a flight
        recorder must not take down the thing it is observing — capture
        failures are reported inside the incident instead."""
        if trigger not in TRIGGERS:
            raise ValueError(f"unknown trigger {trigger!r} (not in "
                             f"{TRIGGERS})")
        now = time.perf_counter()
        with self._lock:
            last = self._last_fire.get(trigger)
            if last is not None and now - last < self.min_interval_s:
                return None
            self._last_fire[trigger] = now
            self._seq += 1
            seq = self._seq
        report: Dict[str, Any] = {
            "schema": INCIDENT_SCHEMA,
            "seq": seq,
            "trigger": trigger,
            "reason": reason,
            "t_wall": time.time(),
            "implicated": {k: v for k, v in dict(
                tenant=tenant, scope=scope, shard=shard, site=site,
            ).items() if v is not None},
            "extra": extra or {},
        }
        try:
            after = self.registry.snapshot()
            if self._metrics_before is not None:
                report["metrics_before"] = self._metrics_before.summary()
                report["metrics_window"] = after.delta(self._metrics_before)
            report["metrics_after"] = after.summary()
            if self.telemetry is not None and self._baseline is not None:
                report["telemetry_window"] = diff_counters(
                    self.telemetry.counters_snapshot(), self._baseline)
            if _trace.enabled():
                doc = _export.chrome_trace()  # sweeps open spans (trunc)
                report["trace"] = doc
                if "telemetry_window" in report:
                    report["crosscheck"] = _export.crosscheck(
                        doc, report["telemetry_window"])
        except Exception as e:  # pragma: no cover - capture must not kill
            report["capture_error"] = f"{type(e).__name__}: {e}"
        with self._lock:
            self.incidents.append(report)
            if len(self.incidents) > self.capacity:
                del self.incidents[: len(self.incidents) - self.capacity]
        self._persist(report)
        return report

    def _persist(self, report: Dict[str, Any]):
        if self.out_dir is None:
            return
        try:
            os.makedirs(self.out_dir, exist_ok=True)
            name = f"incident-{report['seq']:03d}-{report['trigger']}.json"
            with open(os.path.join(self.out_dir, name), "w") as f:
                json.dump(report, f, indent=1)
        except OSError:  # pragma: no cover - best-effort persistence
            pass

    def count(self, trigger: Optional[str] = None) -> int:
        with self._lock:
            if trigger is None:
                return len(self.incidents)
            return sum(1 for i in self.incidents if i["trigger"] == trigger)


#: the module-level recorder: ``None`` (default) makes every hook one
#: global read — the faults-harness default-off idiom.
_RECORDER: Optional[FlightRecorder] = None


def install(recorder: FlightRecorder) -> FlightRecorder:
    global _RECORDER
    _RECORDER = recorder
    return recorder


def uninstall():
    global _RECORDER
    _RECORDER = None


def active() -> Optional[FlightRecorder]:
    return _RECORDER


class recording:
    """``with recording(FlightRecorder(...)) as rec:`` — scoped install,
    mirroring ``injected_faults`` from the fault harness."""

    def __init__(self, recorder: FlightRecorder):
        self.recorder = recorder

    def __enter__(self) -> FlightRecorder:
        return install(self.recorder)

    def __exit__(self, *exc):
        uninstall()
        return False


# -- executor-side trigger hooks ---------------------------------------------
# Called from FinishScope.wait; one global read when no recorder is
# installed, so the hot path cost matches the faults harness.

def on_join_failed(scope: Any, error_count: int,
                   site: Optional[str] = None):
    rec = _RECORDER
    if rec is None:
        return
    rec.record("multiple_exceptions",
               f"finish scope join surfaced {error_count} task error(s)",
               scope=type(scope).__name__, site=site,
               extra={"error_count": int(error_count)})


def on_join_timeout(scope: Any, pending: int, timeout_s: float):
    rec = _RECORDER
    if rec is None:
        return
    rec.record("join_stall",
               f"finish scope wait timed out after {timeout_s:.3f}s with "
               f"{pending} waitable(s) pending",
               scope=type(scope).__name__,
               extra={"pending": int(pending),
                      "timeout_s": float(timeout_s)})


def on_ep_degraded(dead_shards: Any, round_errors: int = 0):
    rec = _RECORDER
    if rec is None:
        return
    dead = sorted(dead_shards)
    rec.record("ep_degraded",
               f"EP round ran degraded: {len(dead)} dead shard(s) "
               f"{dead}, lanes rerouted to live shards",
               shard=dead[0] if dead else None, site="ep.round",
               extra={"dead_shards": dead,
                      "round_errors": int(round_errors)})


# -- stall watchdog ----------------------------------------------------------

class StallWatchdog:
    """Daemon thread that fires a ``join_stall`` incident when a watched
    ``FinishScope`` is still pending past its deadline — the stall is
    detected even when nobody is blocked in ``wait(timeout=...)`` (the
    caller may be wedged *inside* the scope, which is exactly when an
    external observer is needed).

    Scopes are watched by duck type: anything with ``pending()`` works.
    A watched scope fires **at most once** (deterministic incident
    counts for the seeded fault tests), and a scope observed quiescent
    is dropped from the watch list.
    """

    def __init__(self, recorder: Optional[FlightRecorder] = None,
                 poll_s: float = 0.01):
        self.recorder = recorder
        self.poll_s = poll_s
        self.fired = 0
        self._watched: Dict[int, Dict[str, Any]] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._next_token = 0

    def watch(self, scope: Any, deadline_s: float,
              label: Optional[str] = None) -> int:
        """Register ``scope``: if it still has pending waitables
        ``deadline_s`` from now, a ``join_stall`` incident fires."""
        with self._lock:
            self._next_token += 1
            token = self._next_token
            self._watched[token] = dict(
                scope=scope, deadline=time.perf_counter() + deadline_s,
                deadline_s=deadline_s, label=label or f"scope-{token}")
        self._ensure_thread()
        return token

    def unwatch(self, token: int):
        with self._lock:
            self._watched.pop(token, None)

    def _ensure_thread(self):
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop,
                                            name="stall-watchdog",
                                            daemon=True)
            self._thread.start()

    def scan(self) -> int:
        """One pass over the watch list (public so tests can drive the
        watchdog without thread-timing dependence).  Returns how many
        incidents this pass fired."""
        now = time.perf_counter()
        with self._lock:
            entries = list(self._watched.items())
        fired = 0
        for token, ent in entries:
            try:
                pending = ent["scope"].pending()
            except Exception:  # a broken scope must not kill the thread
                pending = 0
            if pending == 0:
                self.unwatch(token)
                continue
            if now >= ent["deadline"]:
                self.unwatch(token)  # at most one incident per scope
                fired += 1
                self.fired += 1
                rec = self.recorder if self.recorder is not None \
                    else _RECORDER
                if rec is not None:
                    rec.record(
                        "join_stall",
                        f"watchdog: {ent['label']} still has {pending} "
                        f"waitable(s) pending {ent['deadline_s']:.3f}s "
                        f"past its deadline",
                        scope=ent["label"],
                        extra={"pending": int(pending),
                               "deadline_s": float(ent["deadline_s"])})
        return fired

    def _loop(self):
        while not self._stop.wait(self.poll_s):
            self.scan()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "StallWatchdog":
        return self

    def __exit__(self, *exc):
        self.stop()
        return False


# -- per-tenant SLO burn-rate monitor ----------------------------------------

class TenantBudget:
    """One tenant's sliding-window SLO accounting (monitor-internal)."""

    __slots__ = ("name", "cost_slo", "allowed", "observed_steps",
                 "bad_steps", "fired", "first_burn_step", "costs_seen",
                 "failures_seen", "depth_window")

    def __init__(self, name: str, cost_slo: float, allowed: float):
        self.name = name
        self.cost_slo = cost_slo       # per-token decode-cost ceiling
        self.allowed = allowed         # bad steps the budget tolerates
        self.observed_steps = 0
        self.bad_steps = 0
        self.fired = False
        self.first_burn_step = None
        self.costs_seen = 0            # cursor into decode_step_costs
        self.failures_seen = 0         # failed+expired seen so far
        self.depth_window: List[int] = []

    @property
    def budget_spent(self) -> float:
        """Fraction of the error budget consumed (≥ 1.0 = burned)."""
        return self.bad_steps / self.allowed if self.allowed > 0 else 0.0

    def summary(self) -> Dict[str, Any]:
        return dict(tenant=self.name, cost_slo=self.cost_slo,
                    allowed_bad_steps=self.allowed,
                    observed_steps=self.observed_steps,
                    bad_steps=self.bad_steps,
                    budget_spent=round(self.budget_spent, 4),
                    first_burn_step=self.first_burn_step)


class SloMonitor:
    """Burn-rate/error-budget accounting layered on the batcher's
    ``ServeStats`` — called once per ``ContinuousBatcher.step()``.

    The SLO model (docs/obs.md has the math): a step is **bad** for a
    tenant when any of its decode-step costs recorded that step exceeds
    the tenant's per-token cost ceiling (``TenantQueue.slo_cost``,
    derived from ``slo_steps`` when unset), or one of its requests
    failed/expired that step.  The error budget allows
    ``budget_frac × horizon`` bad steps; when a tenant's ``bad_steps``
    exceeds that, its budget has burned and a single ``slo_burn``
    incident fires (the burn *rate* — bad fraction / budget fraction —
    goes in the report).  Everything is integer step counts over seeded
    runs, so verdicts replay deterministically from the artifact.
    """

    def __init__(self, recorder: Optional[FlightRecorder] = None,
                 budget_frac: float = 0.1, horizon: int = 256,
                 depth_window: int = 64):
        self.recorder = recorder
        self.budget_frac = budget_frac
        self.horizon = horizon
        self.depth_window = depth_window
        self.tenants: Dict[str, TenantBudget] = {}
        self.incidents_fired = 0

    # -- SLO derivation ------------------------------------------------------

    @staticmethod
    def derive_cost_slo(slo_steps: int) -> float:
        """Per-token decode-cost ceiling from a whole-request deadline:
        a request that must finish in ``slo_steps`` steps cannot afford
        individual decode steps costing a large fraction of it.  The
        ceiling is ``max(2, slo_steps / 4)`` vtime steps — pure decode
        (cost 1) always passes, and a co-scheduled whole-prompt prefill
        (cost ≈ 1 + prompt_len) blows it, which is the DLBC chunking
        argument in SLO form."""
        return max(2.0, slo_steps / 4.0)

    def _budget(self, name: str, slo_steps: int,
                slo_cost: float) -> TenantBudget:
        b = self.tenants.get(name)
        if b is None:
            cost = slo_cost if slo_cost > 0 else self.derive_cost_slo(
                slo_steps)
            b = self.tenants[name] = TenantBudget(
                name, cost, self.budget_frac * self.horizon)
        return b

    # -- per-step observation ------------------------------------------------

    def observe(self, batcher, now: int):
        """One batcher step: fold each SLO-carrying tenant's new decode
        costs, failure/expiry deltas, and queue depth into its budget."""
        if batcher.registry is not None:
            names = batcher.registry.names()
        else:
            names = ["default"]
        for name in names:
            slo = batcher._slo_of(name)
            if slo <= 0:
                continue
            slo_cost = 0.0
            if batcher.registry is not None:
                slo_cost = getattr(batcher.registry.get(name),
                                   "slo_cost", 0.0)
            b = self._budget(name, slo, slo_cost)
            if batcher.registry is not None:
                st = batcher.tenant_stats.get(name)
                depth = len(batcher.registry.get(name).queue)
            else:
                st = batcher.stats
                depth = len(batcher.queue)
            if st is None:
                continue
            b.observed_steps += 1
            b.depth_window.append(depth)
            if len(b.depth_window) > self.depth_window:
                del b.depth_window[0]
            _metrics.gauge(f"serve.queue_depth.{name}").set(depth)
            costs = st.decode_step_costs
            new_costs = costs[b.costs_seen:]
            b.costs_seen = len(costs)
            failures = st.failed + st.expired
            bad = (any(c > b.cost_slo for c in new_costs)
                   or failures > b.failures_seen)
            b.failures_seen = failures
            if not bad:
                continue
            b.bad_steps += 1
            _metrics.counter(f"serve.slo_bad_steps.{name}").inc()
            if b.bad_steps > b.allowed and not b.fired:
                b.fired = True
                b.first_burn_step = now
                self.incidents_fired += 1
                self._fire(b, depth_growth=self._depth_growth(b))

    def _depth_growth(self, b: TenantBudget) -> int:
        if len(b.depth_window) < 2:
            return 0
        return b.depth_window[-1] - b.depth_window[0]

    def _fire(self, b: TenantBudget, depth_growth: int):
        rec = self.recorder if self.recorder is not None else _RECORDER
        bad_frac = b.bad_steps / max(1, b.observed_steps)
        burn_rate = bad_frac / self.budget_frac
        _metrics.counter("serve.slo_incidents").inc()
        if rec is None:
            return
        rec.record(
            "slo_burn",
            f"tenant {b.name!r} burned its SLO error budget: "
            f"{b.bad_steps} bad steps > {b.allowed:.1f} allowed "
            f"(burn rate {burn_rate:.2f}x, queue depth growth "
            f"{depth_growth:+d} over the window)",
            tenant=b.name,
            extra=dict(b.summary(), burn_rate=round(burn_rate, 4),
                       budget_frac=self.budget_frac, horizon=self.horizon,
                       queue_depth_growth=depth_growth))

    def summary(self) -> Dict[str, Any]:
        return {
            "budget_frac": self.budget_frac,
            "horizon": self.horizon,
            "incidents_fired": self.incidents_fired,
            "tenants": {n: b.summary()
                        for n, b in sorted(self.tenants.items())},
        }
