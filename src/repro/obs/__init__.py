"""repro.obs — low-overhead span tracing + distribution telemetry.

Two halves:

* :mod:`repro.obs.trace` — per-thread bounded ring buffers of
  span/instant events (``perf_counter_ns``; no locks or allocation on
  the hot path; a single module-flag read when disabled).  The
  scheduling surfaces emit an instant wherever they bump a
  ``SchedTelemetry`` counter and a span around worker busy time and
  phase boundaries (serve step phases, EP round edges, trainer step
  phases, checkpoint shard writes).
* :mod:`repro.obs.export` — merge the rings into Chrome trace-event
  JSON (Perfetto-loadable, one track per worker) plus metrics derived
  *from the trace*: per-worker occupancy/idle, join-stall and steal
  breakdowns, and the conservation cross-check that re-derives the
  spawn/join/steal counts from events and compares them to
  ``SchedTelemetry.summary()``.

Enable per-process with ``REPRO_TRACE=/path/out.json`` (exports at
exit), per-run with the launchers' ``--trace out.json``, or in code
with :func:`repro.obs.enable` + :func:`repro.obs.write_chrome_trace`.
See ``docs/obs.md``.
"""

from .trace import (  # noqa: F401
    DEFAULT_CAPACITY, Ring, clear, complete_span, disable, enable,
    enabled, instant, ring_stats, snapshot, trace_span,
)
from .export import (  # noqa: F401
    chrome_trace, counts_from_chrome, crosscheck, derived_metrics,
    exchange_counts_from_chrome, write_chrome_trace,
)
