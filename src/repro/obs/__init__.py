"""repro.obs — low-overhead span tracing, distribution telemetry, the
always-on metrics plane, and the incident flight recorder.

Four parts:

* :mod:`repro.obs.trace` — per-thread bounded ring buffers of
  span/instant events (``perf_counter_ns``; no locks or allocation on
  the hot path; a single module-flag read when disabled).  The
  scheduling surfaces emit an instant wherever they bump a
  ``SchedTelemetry`` counter and a span around worker busy time and
  phase boundaries (serve step phases, EP round edges, trainer step
  phases, checkpoint shard writes).
* :mod:`repro.obs.export` — merge the rings into Chrome trace-event
  JSON (Perfetto-loadable, one track per worker; spans still open at
  export time are swept in as truncated spans) plus metrics derived
  *from the trace*: per-worker occupancy/idle, join-stall and steal
  breakdowns, and the conservation cross-check that re-derives the
  spawn/join/steal counts from events and compares them to
  ``SchedTelemetry.summary()``.
* :mod:`repro.obs.metrics` — the **default-on** metrics registry
  (counters/gauges/``LogHistogram``\\ s) with windowed snapshot deltas,
  a background :class:`~repro.obs.metrics.Snapshotter` into a bounded
  time-series ring, and JSON-lines streaming
  (``REPRO_METRICS=/path/metrics.jsonl`` or the launchers'
  ``--metrics-json``).
* :mod:`repro.obs.monitor` — the per-tenant SLO burn-rate monitor
  (:class:`~repro.obs.monitor.SloMonitor`), the
  :class:`~repro.obs.monitor.StallWatchdog`, and the
  :class:`~repro.obs.monitor.FlightRecorder` that turns trace export
  from an atexit afterthought into a *triggered* incident dump.

Enable tracing per-process with ``REPRO_TRACE=/path/out.json`` (exports
at exit), per-run with the launchers' ``--trace out.json``, or in code
with :func:`repro.obs.enable` + :func:`repro.obs.write_chrome_trace`.
See ``docs/obs.md``.
"""

from .trace import (  # noqa: F401
    DEFAULT_CAPACITY, Ring, clear, complete_span, disable, enable,
    enabled, instant, open_span_events, ring_stats, snapshot, trace_span,
)
from .export import (  # noqa: F401
    chrome_trace, counts_from_chrome, crosscheck, derived_metrics,
    exchange_counts_from_chrome, write_chrome_trace,
)
from .metrics import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, MetricsSnapshot,
    Snapshotter,
)
from .monitor import (  # noqa: F401
    FlightRecorder, SloMonitor, StallWatchdog,
)
