"""Trip-count-aware HLO cost analyzer.

``compiled.cost_analysis()`` counts while-loop (scan) bodies ONCE,
regardless of trip count (verified empirically — see EXPERIMENTS.md
§Roofline methodology).  Every model here scans over layers and
microbatches, so naive numbers undercount FLOPs/bytes/collective traffic
by 10–400×.  This analyzer parses the optimised HLO text:

* builds a per-computation symbol table (op name → result type) so dot
  contractions and operand traffic can be sized (operands are not
  type-annotated inline in modern HLO);
* reads while-loop trip counts from ``backend_config=
  {"known_trip_count":{"n":...}}`` (falling back to the condition's
  ``compare(iter, constant(N)), direction=LT``);
* accumulates, scaled by the product of enclosing trip counts:
  - **flops**: dot ops, 2 · numel(result) · Π(contracted lhs dims);
  - **bytes**: HBM-traffic proxy — Σ over top-level (post-fusion) ops of
    result + operand bytes (fusion internals stay on-chip);
  - **collectives**: count + payload bytes by kind.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([a-z][\w\-]*)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CONST_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*s(?:32|64)\[\]\s+constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_TRAFFIC = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "iota", "compare", "add",
})


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt in _DTYPE_BYTES:
            total += _numel(m.group(2)) * _DTYPE_BYTES[dt]
    return total


def _type_dims(type_str: str) -> Optional[list]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "OpCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def total_coll_count(self) -> float:
        return sum(self.coll_count.values())


@dataclass
class Computation:
    name: str
    ops: List[dict] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)
    constants: Dict[str, int] = field(default_factory=dict)


class HLOAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, OpCost] = {}

    # -- parsing --------------------------------------------------------------

    def _parse(self, text: str):
        cur: Optional[Computation] = None
        for line in text.splitlines():
            if line and not line[0].isspace():
                hdr = _COMP_HDR.match(line)
                if hdr and line.rstrip().endswith("{"):
                    cur = Computation(name=hdr.group(2))
                    self.comps[cur.name] = cur
                    if hdr.group(1):
                        self.entry = cur.name
                    # parameters typed in the header: "(x: f32[2,3], ...)"
                    for pm in re.finditer(
                            r"([\w.\-]+):\s*(\(?[a-z][^,)]*(?:\)[^,)]*)?)",
                            line.split("->")[0]):
                        cur.types[pm.group(1)] = pm.group(2)
                    continue
            if cur is None:
                continue
            om = _OP_RE.match(line)
            if om:
                opname, rtype, kind = om.groups()
                cur.types[opname] = rtype
                cur.ops.append({"name": opname, "kind": kind, "line": line,
                                "rtype": rtype})
                cm = _CONST_RE.search(line)
                if cm:
                    cur.constants[cm.group(1)] = int(cm.group(2))
                # parameters appear as ops too
                if kind == "parameter":
                    cur.types[opname] = rtype

    # -- helpers ---------------------------------------------------------------

    def _operands(self, line: str, kind: str) -> List[str]:
        """Operand names inside the instruction's parens."""
        start = line.find(f" {kind}(")
        if start < 0:
            return []
        seg = line[start + len(kind) + 2:]
        # cut at the closing paren of the call (first unbalanced ')')
        depth = 1
        out_chars = []
        for ch in seg:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            out_chars.append(ch)
        return _OPERAND_RE.findall("".join(out_chars))

    def _operand_bytes(self, comp: Computation, line: str, kind: str) -> int:
        total = 0
        for name in self._operands(line, kind):
            t = comp.types.get(name)
            if t:
                total += _type_bytes(t)
        return total

    def _fusion_param_access(self, inner_name: str) -> list:
        """Per-parameter effective read size inside a fused computation.

        Returns a list indexed by parameter number: None = the parameter is
        read in full; an int = only that many bytes are read (the parameter
        is consumed exclusively by dynamic-slice/gather ops — the stacked
        layer-params pattern inside scan bodies, which otherwise inflates
        traffic by the layer count).
        """
        if not hasattr(self, "_fusion_memo"):
            self._fusion_memo: Dict[str, list] = {}
        if inner_name in self._fusion_memo:
            return self._fusion_memo[inner_name]
        inner = self.comps.get(inner_name)
        out: list = []
        if inner is None:
            self._fusion_memo[inner_name] = out
            return out
        params = []  # (index, name)
        for iop in inner.ops:
            if iop["kind"] == "parameter":
                pm = re.search(r"parameter\((\d+)\)", iop["line"])
                if pm:
                    params.append((int(pm.group(1)), iop["name"]))
        n = (max(i for i, _ in params) + 1) if params else 0
        out = [None] * n
        for idx, pname in params:
            uses = []
            pat = re.compile(rf"%{re.escape(pname)}\b")
            for iop in inner.ops:
                if iop["kind"] == "parameter" or iop["name"] == pname:
                    continue
                seg = iop["line"].split(iop["kind"] + "(", 1)
                if len(seg) > 1 and pat.search(seg[1].split(")")[0] if ")"
                                               in seg[1] else seg[1]):
                    uses.append(iop)
            if not uses:
                continue
            if all(u["kind"] in ("dynamic-slice", "gather") for u in uses):
                out[idx] = max(_type_bytes(u["rtype"]) for u in uses)
            elif all(u["kind"] == "dynamic-update-slice"
                     and self._operands(u["line"],
                                        "dynamic-update-slice")[:1]
                     == [pname] for u in uses):
                # in-place scatter target: traffic = the written region,
                # which the DUS update operand sizes (operand 1)
                eff = 0
                for u in uses:
                    ops_ = self._operands(u["line"], "dynamic-update-slice")
                    t = inner.types.get(ops_[1]) if len(ops_) > 1 else None
                    eff += _type_bytes(t) if t else 0
                out[idx] = eff
        self._fusion_memo[inner_name] = out
        return out

    def _fusion_operand_bytes(self, comp: Computation, line: str,
                              inner_name: Optional[str]) -> int:
        operands = self._operands(line, "fusion")
        access = self._fusion_param_access(inner_name) if inner_name else []
        total = 0
        for i, name in enumerate(operands):
            eff = access[i] if i < len(access) else None
            if eff is not None:
                total += eff
                continue
            t = comp.types.get(name)
            if t:
                total += _type_bytes(t)
        return total

    def _dot_flops(self, comp: Computation, op: dict) -> float:
        result_dims = _type_dims(op["rtype"])
        if result_dims is None:
            return 0.0
        operands = self._operands(op["line"], "dot")
        if not operands:
            return 0.0
        lhs_t = comp.types.get(operands[0])
        lhs_dims = _type_dims(lhs_t) if lhs_t else None
        contracted = 1
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op["line"])
        if lhs_dims and cm and cm.group(1):
            for idx in cm.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    contracted *= lhs_dims[i]
        result_numel = 1
        for d in result_dims:
            result_numel *= d
        return 2.0 * result_numel * contracted

    def _trip_count(self, line: str, cond_name: Optional[str]) -> int:
        m = _TRIP_RE.search(line)
        if m:
            return max(1, int(m.group(1)))
        cond = self.comps.get(cond_name or "")
        if cond is None:
            return 1
        for op in cond.ops:
            if op["kind"] == "compare" and "direction=LT" in op["line"]:
                for cname, val in cond.constants.items():
                    if cname in op["line"]:
                        return max(1, val)
        if cond.constants:
            return max(1, max(cond.constants.values()))
        return 1

    # -- cost accumulation -------------------------------------------------------

    def cost_of(self, comp_name: str) -> OpCost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = OpCost()
        self._memo[comp_name] = total
        comp = self.comps.get(comp_name)
        if comp is None:
            return total
        for op in comp.ops:
            line, kind = op["line"], op["kind"]
            if kind == "while":
                body = cond = None
                for m in re.finditer(r"(condition|body)=%?([\w.\-]+)", line):
                    if m.group(1) == "body":
                        body = m.group(2)
                    else:
                        cond = m.group(2)
                trips = self._trip_count(line, cond)
                if body:
                    total.add(self.cost_of(body), mult=trips)
                continue
            if kind == "conditional":
                bm = re.search(r"branch_computations=\{([^}]*)\}", line)
                if bm:
                    branches = [b.strip().lstrip("%")
                                for b in bm.group(1).split(",") if b.strip()]
                    costs = [self.cost_of(b) for b in branches]
                    if costs:
                        total.add(max(costs, key=lambda c: c.flops + c.bytes))
                continue
            if kind in ("call", "async-start"):
                m = re.search(r"(?:to_apply|calls|called_computation)"
                              r"=%?([\w.\-]+)", line)
                if m:
                    total.add(self.cost_of(m.group(1)))
                continue
            if kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", line)
                inner_name = m.group(1) if m else None
                result_bytes = _type_bytes(op["rtype"])
                if inner_name:
                    inner = self.comps.get(inner_name)
                    if inner:
                        dus_upd = 0
                        has_dus_root = False
                        for iop in inner.ops:
                            if iop["kind"] == "dot":
                                total.flops += self._dot_flops(inner, iop)
                            if iop["kind"] == "dynamic-update-slice":
                                has_dus_root = True
                                ops_ = self._operands(
                                    iop["line"], "dynamic-update-slice")
                                t = inner.types.get(ops_[1]) \
                                    if len(ops_) > 1 else None
                                dus_upd += _type_bytes(t) if t else 0
                        if has_dus_root and dus_upd:
                            # result aliases the scatter target: the write
                            # is only the updated region
                            result_bytes = dus_upd
                total.bytes += result_bytes + \
                    self._fusion_operand_bytes(comp, line, inner_name)
                continue
            if kind == "dot":
                total.flops += self._dot_flops(comp, op)
                total.bytes += _type_bytes(op["rtype"]) + \
                    self._operand_bytes(comp, line, kind)
                continue
            base = kind
            for suffix in ("-start", "-done"):
                if base.endswith(suffix):
                    base = base[: -len(suffix)]
            if base in _COLL_KINDS:
                if kind.endswith("-done"):
                    continue
                nbytes = _type_bytes(op["rtype"])
                total.coll_bytes[base] = total.coll_bytes.get(base, 0.0) \
                    + nbytes
                total.coll_count[base] = total.coll_count.get(base, 0.0) + 1
                total.bytes += nbytes
                continue
            if kind in _SKIP_TRAFFIC:
                continue
            if kind in ("dynamic-slice", "gather"):
                # reads only the sliced region (stacked-params access)
                total.bytes += 2 * _type_bytes(op["rtype"])
                continue
            if kind == "dynamic-update-slice":
                ops_ = self._operands(line, kind)
                upd = comp.types.get(ops_[1]) if len(ops_) > 1 else None
                total.bytes += 2 * (_type_bytes(upd) if upd
                                    else _type_bytes(op["rtype"]))
                continue
            total.bytes += _type_bytes(op["rtype"]) + \
                self._operand_bytes(comp, line, kind)
        return total

    def entry_cost(self) -> OpCost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze_hlo(hlo_text: str) -> OpCost:
    return HLOAnalyzer(hlo_text).entry_cost()
