"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per chip, seconds) for TPU v5e:

    compute    = HLO_FLOPs_per_device / 197e12          (bf16 peak)
    memory     = HLO_bytes_per_device / 819e9           (HBM bw)
    collective = collective_bytes_per_device / 50e9     (ICI link bw)

``cost_analysis()`` reports the per-device (SPMD-partitioned) module, so
the spec's ``X / (chips × BW)`` with global X equals ``X_per_device / BW``
as computed here.  collective_bytes is NOT in cost_analysis — it is parsed
from the optimised HLO: the summed result-shape bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute.
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# matches op definitions like:  %all-reduce.5 = bf16[128,512]{1,0} all-reduce(
_DEF_RE = re.compile(
    r"=\s*(\(?[a-z0-9_,\[\]{}\s]*\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, dict]:
    """Per-op-kind {count, bytes} from optimised HLO text.

    ``-start``/``-done`` async pairs are counted once (on -start; a bare
    ``-done`` has no shape on its LHS worth double counting).
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async completion: counted at -start
        m = _DEF_RE.search(line)
        if not m:
            continue
        kind = m.group(2).lower()
        nbytes = _shape_bytes(m.group(1))
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


@dataclass
class RooflineTerms:
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collective_bytes_per_device: float = 0.0
    collective_ops: int = 0
    collective_by_kind: dict = field(default_factory=dict)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0          # 6·N·D (·N_active for MoE)
    useful_flops_ratio: float = 0.0   # model / (HLO × chips)
    chips: int = 0

    def as_dict(self):
        return asdict(self)


def roofline_from_artifacts(cost: dict, hlo_text: str, *, chips: int,
                            model_flops: float = 0.0) -> RooflineTerms:
    t = RooflineTerms(chips=chips)
    t.flops_per_device = float(cost.get("flops", 0.0))
    t.bytes_per_device = float(cost.get("bytes accessed", 0.0))
    stats = collective_stats(hlo_text)
    t.collective_by_kind = stats
    t.collective_bytes_per_device = float(
        sum(v["bytes"] for v in stats.values()))
    t.collective_ops = sum(v["count"] for v in stats.values())
    t.compute_s = t.flops_per_device / PEAK_FLOPS
    t.memory_s = t.bytes_per_device / HBM_BW
    t.collective_s = t.collective_bytes_per_device / ICI_BW
    terms = {"compute": t.compute_s, "memory": t.memory_s,
             "collective": t.collective_s}
    t.dominant = max(terms, key=terms.get)
    t.model_flops = model_flops
    total_hlo = t.flops_per_device * chips
    t.useful_flops_ratio = (model_flops / total_hlo) if total_hlo else 0.0
    return t


def roofline_from_opcost(opcost, *, chips: int,
                         model_flops: float = 0.0) -> RooflineTerms:
    """Roofline terms from the trip-count-scaled HLO analyzer
    (:mod:`repro.roofline.hlo_analyzer`) — the §Roofline methodology,
    since ``cost_analysis()`` counts scan bodies once."""
    t = RooflineTerms(chips=chips)
    t.flops_per_device = float(opcost.flops)
    t.bytes_per_device = float(opcost.bytes)
    t.collective_by_kind = {
        k: {"count": opcost.coll_count.get(k, 0.0),
            "bytes": opcost.coll_bytes.get(k, 0.0)}
        for k in set(opcost.coll_count) | set(opcost.coll_bytes)
    }
    t.collective_bytes_per_device = float(opcost.total_coll_bytes)
    t.collective_ops = int(opcost.total_coll_count)
    t.compute_s = t.flops_per_device / PEAK_FLOPS
    t.memory_s = t.bytes_per_device / HBM_BW
    t.collective_s = t.collective_bytes_per_device / ICI_BW
    terms = {"compute": t.compute_s, "memory": t.memory_s,
             "collective": t.collective_s}
    t.dominant = max(terms, key=terms.get)
    t.model_flops = model_flops
    total_hlo = t.flops_per_device * chips
    t.useful_flops_ratio = (model_flops / total_hlo) if total_hlo else 0.0
    return t


def model_flops_estimate(cfg, shape) -> float:
    """6·N·D with N = active params; D = tokens processed by the step."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def roofline_fraction(t: RooflineTerms) -> float:
    """Fraction of the roofline bound the useful model FLOPs achieve:
    (model_flops / chips / peak) / max(term)."""
    bound = max(t.compute_s, t.memory_s, t.collective_s)
    if bound <= 0 or t.chips == 0:
        return 0.0
    useful_s = t.model_flops / t.chips / PEAK_FLOPS
    return useful_s / bound
