"""Recompute roofline fields of dry-run artifacts from their saved HLO
dumps (no recompilation): ``python -m repro.roofline.reanalyze [dir]``."""

from __future__ import annotations

import json
import sys
from pathlib import Path

import zstandard

from ..configs import SHAPES, get_config
from .analysis import (
    model_flops_estimate, roofline_fraction, roofline_from_opcost,
)
from .hlo_analyzer import analyze_hlo


def reanalyze(path: Path) -> bool:
    rec = json.loads(path.read_text())
    if rec.get("status") != "ok":
        return False
    hlo_path = path.with_suffix("").with_suffix("")  # strip .json
    hlo_path = path.parent / (path.stem + ".hlo.zst")
    if not hlo_path.exists():
        return False
    hlo = zstandard.ZstdDecompressor().decompress(
        hlo_path.read_bytes(), max_output_size=4_000_000_000).decode()
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    opcost = analyze_hlo(hlo)
    terms = roofline_from_opcost(
        opcost, chips=rec["chips"],
        model_flops=model_flops_estimate(cfg, shape))
    rec["roofline"] = terms.as_dict()
    rec["roofline_fraction"] = round(roofline_fraction(terms), 4)
    path.write_text(json.dumps(rec, indent=1))
    return True


def main(argv=None):
    d = Path((argv or sys.argv[1:] or ["experiments/dryrun"])[0])
    n = 0
    for f in sorted(d.glob("*.json")):
        if reanalyze(f):
            n += 1
            print("reanalyzed", f.name)
    print(f"done: {n} artifacts")


if __name__ == "__main__":
    main()
