"""Sharding rules: DP / FSDP / TP / EP / SP over the production mesh.

Mesh axes (launch/mesh.py): single-pod ``("data", "model")`` = (16, 16);
multi-pod ``("pod", "data", "model")`` = (2, 16, 16).  ``"pod"`` extends
the data axis (gradient sync crosses pods; TP stays intra-pod — ICI-aware
placement).  An optional ``"expert"`` axis (carved out of the data axis,
``make_production_mesh(expert=S)``) enables expert-parallel all-to-all
MoE dispatch (repro.ep): expert weights shard E over it and tokens are
exchanged between expert shards intra-pod.

Param rules (per tensor-role, applied by pytree path):

* embeddings/lm_head: vocab → model, d_model → fsdp axes
* attention qkv: d_model(in) → fsdp, heads(out) → model (Megatron TP)
* attention out: heads(in) → model, d_model(out) → fsdp
* mlp w1/w3: d → fsdp, ff → model;  w2: ff → model, d → fsdp
* MoE experts: E → "expert" when the mesh carves a dedicated expert
  axis that divides E (repro.ep all-to-all dispatch); else E → model
  when E % model_size == 0 (expert parallelism on the TP axis), else
  ff → model (TP inside experts)
* mamba: d_inner → model (heads-analog), d_model → fsdp
* norms/scalars: replicated
* stacked layer dim (leading L): never sharded

Sync-policy variants (train/train_step.py):
* "unopt"/"lc" — pure DP: params replicated over (pod, data) (no fsdp dim)
* "afe"/"afe_bucket" — FSDP: params sharded over (pod, data) as above

The model code calls :func:`shard` on activations; it is a no-op unless a
mesh context is installed (smoke tests run un-meshed on one device).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_mesh", default=None)

#: The one copy of the expert-parallel mesh-axis name (repro.ep and
#: launch.mesh import it; a drifting literal would silently disable the
#: EP dispatch path).
EXPERT_AXIS = "expert"


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    token = _MESH_CTX.set(mesh)
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _MESH_CTX.reset(token)


def current_mesh() -> Optional[Mesh]:
    return _MESH_CTX.get()


def fsdp_axes(mesh: Optional[Mesh] = None):
    """The data-parallel axes tuple: ("pod","data") or ("data",)."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return ("data",)
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def expert_axis_size(mesh: Optional[Mesh] = None) -> int:
    """Size of the dedicated expert-parallel axis (0 when the mesh does
    not carve one — the single-host MoE dispatch path)."""
    mesh = mesh or current_mesh()
    if mesh is None or EXPERT_AXIS not in mesh.axis_names:
        return 0
    return dict(mesh.shape)[EXPERT_AXIS]


def _model_size(mesh: Mesh) -> int:
    """TP axis size; 1 for meshes without a "model" axis (e.g. an
    expert-only EP test mesh)."""
    return dict(mesh.shape).get("model", 1)


def shard(x, *spec):
    """with_sharding_constraint that degrades to identity without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def batch_spec() -> P:
    return P(fsdp_axes(), None)


def act_spec() -> P:
    """(B, S, D) activations: batch over data axes, D unsharded between
    layers (TP collectives happen inside the layer einsums)."""
    return P(fsdp_axes(), None, None)


def shard_act(x):
    """Megatron-style sequence-parallel activation constraint.

    Residual-stream activations between layers are sharded over the model
    axis along the *sequence* dimension whenever it divides — this is what
    bounds the remat-saved layer inputs (L × tokens × d_model bf16 would
    otherwise dominate HBM: qwen2.5-32b train_4k saves 42 GB/device
    un-sharded, 2.6 GB with SP — EXPERIMENTS.md §Perf iteration 1).
    Falls back to batch-only sharding for ragged lengths (whisper's 1500
    frames) and decode (S=1).
    """
    mesh = current_mesh()
    if mesh is None or x.ndim != 3:
        return x
    fa = fsdp_axes(mesh)
    msize = _model_size(mesh)
    dsize = 1
    for a in fa:
        dsize *= mesh.shape[a]
    b_ax = fa if fa and x.shape[0] % dsize == 0 else None
    s_ax = "model" if msize > 1 and x.shape[1] % msize == 0 else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, s_ax, None)))


def shard_logits(x):
    """(B, S, V) or (B, V) logits: vocab over the model axis (matches the
    lm_head output sharding → no reshard), batch over data."""
    mesh = current_mesh()
    if mesh is None:
        return x
    fa = fsdp_axes(mesh)
    msize = _model_size(mesh)
    dsize = 1
    for a in fa:
        dsize *= mesh.shape[a]
    b_ax = fa if fa and x.shape[0] % dsize == 0 else None
    v_ax = "model" if msize > 1 and x.shape[-1] % msize == 0 else None
    spec = P(b_ax, None, v_ax) if x.ndim == 3 else P(b_ax, v_ax)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Param specs by pytree path
# ---------------------------------------------------------------------------


def _role_spec(path: str, shape: tuple, cfg, dp_shard: bool,
               model_size: int, expert_size: int = 0) -> P:
    """PartitionSpec for one param; ``path`` is '/'-joined pytree keys.
    Leading stacked-layer dims (added by the L-stacking) are detected by
    comparing ndim with the role's base rank and left unsharded."""
    fa = fsdp_axes() if dp_shard else None
    M = "model"

    def pad(spec_tail: tuple, ndim: int) -> P:
        lead = ndim - len(spec_tail)
        return P(*([None] * lead), *spec_tail)

    nd = len(shape)
    # --- scalars / norms / biases: replicated ---
    if nd <= 1 or "scale" in path or "bias" in path or path.endswith("/b") \
            or "conv_b" in path or "/D" in path or "dt_b" in path:
        return P(*([None] * nd))
    # --- embeddings / lm head ---
    if "embed" in path:
        return pad((M, fa), nd)       # (V, D)
    if "lm_head" in path:
        return pad((fa, M), nd)       # (D, V)
    # --- MoE experts ---
    if "/moe/" in path or path.startswith("moe/"):
        if "router" in path:
            return pad((fa, None), nd)
        # A dedicated expert axis (repro.ep all-to-all dispatch) wins:
        # E shards over "expert" and the TP axis stays free for d_ff.
        # Gated on the config opting in: a mesh may carve the axis while
        # a model keeps single-host dispatch, and expert-sharded weights
        # under the single-host gather would hand GSPMD exactly the
        # guess-a-reshard case repro.ep exists to avoid.
        if cfg.n_experts > 0 and expert_size > 0 and \
                getattr(cfg, "expert_parallel", False) and \
                cfg.n_experts % expert_size == 0:
            if "w1" in path or "w3" in path:
                return pad((EXPERT_AXIS, fa, M), nd)   # (E, d, f)
            if "w2" in path:
                return pad((EXPERT_AXIS, M, fa), nd)   # (E, f, d)
        ep = cfg.n_experts > 0 and model_size > 0 and \
            cfg.n_experts % model_size == 0
        if "w1" in path or "w3" in path:
            # (E, d, f)
            return pad((M, fa, None), nd) if ep else pad((None, fa, M), nd)
        if "w2" in path:
            # (E, f, d)
            return pad((M, None, fa), nd) if ep else pad((None, M, fa), nd)
    # --- attention ---
    if "/wq/" in path or "/wk/" in path or "/wv/" in path:
        return pad((fa, M), nd)
    if "/wo/" in path:
        return pad((M, fa), nd)
    # --- mamba ---
    if "in_proj" in path:
        return pad((fa, M), nd)       # (D, 2*Di): Di → model
    if "out_proj" in path:
        return pad((M, fa), nd)       # (Di, D)
    if "x_proj" in path:
        return pad((M, None), nd)     # (Di, dtr+2N)
    if "dt_proj" in path:
        return pad((None, M), nd)     # (dtr, Di)
    if "conv_w" in path:
        return pad((None, M), nd)     # (cw, Di)
    if "A_log" in path:
        return pad((M, None), nd)     # (Di, N)
    # --- dense mlp ---
    if "/w1/" in path or "/w3/" in path or path.endswith("/w1") \
            or path.endswith("/w3"):
        return pad((fa, M), nd)
    if "/w2/" in path or path.endswith("/w2"):
        return pad((M, fa), nd)
    if path.endswith("/w"):
        # generic dense inside attn/mlp dicts handled above via parent name
        return pad((fa, M), nd)
    return P(*([None] * nd))


def param_specs_tree(shapes_tree, cfg, *, dp_shard: bool = True):
    """Map a ShapeDtypeStruct pytree to PartitionSpecs (same structure)."""
    mesh = current_mesh()
    model_size = _model_size(mesh) if mesh is not None else 0
    expert_size = expert_axis_size(mesh)

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        return _role_spec(path, node.shape, cfg, dp_shard, model_size,
                          expert_size)

    return walk(shapes_tree, "")


def named_shardings(shapes_tree, cfg, *, dp_shard: bool = True):
    mesh = current_mesh()
    assert mesh is not None, "named_shardings requires a mesh context"
    specs = param_specs_tree(shapes_tree, cfg, dp_shard=dp_shard)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
