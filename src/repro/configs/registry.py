"""Architecture registry: --arch <id> → ModelConfig (full + smoke)."""

from __future__ import annotations

from . import (
    falcon_mamba_7b, granite_moe_1b, hymba_1_5b, internlm2_20b,
    llama_3_2_vision_90b, minitron_4b, mixtral_8x7b, phi3_mini_3_8b,
    qwen2_5_32b, whisper_medium,
)
from .base import SHAPES, ModelConfig, ShapeConfig, input_specs, shape_applicable

_MODULES = {
    "whisper-medium": whisper_medium,
    "falcon-mamba-7b": falcon_mamba_7b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "mixtral-8x7b": mixtral_8x7b,
    "hymba-1.5b": hymba_1_5b,
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "internlm2-20b": internlm2_20b,
    "qwen2.5-32b": qwen2_5_32b,
    "minitron-4b": minitron_4b,
    "llama-3.2-vision-90b": llama_3_2_vision_90b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[arch]
    return mod.SMOKE if smoke else mod.CONFIG


def all_cells():
    """Every (arch × shape) cell with applicability flags — 40 total."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, reason = shape_applicable(cfg, shape)
            out.append(dict(arch=arch, shape=sname, applicable=ok,
                            reason=reason))
    return out
