"""whisper-medium — enc-dec audio transformer backbone.

[arXiv:2212.04356; unverified]  24L decoder (+24L encoder) d_model=1024
16H (GQA kv=16 ⇒ MHA) d_ff=4096 vocab=51865.  The conv audio frontend is a
STUB per the assignment: input_specs() provides precomputed frame
embeddings (1500 frames).  Pure full attention → long_500k skipped
(DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    enc_layers=24, enc_seq=1500,
    norm="layernorm", act="gelu", rope_theta=0.0,  # learned/abs pos (stubbed as rope-free)
    source="[arXiv:2212.04356; unverified]",
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    enc_layers=2, enc_seq=32, norm="layernorm", act="gelu", rope_theta=0.0,
    source="reduced",
)
