"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA window 4096.  8 experts do not divide the
16-way model axis → experts are TP-sharded on d_ff (14336/16 = 896).
SWA ⇒ sub-quadratic ⇒ long_500k RUNS.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, n_experts=8, top_k=2, sliding_window=4096,
    source="[arXiv:2401.04088; hf]",
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
    n_experts=4, top_k=2, sliding_window=32,
    source="reduced",
)
