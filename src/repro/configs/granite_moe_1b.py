"""granite-moe-1b-a400m — 32-expert top-8 MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  24L d_model=1024 16H
(GQA kv=8) d_ff=512 per expert, vocab=49155, MoE 32e top-8.
Experts are EP-sharded over the model axis (32 % 16 == 0).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab=49155, n_experts=32, top_k=8,
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
)

SMOKE = ModelConfig(
    name="granite-moe-1b-a400m-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32, vocab=128,
    n_experts=4, top_k=2,
    source="reduced",
)
