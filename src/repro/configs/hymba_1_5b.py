"""hymba-1.5b — hybrid parallel attention + mamba heads per layer.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5, head_dim 64)
d_ff=5504 vocab=32001, ssm_state=16; attention is sliding-window (global
layers approximated as SWA per backbone spec).  Sub-quadratic ⇒ long_500k
RUNS.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, ssm_state=16, d_inner=3200, dt_rank=100, conv_width=4,
    sliding_window=1024, d_head=64,
    source="[arXiv:2411.13676; hf]",
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    ssm_state=4, d_inner=128, dt_rank=8, conv_width=4, sliding_window=32,
    d_head=16,
    source="reduced",
)
