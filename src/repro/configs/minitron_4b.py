"""minitron-4b — pruned Nemotron, dense GQA, 256k vocab.

[arXiv:2407.14679; hf]  32L d_model=3072 24H (GQA kv=8, head_dim 128)
d_ff=9216 vocab=256000.  The 256k vocab stresses embedding/output
sharding.  Pure full attention → long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=9216,
    vocab=256000, d_head=128,
    source="[arXiv:2407.14679; hf]",
)

SMOKE = ModelConfig(
    name="minitron-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    d_head=16,
    source="reduced",
)
