from .base import (  # noqa: F401
    SHAPES, ModelConfig, ShapeConfig, input_specs, shape_applicable,
)
from .registry import ARCH_IDS, all_cells, get_config  # noqa: F401
