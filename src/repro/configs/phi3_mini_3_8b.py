"""phi3-mini-3.8b — dense RoPE/SwiGLU, MHA-equivalent GQA (kv=32).

[arXiv:2404.14219; unverified]  32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064.  Pure full attention → long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32064,
    source="[arXiv:2404.14219; unverified]",
)

SMOKE = ModelConfig(
    name="phi3-mini-3.8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    source="reduced",
)
