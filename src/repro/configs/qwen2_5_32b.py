"""qwen2.5-32b — dense GQA with QKV bias.

[hf:Qwen/Qwen2.5-0.5B; hf]  64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064, qkv_bias.  Pure full attention → long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=27648,
    vocab=152064, qkv_bias=True,
    source="[hf:Qwen/Qwen2.5-0.5B; hf]",
)

SMOKE = ModelConfig(
    name="qwen2.5-32b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    qkv_bias=True,
    source="reduced",
)
