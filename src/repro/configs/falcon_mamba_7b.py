"""falcon-mamba-7b — attention-free Mamba-1 SSM.

[arXiv:2410.05355; unverified]  64L d_model=4096 d_ff=0 vocab=65024,
ssm_state=16, d_inner=8192, dt_rank=256.  Sub-quadratic → long_500k RUNS.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=65024, ssm_state=16, d_inner=8192, dt_rank=256, conv_width=4,
    source="[arXiv:2410.05355; unverified]",
)

SMOKE = ModelConfig(
    name="falcon-mamba-7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
    ssm_state=4, d_inner=128, dt_rank=8, conv_width=4,
    source="reduced",
)
