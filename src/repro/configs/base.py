"""Model + shape configuration system.

``ModelConfig`` describes an architecture (one file per assigned arch in
this package); ``ShapeConfig`` describes an input-shape cell (train_4k /
prefill_32k / decode_32k / long_500k).  ``input_specs()`` returns
ShapeDtypeStruct stand-ins so the multi-pod dry-run can lower/compile
without allocating anything.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # default d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "dlbc"  # "lc" (static GShard) | "dlbc" (two-round)
    #: opt in to expert-parallel all-to-all dispatch (repro.ep): taken
    #: when the mesh carves an "expert" axis that divides E and T,
    #: otherwise falls back to the single-host dispatch path
    expert_parallel: bool = False
    # --- SSM (mamba1) ---
    ssm_state: int = 0
    d_inner: int = 0
    conv_width: int = 4
    dt_rank: int = 0
    # --- attention ---
    sliding_window: int = 0  # 0 = full attention
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 0  # audio frames after the (stubbed) conv frontend
    # --- VLM (llama-3.2-vision) ---
    cross_every: int = 0  # every k-th layer is cross-attention
    vis_seq: int = 0      # vision tokens from the (stubbed) patch frontend
    # --- numerics / structure ---
    norm: str = "rmsnorm"   # rmsnorm | layernorm
    act: str = "swiglu"     # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # citation tag [source; verification tier]
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embedding/lm_head shard 16-way
        (standard Megatron-style vocab padding; tail masked in the loss)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def head_dim(self) -> int:
        if self.n_heads == 0:
            return self.d_head  # attention-free (SSM) archs
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k cell?  SSM / hybrid / SWA yes;
        pure full attention no (skip noted in DESIGN.md)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def n_params(self) -> int:
        """Approximate parameter count (embedding + layers)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        h = self.head_dim
        per_attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) \
            + (self.n_heads * h) * d
        if self.act == "swiglu":
            per_mlp = 3 * d * f
        else:
            per_mlp = 2 * d * f
        per_ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, n = self.d_inner or 2 * d, self.ssm_state
            dtr = self.dt_rank or max(1, d // 16)
            per_ssm = d * 2 * di + di * self.conv_width \
                + di * (dtr + 2 * n) + dtr * di + di * n + di * d
        total = 0
        for i in range(self.n_layers):
            if self.family == "dense" or self.family == "encdec":
                total += per_attn + per_mlp
            elif self.family == "moe":
                total += per_attn + self.n_experts * per_mlp
            elif self.family == "ssm":
                total += per_ssm
            elif self.family == "hybrid":
                total += per_attn + per_ssm + per_mlp
            elif self.family == "vlm":
                total += per_attn + per_mlp  # cross layers ≈ same size
        if self.family == "encdec":
            total += self.enc_layers * (per_attn + per_mlp)
            total += self.n_layers * per_attn  # decoder cross-attention
        total += v * d * (1 if self.tie_embeddings else 2)
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE uses top_k of n_experts)."""
        if self.family != "moe":
            return self.n_params()
        d, f = self.d_model, self.d_ff
        per_mlp = 3 * d * f if self.act == "swiglu" else 2 * d * f
        dead = self.n_layers * (self.n_experts - self.top_k) * per_mlp
        return self.n_params() - dead


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    microbatches: int = 1  # gradient-accumulation steps (train only)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train", microbatches=8),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple:
    """(ok, reason) — long_500k only for sub-quadratic archs (per spec)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            f"{cfg.name} is pure full-attention; long_500k needs "
            "sub-quadratic attention (skip recorded in DESIGN.md)"
        )
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    import jax

    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    specs = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one new token against a seq_len cache
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["cache_index"] = jax.ShapeDtypeStruct((), i32)
    if cfg.family == "encdec":
        # Stubbed audio frontend: precomputed frame embeddings (per spec the
        # modality frontend is a STUB supplying embeddings).
        specs["enc_frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                                   bf16)
    if cfg.family == "vlm":
        # Stubbed vision frontend: precomputed patch embeddings.
        specs["vis_embed"] = jax.ShapeDtypeStruct((B, cfg.vis_seq, cfg.d_model),
                                                  bf16)
    return specs
