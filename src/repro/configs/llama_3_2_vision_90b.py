"""llama-3.2-vision-90b — VLM with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  100L d_model=8192 64H
(GQA kv=8) d_ff=28672 vocab=128256; every 5th layer cross-attends to
vision embeddings.  The vision patch frontend is a STUB per the
assignment: input_specs() supplies precomputed patch embeddings
(vis_seq=1601).  Pure full attention → long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, cross_every=5, vis_seq=1601,
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-90b-smoke", family="vlm",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    cross_every=5, vis_seq=16,
    source="reduced",
)
