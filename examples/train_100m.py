"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with the full production stack — DLBC data pipeline, AFE (FSDP) sync
policy, async checkpointing, straggler detection, failure injection +
restart.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
(CPU: takes a while at the full 100M size; --tiny for a quick pass.)
"""

import argparse

from repro.configs.base import ModelConfig, ShapeConfig
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import StepConfig
from repro.train.trainer import TrainerConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    if args.tiny:
        cfg = ModelConfig(name="lm-tiny", family="dense", n_layers=2,
                          d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                          vocab=2048)
        shape = ShapeConfig("tiny", 128, 8, "train", microbatches=2)
    else:
        # ~100M params: 12L d=768 (GPT-2-small-ish with SwiGLU + GQA)
        cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                          vocab=32000)
        shape = ShapeConfig("100m", 512, 8, "train", microbatches=2)

    rep = run_training(
        cfg, shape,
        TrainerConfig(steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir),
        StepConfig(policy="afe", q_chunk=min(512, shape.seq_len),
                   k_chunk=min(512, shape.seq_len)),
        AdamWConfig(lr=3e-4, warmup_steps=20),
    )
    print(f"completed={rep.completed} stragglers={rep.stragglers}")
    print(f"loss: {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f} "
          f"({len(rep.losses)} evals)")
    assert rep.losses[-1] < rep.losses[0], "loss should decrease"

if __name__ == "__main__":
    main()
