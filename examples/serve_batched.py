"""Serve a small model with batched requests: DLBC continuous batching vs
the LC fixed-batch baseline — the paper's scheduling policy on serving
slots (latency and utilisation printed for both).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MDL
from repro.serve.batcher import ContinuousBatcher, Request


def main():
    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=1024)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    def make_requests():
        return [Request(rid=i, prompt=list(rng.integers(0, 1024, size=3)),
                        max_new=int(rng.integers(3, 24)),
                        arrive_step=int(rng.integers(0, 20)))
                for i in range(24)]

    for policy in ("lc", "dlbc"):
        rng = np.random.default_rng(0)
        b = ContinuousBatcher(cfg, params, n_slots=4, cache_len=64,
                              policy=policy)
        st = b.run(make_requests())
        print(f"{policy:5s}: steps={st.steps:4d} util={st.utilization:.2f} "
              f"mean_latency={np.mean(st.latencies):6.1f} "
              f"p99={np.percentile(st.latencies, 99):6.1f}")

if __name__ == "__main__":
    main()
