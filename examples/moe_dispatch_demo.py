"""DLBC vs LC MoE dispatch on a skewed token distribution: measures the
dropped-token fraction for both policies (the paper's load-balancing
payoff in its MoE form).

Run:  PYTHONPATH=src python examples/moe_dispatch_demo.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import moe as MOE


def main():
    cfg = get_config("mixtral-8x7b", smoke=True)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    # Skewed inputs: token clusters that all prefer the same experts.
    key = jax.random.PRNGKey(1)
    base = jax.random.normal(key, (8, cfg.d_model))
    x = jnp.repeat(base, 64, axis=0) + 0.05 * jax.random.normal(
        jax.random.PRNGKey(2), (512, cfg.d_model))
    for dispatch in ("lc", "dlbc"):
        c = dataclasses.replace(cfg, moe_dispatch=dispatch,
                                moe_capacity_factor=1.0)
        y, stats = MOE.moe_apply(p, c, x, return_stats=True)
        ref = MOE.moe_ref(p, c, x)
        err = float(jnp.mean(jnp.abs(y - ref)))
        print(f"{dispatch:5s}: dropped={float(stats['dropped_frac']):.3f} "
              f"mean|y-ref|={err:.4f}")

if __name__ == "__main__":
    main()
