"""Quickstart: the DCAFE paper core in 60 seconds.

Builds the NQueens RTP kernel, applies the full scheme ladder
(UnOpt → LC → DLBC → DCAFE), runs each in the deterministic multi-worker
simulator, and prints the paper's Fig. 10-style dynamic counts — watch
the finish count collapse to 1 and the task count drop ~50×.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import build_kernel, run_scheme

def main():
    kernel = build_kernel("NQ", scale="test")
    print(f"kernel={kernel.name}: {kernel.notes}\n")
    print(f"{'scheme':10s} {'asyncs':>8s} {'finishes':>9s} {'sim time':>9s} "
          f"{'energy':>9s} ok")
    for scheme in ["Serial", "UnOpt", "UnOpt+AFE", "LC", "LC+AFE", "DLBC",
                   "DCAFE"]:
        r = run_scheme(kernel, scheme, workers=8)
        print(f"{scheme:10s} {r.asyncs:8d} {r.finishes:9d} {r.time:9.1f} "
              f"{r.energy:9.1f} {r.ok}")

if __name__ == "__main__":
    main()
