"""Oracle-first, distribution-gated benchmark harness.

DCAFE's headline claims are *distributional* — geomean speedups and tail
behavior across kernels — yet a single-run threshold check can pass a
real regression or fail a good PR on one noisy sample.  This layer is
the shared vocabulary every gated benchmark speaks (ROADMAP
"oracle-first, distribution-gated benchmark harness"):

* **Oracle arm** — every bench declares the serial/LC baseline it must
  match or beat.  Where the arms produce comparable results (item
  counts, token sums), the harness checks result-equivalence against
  the oracle on every repeat, so a "fast" arm that silently drops work
  fails loudly.
* **Repeated runs** — each arm runs ``repeats`` times under a recorded
  seed and emits the full per-repeat sample list plus a
  :class:`~repro.sched.telemetry.LogHistogram` summary, not just a
  best-of scalar.
* **Declarative gates** — tail ratios (p99/p50), arm-vs-oracle ratios
  and speedups are gated through *bootstrap confidence intervals*
  across the repeats: a gate only FAILS when the whole CI lands on the
  wrong side of the threshold.  A CI that straddles the threshold is
  inconclusive and passes — flaky single-sample verdicts cannot kill a
  good PR, and a real regression shifts the whole interval.
* **Trajectory metrics** — each gate contributes its point value (and
  CI) to a per-bench ``trajectory`` dict; ``benchmarks.gates
  trajectory`` diffs those across commits and fails on a >10% p99
  regression on any gated surface.

Everything here is stdlib + ``repro.sched.telemetry`` — the gates must
be re-runnable from a bare JSON artifact on a laptop with no jax.
"""

from __future__ import annotations

import random
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.sched.telemetry import LogHistogram, percentile

#: Bump when the emitted artifact shape changes incompatibly.  The
#: trajectory differ refuses to compare artifacts across versions
#: instead of KeyError-ing mid-diff.
SCHEMA_VERSION = 2

#: bootstrap defaults: resamples per gate and two-sided CI mass.
#: 1000 resamples of <=16 repeats is <1 ms per gate; alpha=0.10 gives a
#: 90% interval — wide enough that honest noise straddles, tight enough
#: that a real 2x shift excludes the threshold.
N_BOOT = 1000
ALPHA = 0.10


def bootstrap_ci(samples: Sequence[float],
                 stat: Callable[[Sequence[float]], float],
                 *, n_boot: int = N_BOOT, seed: int = 0,
                 alpha: float = ALPHA):
    """Percentile-bootstrap CI of ``stat`` over ``samples``.

    Deterministic for a given ``seed`` — the same artifact replayed in
    CI and locally yields the same interval, so a gate verdict is
    reproducible from the JSON alone.
    """
    n = len(samples)
    if n == 0:
        return (0.0, 0.0)
    if n == 1:
        v = stat(samples)
        return (v, v)
    rng = random.Random(seed)
    stats = sorted(
        stat([samples[rng.randrange(n)] for _ in range(n)])
        for _ in range(n_boot))
    lo = stats[int((alpha / 2) * (n_boot - 1))]
    hi = stats[int((1 - alpha / 2) * (n_boot - 1))]
    return (lo, hi)


def bootstrap_ratio_ci(num: Sequence[float], den: Sequence[float],
                       stat: Callable[[Sequence[float]], float],
                       *, n_boot: int = N_BOOT, seed: int = 0,
                       alpha: float = ALPHA):
    """CI of ``stat(num)/stat(den)`` with both arms resampled
    independently per bootstrap iteration (unpaired arms: the repeats of
    one arm say nothing about the matching repeat of the other)."""
    if not num or not den:
        return (0.0, 0.0)
    rng = random.Random(seed)

    def resample(xs):
        n = len(xs)
        return [xs[rng.randrange(n)] for _ in range(n)]

    ratios = []
    for _ in range(n_boot):
        d = stat(resample(den))
        n_ = stat(resample(num))
        ratios.append(n_ / d if d > 0 else 0.0)
    ratios.sort()
    lo = ratios[int((alpha / 2) * (n_boot - 1))]
    hi = ratios[int((1 - alpha / 2) * (n_boot - 1))]
    return (lo, hi)


def pstat(p: float) -> Callable[[Sequence[float]], float]:
    """The percentile-``p`` statistic as a bootstrap-able callable."""
    return lambda xs: percentile(xs, p)


def ci_verdict(ci, op: str, threshold: float) -> bool:
    """Distribution-gate semantics: FAIL only when the whole CI is on
    the wrong side of the threshold.

    * ``op="<="`` (value must stay below): fails iff ``ci.lo > thr``.
    * ``op=">="`` (value must stay above): fails iff ``ci.hi < thr``.

    A straddling CI is *inconclusive* → pass.  This is deliberately
    asymmetric with a point check: one noisy repeat widens the interval
    instead of flipping the verdict.
    """
    lo, hi = ci
    if op == "<=":
        return not lo > threshold
    if op == ">=":
        return not hi < threshold
    raise ValueError(f"unknown gate op {op!r}")


def sample_dist(samples: Sequence[float], unit: str = "s") -> Dict:
    """Exact-percentile distribution summary of repeat samples, plus the
    LogHistogram shape when the unit is seconds (so bench tables and
    runtime telemetry histograms stay comparable — same bucketing)."""
    xs = list(samples)
    if not xs:
        return {"n": 0, "unit": unit}
    out = dict(
        n=len(xs), unit=unit,
        mean=sum(xs) / len(xs), min=min(xs), max=max(xs),
        p50=percentile(xs, 50), p90=percentile(xs, 90),
        p99=percentile(xs, 99),
    )
    out["tail_p99_p50"] = out["p99"] / out["p50"] if out["p50"] > 0 else 1.0
    if unit == "s":
        out["latency_hist"] = LogHistogram().extend(xs).summary()
    return out


class Bench:
    """One oracle-first benchmark: named arms, repeated seeded runs,
    bootstrap-CI gates, and the trajectory metrics CI diffs across PRs.

    Typical shape::

        bench = Bench("sched", seed=seed, repeats=repeats)
        bench.measure("uniform/serial", run_serial, oracle=True)
        bench.measure("uniform/dlbc", run_dlbc, equiv_to="uniform/serial")
        bench.gate_speedup("uniform/dlbc", "uniform/serial", 1.5)
        bench.gate_tail_ratio("uniform/dlbc", 3.0)
        bench.check()                       # raises if a gate FAILED
        report(..., harness=bench.payload())
    """

    def __init__(self, name: str, *, seed: int = 0,
                 repeats: Optional[int] = None,
                 n_boot: int = N_BOOT, alpha: float = ALPHA):
        self.name = name
        self.seed = int(seed)
        self.repeats = int(repeats) if repeats else 5
        self.n_boot = n_boot
        self.alpha = alpha
        self.arms: Dict[str, Dict] = {}
        self.gates: List[Dict] = []
        self.trajectory: Dict[str, Dict] = {}

    # -- arms ------------------------------------------------------------

    def add_samples(self, arm: str, samples: Sequence[float], *,
                    oracle: bool = False, unit: str = "s",
                    results: Optional[list] = None,
                    meta: Optional[Dict] = None) -> Dict:
        """Register an arm from externally measured repeat samples."""
        rec = dict(
            name=arm, role="oracle" if oracle else "candidate", unit=unit,
            samples=[float(s) for s in samples],
            dist=sample_dist(samples, unit),
        )
        if meta:
            rec["meta"] = meta
        self.arms[arm] = rec
        if results is not None:
            rec["_results"] = results  # stripped from payload()
        # every arm's tail lands in the trajectory (lower is better)
        if rec["dist"].get("n"):
            self.track(f"{arm}.p99_{unit}", rec["dist"]["p99"],
                       better="lower")
        return rec

    def measure(self, arm: str, fn: Callable[[int], object], *,
                oracle: bool = False, repeats: Optional[int] = None,
                equiv_to: Optional[str] = None,
                check: Optional[Callable[[object, object], bool]] = None,
                meta: Optional[Dict] = None) -> Dict:
        """Run ``fn(rep)`` ``repeats`` times, wall-timing each repeat.

        ``equiv_to`` names the oracle arm whose per-repeat results this
        arm must reproduce — ``check(oracle_result, result)`` (default:
        equality) runs on every repeat, so an arm that drops or
        duplicates work cannot win on latency.
        """
        reps = int(repeats or self.repeats)
        samples, results = [], []
        for rep in range(reps):
            t0 = time.perf_counter()
            results.append(fn(rep))
            samples.append(time.perf_counter() - t0)
        rec = self.add_samples(arm, samples, oracle=oracle, unit="s",
                               results=results, meta=meta)
        if equiv_to is not None:
            want = self.arms[equiv_to].get("_results")
            if want is None:
                raise KeyError(f"{equiv_to} has no recorded results")
            ok = all((check or (lambda a, b: a == b))(w, r)
                     for w, r in zip(want, results))
            rec["equiv_to"] = equiv_to
            rec["equiv_ok"] = bool(ok)
            if not ok:
                raise AssertionError(
                    f"{self.name}/{arm}: result mismatch vs oracle "
                    f"{equiv_to} — the arm is fast but wrong")
        return rec

    def _samples(self, arm: str) -> List[float]:
        return self.arms[arm]["samples"]

    # -- gates -----------------------------------------------------------

    def _add_gate(self, gate: Dict) -> Dict:
        gate.setdefault("n_boot", self.n_boot)
        gate.setdefault("alpha", self.alpha)
        gate.setdefault("seed", self.seed)
        self.gates.append(gate)
        better = "lower" if gate["op"] == "<=" else "higher"
        self.track(f"gate.{gate['gate']}", gate["value"], better=better,
                   ci=gate.get("ci"))
        return gate

    def gate_samples(self, name: str, arm: str, op: str, threshold: float,
                     *, p: float = 50.0) -> Dict:
        """Gate the percentile-``p`` of one arm's samples against a
        threshold, bootstrap-CI verdict."""
        xs = self._samples(arm)
        ci = bootstrap_ci(xs, pstat(p), n_boot=self.n_boot,
                          seed=self.seed, alpha=self.alpha)
        return self._add_gate(dict(
            gate=name, kind="samples", arm=arm, p=p, op=op,
            threshold=threshold, value=percentile(xs, p), ci=list(ci),
            ok=ci_verdict(ci, op, threshold)))

    def gate_ratio(self, name: str, num: str, den: str, op: str,
                   threshold: float, *, p: float = 50.0) -> Dict:
        """Gate ``p(num)/p(den)`` (e.g. arm-vs-oracle p99 ratio)."""
        nx, dx = self._samples(num), self._samples(den)
        ci = bootstrap_ratio_ci(nx, dx, pstat(p), n_boot=self.n_boot,
                                seed=self.seed, alpha=self.alpha)
        d = percentile(dx, p)
        value = percentile(nx, p) / d if d > 0 else 0.0
        return self._add_gate(dict(
            gate=name, kind="ratio", num=num, den=den, p=p, op=op,
            threshold=threshold, value=value, ci=list(ci),
            ok=ci_verdict(ci, op, threshold)))

    def gate_tail_ratio(self, arm: str, max_ratio: float, *,
                        hi: float = 99.0, lo: float = 50.0,
                        name: Optional[str] = None) -> Dict:
        """p``hi``/p``lo`` tail-shape gate on one arm's repeat samples."""
        xs = self._samples(arm)

        def tail(samples):
            d = percentile(samples, lo)
            return percentile(samples, hi) / d if d > 0 else 1.0

        ci = bootstrap_ci(xs, tail, n_boot=self.n_boot,
                          seed=self.seed, alpha=self.alpha)
        return self._add_gate(dict(
            gate=name or f"{arm}.tail", kind="tail", arm=arm,
            hi=hi, lo=lo, op="<=", threshold=max_ratio,
            value=tail(xs), ci=list(ci),
            ok=ci_verdict(ci, "<=", max_ratio)))

    def gate_oracle_ratio(self, arm: str, oracle: str, max_ratio: float,
                          *, p: float = 99.0,
                          name: Optional[str] = None) -> Dict:
        """Arm-vs-oracle tail gate: p99(arm)/p99(oracle) <= max_ratio."""
        return self.gate_ratio(name or f"{arm}.vs_oracle", arm, oracle,
                               "<=", max_ratio, p=p)

    def gate_speedup(self, arm: str, baseline: str, min_speedup: float,
                     *, p: float = 50.0,
                     name: Optional[str] = None) -> Dict:
        """p50(baseline)/p50(arm) >= min_speedup (times are lower-better,
        so the baseline is the numerator)."""
        g = self.gate_ratio(name or f"{arm}.speedup", baseline, arm,
                            ">=", min_speedup, p=p)
        return g

    def gate_exact(self, name: str, value: float, op: str,
                   threshold: float) -> Dict:
        """Point gate for exact counters (joins, drops, conservation) —
        quantities with no sampling noise get no CI slack."""
        value = float(value)
        ok = value <= threshold if op == "<=" else value >= threshold
        return self._add_gate(dict(
            gate=name, kind="exact", op=op, threshold=threshold,
            value=value, ci=[value, value], ok=bool(ok)))

    # -- output ----------------------------------------------------------

    def track(self, metric: str, value: float, *, better: str = "lower",
              ci: Optional[Sequence[float]] = None):
        """Record a trajectory metric CI will diff across commits."""
        rec = dict(value=float(value), better=better)
        if ci is not None:
            rec["ci"] = [float(ci[0]), float(ci[1])]
        self.trajectory[metric] = rec

    def failed(self) -> List[Dict]:
        return [g for g in self.gates if not g["ok"]]

    def check(self):
        """Raise if any gate conclusively failed (CI beyond threshold)."""
        bad = self.failed()
        if bad:
            msgs = [f"{g['gate']}: value={g['value']:.4g} "
                    f"ci=[{g['ci'][0]:.4g}, {g['ci'][1]:.4g}] "
                    f"must be {g['op']} {g['threshold']}" for g in bad]
            raise AssertionError(
                f"{self.name}: distribution gates failed: {msgs}")

    def payload(self) -> Dict:
        """The JSON section ``benchmarks.gates dist`` replays: arms with
        raw samples, evaluated gates (with the bootstrap parameters that
        make the verdict reproducible), and trajectory metrics."""
        arms = {}
        for name, rec in self.arms.items():
            arms[name] = {k: v for k, v in rec.items()
                          if k != "_results"}
        return dict(seed=self.seed, repeats=self.repeats,
                    n_boot=self.n_boot, alpha=self.alpha,
                    arms=arms, gates=self.gates,
                    trajectory=self.trajectory)


def replay_gate(gate: Dict, arms: Dict[str, Dict]) -> Dict:
    """Re-evaluate one stored gate from artifact samples — the CI-side
    half of the contract: the verdict must be re-derivable from the JSON
    alone, not trusted from the producer's ``ok`` flag."""
    kind = gate["kind"]
    n_boot = gate.get("n_boot", N_BOOT)
    alpha = gate.get("alpha", ALPHA)
    seed = gate.get("seed", 0)
    if kind == "exact":
        v = float(gate["value"])
        ok = v <= gate["threshold"] if gate["op"] == "<=" \
            else v >= gate["threshold"]
        return dict(gate, ok=bool(ok), ci=[v, v])
    if kind == "samples":
        xs = arms[gate["arm"]]["samples"]
        ci = bootstrap_ci(xs, pstat(gate["p"]), n_boot=n_boot,
                          seed=seed, alpha=alpha)
    elif kind == "tail":
        xs = arms[gate["arm"]]["samples"]
        lo_p, hi_p = gate["lo"], gate["hi"]

        def tail(samples):
            d = percentile(samples, lo_p)
            return percentile(samples, hi_p) / d if d > 0 else 1.0

        ci = bootstrap_ci(xs, tail, n_boot=n_boot, seed=seed, alpha=alpha)
    elif kind == "ratio":
        ci = bootstrap_ratio_ci(
            arms[gate["num"]]["samples"], arms[gate["den"]]["samples"],
            pstat(gate["p"]), n_boot=n_boot, seed=seed, alpha=alpha)
    else:
        raise ValueError(f"unknown gate kind {kind!r}")
    return dict(gate, ci=list(ci),
                ok=ci_verdict(ci, gate["op"], gate["threshold"]))
