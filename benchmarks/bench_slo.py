"""SLO burn-rate lane: adversary bursts burn the error budget, the
flight recorder fires, and DLBC chunking keeps the budget intact.

Three arms over the same seeded traces, each with the per-tenant
:class:`~repro.obs.monitor.SloMonitor` attached to the batcher and an
armed :class:`~repro.obs.monitor.FlightRecorder` (tracing on, so every
incident embeds its own trace window):

* ``clean``       — the steady tenant alone under its SLO: the
  zero-incident baseline.  Any incident here is a false positive.
* ``adv_whole``   — a long-prompt adversary prefills whole-prompt (the
  pre-DLBC behaviour): every co-scheduled steady decode step absorbs
  the full prompt cost, blowing the steady tenant's per-step cost
  ceiling.  Its error budget burns and ONE ``slo_burn`` incident fires.
* ``adv_chunked`` — the *same* adversary trace, prefill DLBC-chunked at
  ``ADV_PREFILL_CHUNK``: no step exceeds the ceiling, zero incidents.
  Chunking is the SLO story told as a budget, not a percentile.

Gates (exact — integer incident/step counts over seeded runs carry no
sampling noise):

* zero incidents and zero bad steps on ``clean`` and ``adv_chunked``
  on *every* repeat (no false positives at identical settings);
* at least one ``slo_burn`` incident per repeat on ``adv_whole``, fired
  within ``DETECT_WITHIN_K`` steps of the first adversary arrival;
* every incident's embedded trace window passes ``crosscheck()``
  against its embedded telemetry delta — an incident report that lies
  about its own window is itself a failure.

CI replays the verdicts (and the crosschecks, from the persisted
incident files) via ``python -m benchmarks.gates slo``.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MDL
from repro.obs import trace as obs
from repro.obs.monitor import FlightRecorder, SloMonitor
from repro.serve.batcher import ContinuousBatcher, Request

from .common import INCIDENTS_DIR, report
from .harness import Bench

STEPS = 160                 # arrival horizon (runs drain past it)
SLOTS = 4
STEADY_MAX_NEW = 4
STEADY_EVERY = 4            # steady arrival spacing (steps)
ADV_PROMPT_LEN = 48
ADV_MAX_NEW = 2
ADV_EVERY = 12
ADV_PREFILL_CHUNK = 8
CACHE_LEN = 64
STEADY_SLO_STEPS = 40       # whole-request deadline (decode steps)
#: explicit per-step cost ceiling for the steady tenant: own prefill
#: (1 + 3-token prompt) plus one co-scheduled adversary chunk
#: (ADV_PREFILL_CHUNK) is the worst *chunked* step — whole-prompt
#: prefill (1 + ADV_PROMPT_LEN) blows it by ~4x
STEADY_COST_SLO = 1.0 + 3.0 + ADV_PREFILL_CHUNK
#: error budget: BUDGET_FRAC x HORIZON bad steps tolerated before the
#: budget counts as burned — tight enough that the adversary's ~1-in-12
#: bad-step rate fires within a few arrivals
BUDGET_FRAC = 0.05
HORIZON = 60
#: the incident must fire within this many steps of the first adversary
#: arrival (arrivals start at step 0): allowed+1 bad arrivals at
#: ADV_EVERY spacing, plus admission jitter
DETECT_WITHIN_K = 64
ARMS = ("clean", "adv_whole", "adv_chunked")


def _cfg():
    return ModelConfig(name="bench-slo", family="dense", n_layers=2,
                       d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                       vocab=1024)


def make_traces(rng):
    """(steady requests, adversary requests) over the STEPS horizon."""
    steady = [Request(rid=i, prompt=list(rng.integers(0, 1024, size=3)),
                      max_new=STEADY_MAX_NEW, arrive_step=STEADY_EVERY * i,
                      tenant="steady")
              for i in range(STEPS // STEADY_EVERY)]
    adversary = [Request(rid=10_000 + j,
                         prompt=list(rng.integers(0, 1024,
                                                  size=ADV_PROMPT_LEN)),
                         max_new=ADV_MAX_NEW, arrive_step=start,
                         tenant="adversary")
                 for j, start in enumerate(range(0, STEPS, ADV_EVERY))]
    return steady, adversary


def _one_repeat(arm: str, cfg, params, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    steady, adversary = make_traces(rng)
    tenants = {"steady": 3.0}
    reqs = steady
    mode = "chunked"
    if arm != "clean":
        tenants["adversary"] = 1.0
        reqs = steady + adversary
        mode = "whole" if arm == "adv_whole" else "chunked"

    rec = FlightRecorder(out_dir=str(INCIDENTS_DIR))
    monitor = SloMonitor(recorder=rec, budget_frac=BUDGET_FRAC,
                         horizon=HORIZON)
    b = ContinuousBatcher(cfg, params, n_slots=SLOTS, cache_len=CACHE_LEN,
                          policy="wdlbc", tenants=tenants,
                          prefill_chunk=ADV_PREFILL_CHUNK,
                          prefill_mode=mode,
                          slos={"steady": STEADY_SLO_STEPS},
                          monitor=monitor)
    # explicit per-step ceiling (TenantQueue.slo_cost): the derived
    # max(2, slo/4) ceiling would flag benign chunk collisions
    b.registry.get("steady").slo_cost = STEADY_COST_SLO
    rec.telemetry = b.sched.telemetry

    obs.enable()
    try:
        rec.arm()  # clears the rings: the window starts at step 0
        b.run(reqs, max_steps=STEPS * 20)
    finally:
        obs.disable()
        obs.clear()

    tele = b.sched.telemetry
    assert tele.spawns == tele.joins, \
        (arm, "quiescence: every admitted request completed")
    incidents = list(rec.incidents)
    bad_cross = sum(1 for i in incidents
                    if not i.get("crosscheck", {}).get("ok", False))
    steady_budget = monitor.summary()["tenants"].get("steady", {})
    return dict(
        arm=arm, seed=seed, steps=b.stats.steps,
        prefill_mode=mode,
        incidents=len(incidents),
        slo_burn_incidents=rec.count("slo_burn"),
        incident_crosscheck_failures=bad_cross,
        first_burn_step=steady_budget.get("first_burn_step"),
        bad_steps=steady_budget.get("bad_steps", 0),
        observed_steps=steady_budget.get("observed_steps", 0),
        budget_spent=steady_budget.get("budget_spent", 0.0),
        monitor=monitor.summary(),
        sched=tele.summary(),
        tenant_stats={t: s.summary()
                      for t, s in b.tenant_stats.items()})


def run(seed: int = 0, repeats: int = 5):
    cfg = _cfg()
    params = MDL.init_params(cfg, jax.random.PRNGKey(seed))
    repeats = max(int(repeats or 5), 5)
    bench = Bench("slo", seed=seed, repeats=repeats)

    records = []
    for rep in range(repeats):
        for arm in ARMS:
            r = _one_repeat(arm, cfg, params, seed + rep)
            r["repeat"] = rep
            records.append(r)

    by = {arm: [r for r in records if r["arm"] == arm] for arm in ARMS}
    detect = [r["first_burn_step"] for r in by["adv_whole"]
              if r["first_burn_step"] is not None]
    burn_rates = [r["bad_steps"] / max(1, BUDGET_FRAC * HORIZON)
                  for r in by["adv_whole"]]

    bench.add_samples("whole_detect_step", detect or [float(STEPS * 20)],
                      unit="steps")
    bench.add_samples("whole_burn_rate", burn_rates, unit="ratio")
    bench.add_samples("whole_bad_steps",
                      [float(r["bad_steps"]) for r in by["adv_whole"]],
                      unit="steps")

    # exact gates: integer incident/step counts over seeded runs
    bench.gate_exact("clean_zero_incidents",
                     sum(r["incidents"] for r in by["clean"]), "<=", 0)
    bench.gate_exact("clean_zero_bad_steps",
                     sum(r["bad_steps"] for r in by["clean"]), "<=", 0)
    bench.gate_exact("chunked_zero_incidents",
                     sum(r["incidents"] for r in by["adv_chunked"]),
                     "<=", 0)
    bench.gate_exact("whole_incident_fired",
                     min(r["slo_burn_incidents"] for r in by["adv_whole"]),
                     ">=", 1)
    bench.gate_exact("detect_within_k",
                     max(detect) if detect else float(STEPS * 20),
                     "<=", DETECT_WITHIN_K)
    bench.gate_exact("incident_crosscheck",
                     sum(r["incident_crosscheck_failures"]
                         for r in records), "<=", 0)

    rows = []
    for arm in ARMS:
        rs = by[arm]
        rows.append([
            arm, rs[0]["prefill_mode"],
            sum(r["incidents"] for r in rs),
            sum(r["bad_steps"] for r in rs),
            f"{max(r['budget_spent'] for r in rs):.2f}",
            min((r["first_burn_step"] for r in rs
                 if r["first_burn_step"] is not None), default="-"),
            len(rs)])
    for g in bench.gates:
        print(f"gate {g['gate']}: value={g['value']:.3f} "
              f"{g['op']} {g['threshold']} -> "
              f"{'ok' if g['ok'] else 'FAIL'}")
    out = report(
        f"SLO burn-rate lane: adversary bursts vs DLBC chunking "
        f"(budget {BUDGET_FRAC:.0%} x {HORIZON} steps, detect<=K="
        f"{DETECT_WITHIN_K}, {repeats} repeats, seed {seed})",
        rows,
        ["arm", "prefill", "incidents", "bad_steps", "max_budget_spent",
         "first_burn", "repeats"],
        "slo", records, harness=bench.payload())
    bench.check()
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)
    run(seed=args.seed, repeats=args.repeats)


if __name__ == "__main__":
    main()
