"""Multi-tenant serving isolation: weighted-DLBC admission over one
SlotExecutor.

Scenario: a *steady* tenant trickles short requests while a *bursty*
tenant dumps synchronized bursts.  Three arms over the same traces:

* ``solo``      — the steady tenant alone: the *oracle* arm (its
                  unloaded baseline — isolation is judged against it);
* ``fifo``      — both tenants through the single anonymous DLBC queue
                  (no isolation: the burst queues ahead of later steady
                  arrivals);
* ``weighted``  — per-tenant queues, weighted-DLBC admission
                  (``steady`` weighted above ``bursty``).

Isolation gate: with weight share ``s = w_steady / W``, the steady
tenant keeps ≥ ``s`` of the slot capacity, so its p99 may grow by at
most the inverse share plus one bursty service time (slots are
non-preemptive — a just-admitted burst request holds its slot for its
full decode):

    p99_weighted(steady) <= p99_solo(steady) / s + bursty_max_new + slack

The whole scenario triple runs ``repeats`` times under per-repeat seeds
and the gate is a *bootstrap-CI* verdict over the per-repeat ratio
``p99_weighted / bound`` — a single noisy repeat widens the interval
instead of failing the lane (the old single-run assert was exactly the
flaky-runner hazard the harness exists to kill).  CI replays the same
verdict from ``tenants.json`` via ``python -m benchmarks.gates
tenants``.  Telemetry conservation (per-tenant spawns/joins sum to the
globals) stays an exact per-repeat assert: counters carry no noise.

Long-prompt adversary (the chunked-prefill SLO surface): a second
scenario triple where an *adversary* tenant submits long prompts
(~``ADV_PROMPT_LEN`` tokens, ``max_new=2``) into the steady trickle.
The judged metric is the steady tenant's per-token decode-step cost p99
(``ServeStats.p99_decode_cost`` — vtime units where one decode = 1 and
a prefill chunk of ``c`` tokens = ``c``):

* ``adv_solo``    — steady alone (oracle: its unloaded decode cost);
* ``adv_whole``   — adversary prefills whole-prompt in its placement
                    step (the pre-DLBC behaviour: every co-resident
                    decode that step stalls for the full prompt);
* ``adv_chunked`` — adversary prefill is DLBC-chunked at
                    ``ADV_PREFILL_CHUNK`` and interleaved with decode.

Gates (bootstrap CI over per-repeat ratios): chunked steady decode p99
≤ solo p99 + one prefill-chunk service time, and whole-prompt p99 /
chunked p99 ≥ ``CHUNKING_GAIN_MIN`` (chunking must actually buy the
tail back, not just not hurt).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MDL
from repro.serve.batcher import ContinuousBatcher, Request

from .common import report
from .harness import Bench

STEADY_MAX_NEW = 4
BURSTY_MAX_NEW = 8
SLACK_STEPS = 4
#: CI-judged thresholds on per-repeat ratios (fail only when the
#: bootstrap interval excludes them)
ISOLATION_RATIO_MAX = 1.0   # p99_weighted / bound
WEIGHTED_VS_FIFO_MAX = 1.0  # weighted must not serve steady worse

# -- long-prompt adversary (chunked-prefill SLO surface) -------------------
ADV_PROMPT_LEN = 48         # adversary prompt length (tokens)
ADV_MAX_NEW = 2             # adversary is prefill-heavy, decode-light
ADV_EVERY = 12              # steps between adversary arrivals
ADV_PREFILL_CHUNK = 8       # DLBC chunk cap in the adversary arms
ADV_CACHE_LEN = 64          # adversary prompts need the deeper cache
PREFILL_ISOLATION_MAX = 1.0  # chunked p99 / (solo p99 + chunk) bound
CHUNKING_GAIN_MIN = 1.5     # whole p99 / chunked p99 must exceed this


def _cfg():
    return ModelConfig(name="bench-tenants", family="dense", n_layers=2,
                       d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                       vocab=1024)


def make_traces(steps: int, rng):
    """(steady requests, bursty requests) over a ``steps``-long horizon."""
    steady = [Request(rid=i, prompt=list(rng.integers(0, 1024, size=3)),
                      max_new=STEADY_MAX_NEW, arrive_step=4 * i,
                      tenant="steady")
              for i in range(max(2, steps // 4))]
    bursty, rid = [], 10_000
    for start in range(0, steps, max(1, steps // 2)):
        for _ in range(24):
            bursty.append(Request(
                rid=rid, prompt=list(rng.integers(0, 1024, size=3)),
                max_new=BURSTY_MAX_NEW, arrive_step=start, tenant="bursty"))
            rid += 1
    return steady, bursty


def make_adversary_trace(steps: int, rng):
    """Long-prompt, short-decode requests arriving every ADV_EVERY steps."""
    return [Request(rid=20_000 + j,
                    prompt=list(rng.integers(0, 1024,
                                             size=ADV_PROMPT_LEN)),
                    max_new=ADV_MAX_NEW, arrive_step=start,
                    tenant="adversary")
            for j, start in enumerate(range(0, steps, ADV_EVERY))]


def _run_adversary_repeat(cfg, params, steps, slots, weights, seed):
    """Steady decode-cost p99 under a long-prompt adversary: solo vs
    whole-prompt prefill vs DLBC-chunked prefill.  Returns the
    per-scenario records and ``{scenario: steady p99_decode_cost}``."""
    w_steady, w_adv = weights
    max_steps = steps * 20

    def fresh(mode, tenants):
        return ContinuousBatcher(cfg, params, n_slots=slots,
                                 cache_len=ADV_CACHE_LEN, policy="wdlbc",
                                 tenants=tenants,
                                 prefill_chunk=ADV_PREFILL_CHUNK,
                                 prefill_mode=mode)

    def traces():
        rng = np.random.default_rng(seed)
        steady, _ = make_traces(steps, rng)
        return steady, make_adversary_trace(steps, rng)

    scenarios = {}
    steady, _ = traces()
    b = fresh("chunked", tenants={"steady": w_steady})
    b.run(steady, max_steps=max_steps)
    scenarios["adv_solo"] = b

    for name, mode in (("adv_whole", "whole"), ("adv_chunked", "chunked")):
        steady, adversary = traces()
        b = fresh(mode, tenants={"steady": w_steady, "adversary": w_adv})
        b.run(steady + adversary, max_steps=max_steps)
        scenarios[name] = b

    records, cost_p99s = [], {}
    for name, batcher in scenarios.items():
        tstats = {t: s.summary() for t, s in batcher.tenant_stats.items()}
        sched = batcher.sched.telemetry.summary()
        cost_p99s[name] = float(tstats["steady"]["p99_decode_cost"])
        records.append(dict(
            scenario=name, policy=batcher.policy, seed=seed,
            steps=batcher.stats.steps,
            utilization=batcher.stats.utilization,
            steady_p99_decode_cost=cost_p99s[name],
            prefill_mode=batcher.prefill_mode,
            prefill_chunk=ADV_PREFILL_CHUNK,
            role="oracle" if name == "adv_solo" else "candidate",
            sched=sched, tenant_stats=tstats,
            weights=dict(steady=w_steady, adversary=w_adv)))

        # -- exact conservation, asserted on every repeat ----------------
        tele = batcher.sched.telemetry
        totals = tele.tenant_totals()
        assert totals["spawns"] == tele.spawns == tele.joins, \
            (name, "quiescence: every admitted request completed")
        # AFE: joins count requests, never prefill chunks
        assert tele.joins == len(batcher.stats.latencies), \
            (name, tele.joins, len(batcher.stats.latencies))
        assert sched["prefill_tokens"] > 0, (name, "prefill ran")

    # chunked and whole arms prefill the SAME token work — only the
    # schedule differs
    by = {r["scenario"]: r for r in records}
    assert (by["adv_chunked"]["sched"]["prefill_tokens"]
            == by["adv_whole"]["sched"]["prefill_tokens"])
    return records, cost_p99s


def _run_repeat(cfg, params, steps, slots, weights, seed):
    """One pass over the three scenarios under one seed; returns the
    per-scenario records and the steady-tenant p99s."""
    w_steady, w_bursty = weights
    max_steps = steps * 20  # drain room well past the arrival horizon

    def fresh(policy, tenants=None):
        return ContinuousBatcher(cfg, params, n_slots=slots, cache_len=32,
                                 policy=policy, tenants=tenants)

    def traces():  # fresh Request objects per scenario (runs mutate them)
        return make_traces(steps, np.random.default_rng(seed))

    scenarios, steady_traces = {}, {}

    steady, _ = traces()
    b = fresh("wdlbc", tenants={"steady": w_steady})
    b.run(steady, max_steps=max_steps)
    scenarios["solo"], steady_traces["solo"] = b, steady

    steady, bursty = traces()
    b = fresh("dlbc")
    b.run(steady + bursty, max_steps=max_steps)
    scenarios["fifo"], steady_traces["fifo"] = b, steady

    steady, bursty = traces()
    b = fresh("wdlbc", tenants={"steady": w_steady, "bursty": w_bursty})
    b.run(steady + bursty, max_steps=max_steps)
    scenarios["weighted"], steady_traces["weighted"] = b, steady

    records, steady_p99s = [], {}
    for name, batcher in scenarios.items():
        st = batcher.stats
        tstats = {t: s.summary() for t, s in batcher.tenant_stats.items()}
        tele = batcher.sched.telemetry
        steady_p99 = (tstats.get("steady", {}).get("p99_latency")
                      if tstats else None)
        if steady_p99 is None:  # fifo run: recover per-tenant from requests
            lat = [r.done_step - r.arrive_step for r in steady_traces[name]
                   if r.done_step is not None]
            steady_p99 = float(np.percentile(lat, 99)) if lat else 0.0
        steady_p99s[name] = float(steady_p99)
        records.append(dict(
            scenario=name, policy=batcher.policy, steps=st.steps,
            seed=seed, utilization=st.utilization,
            p99_latency=st.p99_latency,
            steady_p99=float(steady_p99),
            role="oracle" if name == "solo" else "candidate",
            slot_shares=batcher.slot_shares(),
            sched=tele.summary(),
            tenant_stats=tstats,
            weights=dict(steady=w_steady, bursty=w_bursty)))

    # -- telemetry conservation: exact, asserted on every repeat ---------
    for name in ("solo", "weighted"):
        tele = scenarios[name].sched.telemetry
        totals = tele.tenant_totals()
        assert totals["spawns"] == tele.spawns, (name, totals, tele.spawns)
        assert totals["joins"] == tele.joins, (name, totals, tele.joins)
        assert tele.spawns == tele.joins, \
            (name, "quiescence: every admitted request completed")
    return records, steady_p99s


def run(steps: int = 200, slots: int = 4, weights=(3.0, 1.0),
        seed: int = 0, repeats: int = 5):
    cfg = _cfg()
    params = MDL.init_params(cfg, jax.random.PRNGKey(seed))
    w_steady, w_bursty = weights
    share = w_steady / (w_steady + w_bursty)
    repeats = max(int(repeats), 5)
    bench = Bench("tenants", seed=seed, repeats=repeats)

    all_records, p99s = [], {"solo": [], "fifo": [], "weighted": []}
    iso_ratios, fifo_ratios, bounds = [], [], []
    costs = {"adv_solo": [], "adv_whole": [], "adv_chunked": []}
    prefill_iso_ratios, chunk_gain_ratios = [], []
    for rep in range(repeats):
        records, steady_p99 = _run_repeat(cfg, params, steps, slots,
                                          weights, seed + rep)
        adv_records, cost_p99 = _run_adversary_repeat(
            cfg, params, steps, slots, weights, seed + rep)
        for r in records + adv_records:
            r["repeat"] = rep
        all_records.extend(records + adv_records)
        for name in p99s:
            p99s[name].append(steady_p99[name])
        for name in costs:
            costs[name].append(cost_p99[name])
        bound = steady_p99["solo"] / share + BURSTY_MAX_NEW + SLACK_STEPS
        bounds.append(bound)
        iso_ratios.append(steady_p99["weighted"] / bound)
        fifo_ratios.append(
            steady_p99["weighted"] / steady_p99["fifo"]
            if steady_p99["fifo"] > 0 else 0.0)
        # one prefill chunk is the most extra vtime any decode step can
        # absorb under chunking — the SLO bound the tentpole exists for
        cost_bound = cost_p99["adv_solo"] + ADV_PREFILL_CHUNK
        prefill_iso_ratios.append(cost_p99["adv_chunked"] / cost_bound)
        chunk_gain_ratios.append(
            cost_p99["adv_whole"] / cost_p99["adv_chunked"]
            if cost_p99["adv_chunked"] > 0 else 0.0)

    for name, samples in p99s.items():
        bench.add_samples(name, samples, unit="steps",
                          oracle=name == "solo")
    bench.add_samples("isolation_ratio", iso_ratios, unit="ratio")
    bench.add_samples("weighted_vs_fifo", fifo_ratios, unit="ratio")
    for name, samples in costs.items():
        bench.add_samples(name, samples, unit="tokens",
                          oracle=name == "adv_solo")
    bench.add_samples("prefill_isolation_ratio", prefill_iso_ratios,
                      unit="ratio")
    bench.add_samples("prefill_chunking_gain", chunk_gain_ratios,
                      unit="ratio")
    bench.gate_samples("isolation", "isolation_ratio", "<=",
                       ISOLATION_RATIO_MAX, p=50)
    bench.gate_samples("weighted_vs_fifo", "weighted_vs_fifo", "<=",
                       WEIGHTED_VS_FIFO_MAX, p=50)
    # the acceptance bound: steady decode p99 under a chunked adversary
    # stays within solo p99 + one prefill-chunk service time
    bench.gate_samples("prefill_isolation", "prefill_isolation_ratio",
                       "<=", PREFILL_ISOLATION_MAX, p=50)
    bench.gate_samples("prefill_chunking_gain", "prefill_chunking_gain",
                       ">=", CHUNKING_GAIN_MIN, p=50)

    rows = []
    for name in ("solo", "fifo", "weighted"):
        d = bench.arms[name]["dist"]
        rows.append([name, f"{d['p50']:.1f}", f"{d['p99']:.1f}",
                     f"{d['max']:.1f}", d["n"]])
    for name in ("adv_solo", "adv_whole", "adv_chunked"):
        d = bench.arms[name]["dist"]
        rows.append([f"{name} (cost)", f"{d['p50']:.1f}",
                     f"{d['p99']:.1f}", f"{d['max']:.1f}", d["n"]])
    print(f"isolation: steady p99 solo={np.median(p99s['solo']):.1f} "
          f"weighted={np.median(p99s['weighted']):.1f} "
          f"fifo={np.median(p99s['fifo']):.1f} "
          f"bound~{np.median(bounds):.1f} (share={share:.2f}, "
          f"{repeats} repeats)")
    print(f"prefill: steady decode-cost p99 solo="
          f"{np.median(costs['adv_solo']):.1f} "
          f"whole={np.median(costs['adv_whole']):.1f} "
          f"chunked={np.median(costs['adv_chunked']):.1f} "
          f"(chunk={ADV_PREFILL_CHUNK}, prompt={ADV_PROMPT_LEN})")
    for g in bench.gates:
        print(f"gate {g['gate']}: value={g['value']:.3f} "
              f"ci=[{g['ci'][0]:.3f}, {g['ci'][1]:.3f}] "
              f"{g['op']} {g['threshold']} -> "
              f"{'ok' if g['ok'] else 'FAIL'}")
    bench.check()

    return report(
        "Multi-tenant serving: weighted-DLBC isolation under bursts "
        f"({repeats} repeats, seed {seed})",
        rows, ["scenario", "steady_p50", "steady_p99", "steady_max",
               "repeats"],
        "tenants", all_records, harness=bench.payload())


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)
    run(steps=args.steps, slots=args.slots, seed=args.seed,
        repeats=args.repeats)


if __name__ == "__main__":
    main()
