"""Multi-tenant serving isolation: weighted-DLBC admission over one
SlotExecutor.

Scenario: a *steady* tenant trickles short requests while a *bursty*
tenant dumps synchronized bursts.  Three runs over the same traces:

* ``solo``      — the steady tenant alone (its unloaded baseline);
* ``fifo``      — both tenants through the single anonymous DLBC queue
                  (no isolation: the burst queues ahead of later steady
                  arrivals);
* ``weighted``  — per-tenant queues, weighted-DLBC admission
                  (``steady`` weighted above ``bursty``).

Isolation gate (asserted here AND re-checked from the JSON in CI): with
weight share ``s = w_steady / W``, the steady tenant keeps ≥ ``s`` of the
slot capacity, so its p99 may grow by at most the inverse share plus one
bursty service time (slots are non-preemptive — a just-admitted burst
request holds its slot for its full decode):

    p99_weighted(steady) <= p99_solo(steady) / s + bursty_max_new + slack

Telemetry conservation is gated too: per-tenant spawns/joins must sum to
the global counters.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MDL
from repro.serve.batcher import ContinuousBatcher, Request

from .common import report

STEADY_MAX_NEW = 4
BURSTY_MAX_NEW = 8
SLACK_STEPS = 4


def _cfg():
    return ModelConfig(name="bench-tenants", family="dense", n_layers=2,
                       d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                       vocab=1024)


def make_traces(steps: int, rng):
    """(steady requests, bursty requests) over a ``steps``-long horizon."""
    steady = [Request(rid=i, prompt=list(rng.integers(0, 1024, size=3)),
                      max_new=STEADY_MAX_NEW, arrive_step=4 * i,
                      tenant="steady")
              for i in range(max(2, steps // 4))]
    bursty, rid = [], 10_000
    for start in range(0, steps, max(1, steps // 2)):
        for _ in range(24):
            bursty.append(Request(
                rid=rid, prompt=list(rng.integers(0, 1024, size=3)),
                max_new=BURSTY_MAX_NEW, arrive_step=start, tenant="bursty"))
            rid += 1
    return steady, bursty


def run(steps: int = 200, slots: int = 4, weights=(3.0, 1.0), seed: int = 0):
    cfg = _cfg()
    params = MDL.init_params(cfg, jax.random.PRNGKey(seed))
    w_steady, w_bursty = weights
    share = w_steady / (w_steady + w_bursty)
    max_steps = steps * 20  # drain room well past the arrival horizon

    def fresh(policy, tenants=None):
        return ContinuousBatcher(cfg, params, n_slots=slots, cache_len=32,
                                 policy=policy, tenants=tenants)

    def traces():  # fresh Request objects per scenario (runs mutate them)
        return make_traces(steps, np.random.default_rng(seed))

    scenarios, steady_traces = {}, {}

    steady, _ = traces()
    b = fresh("wdlbc", tenants={"steady": w_steady})
    b.run(steady, max_steps=max_steps)
    scenarios["solo"], steady_traces["solo"] = b, steady

    steady, bursty = traces()
    b = fresh("dlbc")
    b.run(steady + bursty, max_steps=max_steps)
    scenarios["fifo"], steady_traces["fifo"] = b, steady

    steady, bursty = traces()
    b = fresh("wdlbc", tenants={"steady": w_steady, "bursty": w_bursty})
    b.run(steady + bursty, max_steps=max_steps)
    scenarios["weighted"], steady_traces["weighted"] = b, steady

    rows, records = [], []
    for name, batcher in scenarios.items():
        st = batcher.stats
        tstats = {t: s.summary() for t, s in batcher.tenant_stats.items()}
        tele = batcher.sched.telemetry
        steady_p99 = (tstats.get("steady", {}).get("p99_latency")
                      if tstats else None)
        if steady_p99 is None:  # fifo run: recover per-tenant from requests
            lat = [r.done_step - r.arrive_step for r in steady_traces[name]
                   if r.done_step is not None]
            steady_p99 = float(np.percentile(lat, 99)) if lat else 0.0
        rec = dict(scenario=name, policy=batcher.policy, steps=st.steps,
                   utilization=st.utilization,
                   p99_latency=st.p99_latency,
                   steady_p99=float(steady_p99),
                   slot_shares=batcher.slot_shares(),
                   sched=tele.summary(),
                   tenant_stats=tstats,
                   weights=dict(steady=w_steady, bursty=w_bursty))
        records.append(rec)
        rows.append([name, st.steps, f"{st.utilization:.3f}",
                     f"{float(steady_p99):.1f}", f"{st.p99_latency:.1f}"])

    by_name = {r["scenario"]: r for r in records}
    # -- telemetry conservation: per-tenant spawns/joins sum to global ------
    for name in ("solo", "weighted"):
        tele = scenarios[name].sched.telemetry
        totals = tele.tenant_totals()
        assert totals["spawns"] == tele.spawns, (name, totals, tele.spawns)
        assert totals["joins"] == tele.joins, (name, totals, tele.joins)
        assert tele.spawns == tele.joins, \
            (name, "quiescence: every admitted request completed")
    # -- isolation gate ------------------------------------------------------
    solo_p99 = by_name["solo"]["steady_p99"]
    weighted_p99 = by_name["weighted"]["steady_p99"]
    bound = solo_p99 / share + BURSTY_MAX_NEW + SLACK_STEPS
    print(f"isolation: steady p99 solo={solo_p99:.1f} "
          f"weighted={weighted_p99:.1f} fifo={by_name['fifo']['steady_p99']:.1f} "
          f"bound={bound:.1f} (share={share:.2f})")
    assert weighted_p99 <= bound, \
        f"bursty tenant broke steady tenant's p99 beyond its weight " \
        f"share: {weighted_p99:.1f} > {bound:.1f}"
    assert weighted_p99 <= by_name["fifo"]["steady_p99"], \
        "weighted admission must not serve the steady tenant worse than " \
        "the anonymous FIFO it replaces"

    return report(
        "Multi-tenant serving: weighted-DLBC isolation under bursts",
        rows, ["scenario", "steps", "util", "steady_p99", "p99_all"],
        "tenants", records)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(steps=args.steps, slots=args.slots, seed=args.seed)


if __name__ == "__main__":
    main()
