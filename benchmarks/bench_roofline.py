"""§Roofline — per (arch × shape × mesh) terms from the dry-run artifacts
(compiled on 512 host devices by repro.launch.dryrun; trip-count-scaled
HLO analysis)."""

from __future__ import annotations

import json
from pathlib import Path

from .common import report

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_cells(mesh="16x16", policy="afe", schedule="masked"):
    cells = []
    for f in sorted(DRYRUN_DIR.glob(f"{mesh}_*_{policy}_{schedule}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "ok":
            cells.append(rec)
    return cells


def run(mesh: str = "16x16"):
    cells = load_cells(mesh)
    if not cells:
        print(f"(no dry-run artifacts for mesh {mesh} yet — run "
              "`python -m repro.launch.dryrun` first)")
        return []
    rows = []
    for rec in sorted(cells, key=lambda r: (r["arch"], r["shape"])):
        t = rec["roofline"]
        rows.append([
            rec["arch"], rec["shape"],
            f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
            f"{t['collective_s']:.4f}", t["dominant"],
            f"{rec['roofline_fraction']:.3f}",
            f"{t['useful_flops_ratio']:.2f}",
            f"{rec['hbm_per_device_gb']:.1f}",
            "yes" if rec["fits_hbm"] else "NO",
        ])
    report(f"Roofline terms per cell (mesh {mesh}; seconds/step; "
           "v5e 197TF/s bf16, 819GB/s HBM, 50GB/s ICI)",
           rows, ["arch", "shape", "compute_s", "memory_s", "collective_s",
                  "dominant", "roofline_frac", "useful_flops",
                  "hbm_GB", "fits"],
           f"roofline_{mesh}", cells)
    return cells


if __name__ == "__main__":
    run()
    run("2x16x16")
