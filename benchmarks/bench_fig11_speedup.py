"""Paper Fig. 11 — DCAFE speedup over LC for varying worker counts
(simulated time; the paper's 16-core Intel / 64-core AMD sweeps)."""

from __future__ import annotations

from repro.core import build_kernel, run_scheme

from .common import report

KERNELS = ["BFS", "BY", "DR", "DST", "MST", "NQ", "HL", "FL"]
WORKERS = [1, 2, 4, 8, 16, 32, 64]


def geomean(xs):
    import math

    xs = [x for x in xs if x > 0]
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 0.0


def run(scale: str = "bench"):
    records = []
    rows = []
    for kernel in KERNELS:
        k = build_kernel(kernel, scale)
        row = [kernel]
        for w in WORKERS:
            lc = run_scheme(k, "LC", workers=w)
            dc = run_scheme(k, "DCAFE", workers=w)
            sp = lc.time / dc.time if dc.time > 0 else float("inf")
            row.append(f"{sp:.2f}")
            records.append(dict(kernel=kernel, workers=w,
                                lc_time=lc.time, dcafe_time=dc.time,
                                speedup=sp))
        rows.append(row)
    gm = {w: geomean([r["speedup"] for r in records if r["workers"] == w])
          for w in WORKERS}
    report("Fig. 11: speedup = time(LC)/time(DCAFE) vs workers",
           rows, ["kernel"] + [f"W{w}" for w in WORKERS],
           "fig11_speedup", dict(records=records, geomean=gm))
    print("geomean speedup by workers:",
          {w: round(v, 2) for w, v in gm.items()})
    print("(paper: geomean 5.75x @16-core Intel, 4.16x @64-core AMD)\n")
    return records


if __name__ == "__main__":
    run()
