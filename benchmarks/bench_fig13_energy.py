"""Paper Fig. 13 — simulated energy (busy/idle power model + per-op
energy) normalised to UnOpt; the RAPL-measurement analogue."""

from __future__ import annotations

from repro.core import build_kernel, run_scheme

from .common import report

KERNELS = ["BFS", "BY", "DR", "DST", "MST", "NQ", "HL", "FL"]


def run(scale: str = "bench", workers: int = 16):
    records = []
    rows = []
    for kernel in KERNELS:
        k = build_kernel(kernel, scale)
        un = run_scheme(k, "UnOpt", workers=workers)
        lc = run_scheme(k, "LC", workers=workers)
        dc = run_scheme(k, "DCAFE", workers=workers)
        rows.append([kernel, f"{lc.energy / un.energy:.3f}",
                     f"{dc.energy / un.energy:.3f}",
                     f"{dc.energy / lc.energy:.3f}"])
        records.append(dict(kernel=kernel, unopt=un.energy, lc=lc.energy,
                            dcafe=dc.energy))
    report(f"Fig. 13: energy normalised to UnOpt (workers={workers})",
           rows, ["kernel", "LC/UnOpt", "DCAFE/UnOpt", "DCAFE/LC"],
           "fig13_energy", records)
    import math

    ratios = [r["dcafe"] / r["lc"] for r in records]
    gm = math.exp(sum(math.log(x) for x in ratios) / len(ratios))
    print(f"geomean DCAFE/LC energy: {gm:.3f} "
          f"(paper: 0.288 ⇒ 71.2% less)\n")
    return records


if __name__ == "__main__":
    run()
