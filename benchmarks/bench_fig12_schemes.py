"""Paper Fig. 12 — all schemes normalised to UnOpt at max workers
(Serial, UnOpt+AFE, LC, LC+AFE, DLBC, DCAFE)."""

from __future__ import annotations

from repro.core import build_kernel, run_scheme

from .common import report

KERNELS = ["BFS", "BY", "DR", "DST", "MST", "NQ", "HL", "FL"]
SCHEMES = ["Serial", "UnOpt", "UnOpt+AFE", "LC", "LC+AFE", "DLBC", "DCAFE"]


def run(scale: str = "bench", workers: int = 16):
    records = []
    rows = []
    for kernel in KERNELS:
        k = build_kernel(kernel, scale)
        base = run_scheme(k, "UnOpt", workers=workers)
        row = [kernel]
        for scheme in SCHEMES:
            r = run_scheme(k, scheme, workers=workers)
            ratio = base.time / r.time if r.time > 0 else float("inf")
            row.append(f"{ratio:.2f}")
            records.append(dict(kernel=kernel, scheme=scheme, time=r.time,
                                vs_unopt=ratio, ok=r.ok))
        rows.append(row)
    report(f"Fig. 12: time(UnOpt)/time(scheme), workers={workers}",
           rows, ["kernel"] + SCHEMES, "fig12_schemes", records)
    import math

    for scheme in ("LC", "LC+AFE", "DLBC", "DCAFE"):
        vals = [r["vs_unopt"] for r in records if r["scheme"] == scheme
                and r["vs_unopt"] > 0]
        gm = math.exp(sum(math.log(v) for v in vals) / len(vals))
        print(f"geomean {scheme} vs UnOpt: {gm:.2f}x")
    print("(paper @16-core Intel: LC 2.2x, LC+AFE 1.31x, DLBC 12.28x, "
          "DCAFE 12.64x)\n")
    return records


if __name__ == "__main__":
    run()
