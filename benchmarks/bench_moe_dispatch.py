"""DLBC vs LC MoE dispatch (paper §3.2 in its MoE form): dropped-token
fraction across capacity factors and input skews.

Records speak the shared spawn/join/drop telemetry vocabulary (one row
per policy, same field names as ``bench_ep``/``bench_adoption``), so
the ``moe_dispatch.json`` and ``ep.json`` CI artifacts are directly
comparable."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import moe as MOE

from .common import report


def skewed_tokens(key, T, d, n_clusters, spread):
    k1, k2 = jax.random.split(key)
    base = jax.random.normal(k1, (n_clusters, d))
    reps = jnp.repeat(base, T // n_clusters, axis=0)
    return reps + spread * jax.random.normal(k2, (T, d))


def run(seed: int = 0):
    cfg0 = get_config("mixtral-8x7b", smoke=True)
    p = MOE.moe_init(jax.random.PRNGKey(seed), cfg0, jnp.float32)
    rows, records = [], []
    for cf in (1.0, 1.25, 2.0):
        for skew_clusters, spread in ((4, 0.05), (8, 0.3), (64, 1.0)):
            x = skewed_tokens(jax.random.PRNGKey(seed + 3), 512,
                              cfg0.d_model, skew_clusters, spread)
            drop = {}
            for dispatch in ("lc", "dlbc"):
                cfg = dataclasses.replace(cfg0, moe_dispatch=dispatch,
                                          moe_capacity_factor=cf)
                _, stats = MOE.moe_apply(p, cfg, x, return_stats=True)
                drop[dispatch] = float(stats["dropped_frac"])
                # one record per policy in the shared telemetry
                # vocabulary (spawns + dropped == T*K pairs; joins is
                # the single gate-combine regardless of rounds)
                records.append(dict(
                    arm=dispatch, capacity_factor=cf,
                    # LC static chunking is the oracle arm DLBC is
                    # judged against (drop-rate delta per row)
                    role="oracle" if dispatch == "lc" else "candidate",
                    clusters=skew_clusters,
                    spawns=int(stats["spawns"]),
                    joins=int(stats["joins"]),
                    rounds=int(stats["rounds"]),
                    dropped_frac=float(stats["dropped_frac"])))
            rows.append([cf, skew_clusters,
                         f"{drop['lc']:.3f}", f"{drop['dlbc']:.3f}",
                         f"{(drop['lc'] - drop['dlbc']):+.3f}"])
    report("MoE dispatch: dropped-token fraction (lower is better)",
           rows, ["cap_factor", "skew_clusters", "LC", "DLBC", "delta"],
           "moe_dispatch", records)
    return records


if __name__ == "__main__":
    run()
