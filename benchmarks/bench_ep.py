"""Expert-parallel vs data-parallel MoE dispatch (repro.ep).

Runs the ``ep_dispatch_combine`` round on a 2-shard ``expert`` mesh
against the single-host ``dispatch_combine`` baseline, under a
perfectly balanced round-robin router and a hot-expert skew, and emits
the shared spawn/join/drop + exchange telemetry so the ``ep.json`` and
``moe_dispatch.json`` artifacts are directly comparable in CI.

Gates (asserted here AND re-checked from the JSON artifact in CI):

* **AFE** — every EP round performs exactly ONE join
  (``joins == rounds``): the all-to-all round has a single barrier, no
  per-expert or per-shard synchronization.
* **DLBC** — zero dropped tokens on the balanced router at
  ``capacity_factor >= 1.0`` (the exchange plan reassigns residuals
  instead of dropping per-shard).

The expert shards are XLA host devices
(``--xla_force_host_platform_device_count``), so the wall-clock column
is a *smoke* trajectory (collective mechanics, not ICI bandwidth); the
run happens in a subprocess so the device-count override never leaks
into sibling benchmarks.

Wall clock is routed through :class:`benchmarks.harness.Bench`: the
inner process emits per-iteration millisecond samples (≥5 seeded
iters), the parent registers ``dp/{router}`` oracle arms against
``ep/{router}`` candidates, and the EP-overhead ceiling is a
bootstrap-CI median-ratio gate replayed in CI from ``ep.json``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from .common import report
from .harness import Bench

#: EP over two *host-platform smoke* shards vs the single-host two-round
#: baseline — a mechanics-overhead ceiling, not an ICI claim (judged at
#: the median via bootstrap CI; host collectives are noisy).
EP_VS_DP_MAX = 2.5

INNER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import dataclasses, json, time
    import jax, jax.numpy as jnp

    from repro.configs import get_config
    from repro.distributed.sharding import mesh_context
    from repro.launch.mesh import make_test_mesh
    from repro.models import moe as MOE
    from repro.ep.dispatch import ep_round
    from repro.obs import trace as obs
    from repro.sched import SchedTelemetry

    obs.enable()  # traced run: the ep.trace.json artifact for CI replay

    # seed/repeats threaded from the parent bench (--seed/--repeats)
    SEED = int(os.environ.get("REPRO_BENCH_SEED", "0"))
    ITERS = int(os.environ.get("REPRO_BENCH_REPEATS", "3"))
    T, CF = 256, 1.0
    cfg0 = dataclasses.replace(get_config("mixtral-8x7b", smoke=True),
                               moe_capacity_factor=CF)
    E, K, d = cfg0.n_experts, cfg0.top_k, cfg0.d_model
    p = MOE.moe_init(jax.random.PRNGKey(SEED), cfg0, jnp.float32)

    # Balanced router: logits read the first E input dims (identity
    # router) and token t prefers experts (t%E, (t+1)%E) -- every expert
    # sees exactly T*K/E pairs, every lane exactly T_local*K/S.
    p_bal = dict(p)
    p_bal["router"] = jnp.zeros((d, E), jnp.float32).at[
        jnp.arange(E), jnp.arange(E)].set(1.0)
    xb = jnp.zeros((T, d), jnp.float32)
    t = jnp.arange(T)
    xb = xb.at[t, t % E].set(3.0).at[t, (t + 1) % E].set(2.0)
    xb = xb + 0.01 * jax.random.normal(jax.random.PRNGKey(SEED + 1), (T, d))

    # Hot-expert skew: the stock router biased hard toward expert 0.
    p_hot = dict(p)
    p_hot["router"] = p["router"].at[:, 0].add(4.0)
    xh = jax.random.normal(jax.random.PRNGKey(SEED + 2), (T, d))

    def timed(fn, iters=ITERS):
        # per-iteration samples, not a single mean: the parent routes
        # these through the bootstrap-CI harness (benchmarks.harness)
        f = jax.jit(fn)
        jax.block_until_ready(f())  # compile
        samples = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            samples.append((time.perf_counter() - t0) * 1e3)
        return samples

    records = []
    ep_tels = []
    mesh = make_test_mesh(data=1, model=1, expert=2)
    for router, pp, xx in (("balanced", p_bal, xb), ("hot", p_hot, xh)):
        # --- data-parallel baseline (single-host two-round dispatch) ---
        cfg = dataclasses.replace(cfg0, moe_dispatch="dlbc")
        y, st = MOE.moe_apply(pp, cfg, xx, return_stats=True)
        ms_samples = timed(lambda: MOE.moe_apply(pp, cfg, xx))
        records.append(dict(
            # the single-host two-round dispatch is the oracle arm: EP
            # must match its combined output (asserted in test_ep)
            arm="dp", role="oracle", router=router,
            capacity_factor=CF, ms=sorted(ms_samples)[len(ms_samples) // 2],
            ms_samples=ms_samples, seed=SEED, iters=ITERS,
            spawns=int(st["spawns"]), joins=int(st["joins"]),
            rounds=int(st["rounds"]),
            dropped_frac=float(st["dropped_frac"])))
        # --- expert-parallel all-to-all over 2 shards ------------------
        ecfg = dataclasses.replace(cfg, expert_parallel=True)
        tel = SchedTelemetry()
        ep_tels.append(tel)
        with mesh_context(mesh):
            y, st = ep_round(pp, ecfg, xx, mesh=mesh, telemetry=tel)
            ms_samples = timed(lambda: MOE.moe_apply(pp, ecfg, xx))
        records.append(dict(
            arm="ep", role="candidate", router=router,
            capacity_factor=CF, ms=sorted(ms_samples)[len(ms_samples) // 2],
            ms_samples=ms_samples, seed=SEED, iters=ITERS,
            spawns=st["spawns"], joins=tel.joins,
            rounds=tel.exchange.rounds,
            dropped_frac=st["dropped_frac"], sent=st["sent"],
            received=st["received"], reassigned=st["reassigned"],
            dropped=st["dropped"], n_shards=st["n_shards"],
            lane_capacity=st["lane_capacity"]))

    # One trace artifact across both EP rounds: the per-round telemetry
    # objects are summed into the summary the exporter cross-checks
    # (write_trace raises -> non-zero exit if the counts disagree).
    from benchmarks.common import write_trace
    write_trace("ep", {
        "spawns": sum(t.spawns for t in ep_tels),
        "joins": sum(t.joins for t in ep_tels),
        "exchange": {
            "posted": sum(t.exchange.posted for t in ep_tels),
            "completed": sum(t.exchange.completed for t in ep_tels),
        },
    })
    print("RESULT " + json.dumps(records))
""")


def run(seed: int = 0, repeats: int = 5):
    repeats = max(int(repeats or 5), 5)
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ, PYTHONPATH="src",
               REPRO_BENCH_SEED=str(seed),
               REPRO_BENCH_REPEATS=str(repeats))
    out = subprocess.run([sys.executable, "-c", INNER], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=root)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    records = None
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            records = json.loads(line[len("RESULT "):])
    assert records is not None, "no RESULT line:\n" + out.stdout[-3000:]

    # --- gates (also re-checked from ep.json in CI) ---------------------
    for r in (r for r in records if r["arm"] == "ep"):
        assert r["joins"] == r["rounds"] == 1, (
            f"AFE regressed: EP round made {r['joins']} joins over "
            f"{r['rounds']} rounds on the {r['router']} router")
        assert r["sent"] == r["received"], r
    bal = next(r for r in records
               if r["arm"] == "ep" and r["router"] == "balanced")
    assert bal["dropped"] == 0 and bal["dropped_frac"] == 0.0, (
        f"balanced router dropped {bal['dropped']} pairs at "
        f"capacity_factor {bal['capacity_factor']} — the exchange plan "
        "must reassign residuals, not drop them")

    # --- harness: per-iteration wall samples, bootstrap-CI verdicts -----
    bench = Bench("ep", seed=seed, repeats=repeats)
    for r in records:
        bench.add_samples(f"{r['arm']}/{r['router']}", r["ms_samples"],
                          oracle=r["arm"] == "dp", unit="ms")
    for router in ("balanced", "hot"):
        bench.gate_oracle_ratio(f"ep/{router}", f"dp/{router}",
                                EP_VS_DP_MAX, p=50,
                                name=f"ep_vs_dp_{router}")
    afe_mismatch = sum(abs(r["joins"] - 1) + abs(r["rounds"] - 1)
                       for r in records if r["arm"] == "ep")
    bench.gate_exact("ep_one_join_per_round", afe_mismatch, "<=", 0)
    bench.gate_exact("balanced_dropped_pairs", bal["dropped"], "<=", 0)
    bench.check()

    rows = [[r["arm"], r["router"], f"{r['ms']:.1f}",
             r["spawns"], r["joins"], f"{r['dropped_frac']:.4f}",
             r.get("reassigned", "-"), r.get("dropped", "-")]
            for r in records]
    report("EP vs DP MoE dispatch (2 expert shards, smoke devices, "
           f"{repeats} timed iters)",
           rows, ["arm", "router", "ms(med)", "spawns", "joins",
                  "dropped_frac", "reassigned", "dropped"],
           "ep", records, harness=bench.payload())
    return records


if __name__ == "__main__":
    run()
