"""Named CI gates, replayable from benchmark artifacts.

Every regression gate CI enforces lives here as a named check over a
saved JSON artifact — the exact same code runs locally and in Actions
(the old inline ``python - <<EOF`` blobs could not be executed or
tested outside CI)::

    python -m benchmarks.gates afe        experiments/bench/adoption.json
    python -m benchmarks.gates grain      experiments/bench/grain.json
    python -m benchmarks.gates ep         experiments/bench/ep.json
    python -m benchmarks.gates tenants    experiments/bench/tenants.json
    python -m benchmarks.gates serve      experiments/bench/batcher.json
    python -m benchmarks.gates faults     experiments/bench/faults.json
    python -m benchmarks.gates slo        experiments/bench/slo.json
    python -m benchmarks.gates trace      experiments/bench
    python -m benchmarks.gates dist       experiments/bench/sched.json
    python -m benchmarks.gates trajectory experiments/bench \\
        --prev prev/trajectory.json --out experiments/bench/trajectory.json

Conventions shared by every gate:

* a **missing artifact is a skip, not a failure** — when an earlier
  step failed before the bench wrote the file, that step's failure is
  the signal; piling a traceback on top hides it;
* gates **re-derive** their verdicts from the raw data in the artifact
  (bootstrap CIs are recomputed from the stored samples via
  :func:`benchmarks.harness.replay_gate`) — a producer cannot pass CI
  by writing ``ok: true``;
* distribution gates fail only when the bootstrap CI *excludes* the
  threshold — one noisy repeat widens the interval instead of flipping
  the verdict (see ``benchmarks/harness.py``).

The ``trajectory`` command collects every gated metric from a results
directory into one ``trajectory.json`` and diffs it against the
previous commit's (actions/cache-backed in CI), failing on a >10%
regression on any gated surface; artifacts with a different
``schema_version`` are refused (reported, not compared) instead of
KeyError-ing mid-diff.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from pathlib import Path

from .harness import SCHEMA_VERSION, replay_gate
from .common import load_envelope, load_records

#: trajectory regression tolerance: >10% on any gated surface fails.
MAX_REGRESS = 0.10


def _skip(path, why="earlier step failed") -> bool:
    if not os.path.exists(str(path)):
        print(f"{path} missing ({why}); skipping gate")
        return True
    return False


# ---------------------------------------------------------------------------
# the five gates extracted from .github/workflows/ci.yml inline blobs
# ---------------------------------------------------------------------------

def gate_afe(path) -> list:
    """DCAFE joins <= LC joins on every adoption surface — the paper's
    aggressive-finish-elimination claim carried onto production
    surfaces.  (bench_adoption asserts the same invariant while it
    runs; this re-checks the saved JSON independently.)"""
    if _skip(path):
        return []
    recs = load_records(path)
    joins = {(r["surface"], r["policy"]): r["joins"]
             for r in recs if "surface" in r}
    bad = []
    for surface in ("train_step", "checkpoint"):
        lc, dcafe = joins[(surface, "lc")], joins[(surface, "dcafe")]
        print(f"{surface}: dcafe={dcafe} lc={lc}")
        if dcafe > lc:
            bad.append(f"DCAFE joined more than LC on {surface} — "
                       "the aggressive-finish-elimination claim regressed")
    return bad


def gate_grain(path) -> list:
    """Adaptive-grain gates: uniform speedup, skew rebalance, spawn
    collapse, steals on skew — judged from the bootstrap-CI harness
    section when present (repeat distributions), with the structural
    counter checks re-derived from the records either way."""
    if _skip(path):
        return []
    env = load_envelope(path)
    recs = [r for r in env["records"] if r.get("arm") != "gates"]
    # every attempt is recorded; judge the one the bench judged
    last = max(r.get("attempt", 1) for r in recs)
    by = {(r["dist"], r["arm"]): r for r in recs
          if r.get("attempt", 1) == last}
    bad = _replay_harness(env, label="grain")
    if bad is None:  # pre-harness artifact: point-estimate fallback
        bad = []
        speedup = (by["uniform", "adaptive"]["items_per_s"]
                   / by["uniform", "grain1"]["items_per_s"])
        fraction = (by["skewed", "adaptive"]["items_per_s"]
                    / by["skewed", "grain1"]["items_per_s"])
        print(f"uniform adaptive/grain1 speedup: {speedup:.2f}x")
        print(f"skewed adaptive/grain1 fraction: {fraction:.2f}")
        if speedup < 3.0:
            bad.append(f"uniform speedup {speedup:.2f}x < 3x")
        if fraction < 0.9:
            bad.append(f"skewed fraction {fraction:.2f} < 0.9")
    print(f"uniform spawns/loop: adaptive "
          f"{by['uniform', 'adaptive']['spawns_per_loop']:.1f} vs "
          f"grain1 {by['uniform', 'grain1']['spawns_per_loop']:.1f}")
    if (by["uniform", "adaptive"]["spawns_per_loop"]
            >= by["uniform", "grain1"]["spawns_per_loop"]):
        bad.append("spawns did not collapse")
    if by["skewed", "adaptive"]["steals"] <= 0:
        bad.append("no steals on skew (rebalancing dead)")
    return bad


def gate_ep(path) -> list:
    """Expert-parallel dispatch: every EP round performs exactly ONE
    join (AFE), sent == received across the exchange, and the balanced
    router drops zero pairs at capacity_factor >= 1.0."""
    if _skip(path):
        return []
    env = load_envelope(path)
    recs = [r for r in env["records"] if r.get("arm") == "ep"]
    bad = []
    for r in recs:
        print(f"ep/{r['router']}: joins={r['joins']} "
              f"rounds={r['rounds']} sent={r['sent']} "
              f"received={r['received']} dropped={r['dropped']}")
        if r["joins"] != r["rounds"] or r["joins"] != 1:
            bad.append(f"{r['router']}: {r['joins']} joins over "
                       f"{r['rounds']} rounds (AFE regressed)")
        if r["sent"] != r["received"]:
            bad.append(f"{r['router']}: exchange lost pairs "
                       f"({r['sent']} sent, {r['received']} recv)")
        if (r["router"] == "balanced"
                and r["capacity_factor"] >= 1.0
                and r["dropped"] != 0):
            bad.append(f"balanced router dropped {r['dropped']} "
                       "pairs (exchange plan must reassign)")
    if not recs:
        bad.append("no ep records in artifact")
    replayed = _replay_harness(env, label="ep")
    if replayed:  # None = pre-harness artifact: counters above suffice
        bad.extend(replayed)
    return bad


def gate_trace(results_dir) -> list:
    """Replay every trace artifact through the exporter: trace-derived
    spawn/join/steal/split/complete counts must equal the embedded
    telemetry (conservation), and the tracer's measured overhead on the
    uniform grain loop must stay within its 5% budget."""
    from repro.obs import export as obs_export

    results_dir = Path(results_dir)
    paths = sorted(glob.glob(str(results_dir / "trace" / "*.trace.json")))
    if not paths:
        print("no trace artifacts (earlier step failed); skipping gate")
        return []
    bad = []
    for path in paths:
        doc = json.load(open(path))
        tel = doc.get("telemetry")
        if tel is None:
            bad.append(f"{path}: no embedded telemetry")
            continue
        check = obs_export.crosscheck(doc, tel)
        print(f"{os.path.basename(path)}: ok={check['ok']} "
              f"counts={check['trace']}")
        if not check["ok"]:
            bad.append(f"{path}: {check['mismatches']}")
    gpath = results_dir / "grain.json"
    if gpath.exists():
        gates = [r for r in load_records(gpath)
                 if r.get("arm") == "gates"][-1]
        frac = gates["trace_overhead_frac"]
        print(f"tracing overhead on uniform grain loop: {frac:.1%}")
        if frac > 0.05:
            bad.append(f"tracing overhead {frac:.1%} > 5% budget")
        mfrac = gates.get("metrics_overhead_frac")
        if mfrac is not None:  # pre-metrics artifacts lack the field
            print(f"always-on metrics overhead on uniform grain loop: "
                  f"{mfrac:.1%}")
            if mfrac > 0.05:
                bad.append(f"metrics overhead {mfrac:.1%} > 5% budget")
    return bad


def gate_slo(path) -> list:
    """SLO burn-rate lane from ``slo.json``: burn verdicts re-derived
    from the stored per-tenant bad-step counters (never trusted from the
    producer's incident counts), zero incidents on the clean and chunked
    arms, the stored exact/CI gates replayed, and every persisted
    incident file's embedded trace window re-crosschecked against its
    embedded telemetry delta — a tampered ``crosscheck.ok`` is caught by
    re-running the conservation check, not by reading it."""
    from repro.obs import export as obs_export

    if _skip(path):
        return []
    env = load_envelope(path)
    recs = [r for r in env["records"] if r.get("arm")]
    if not recs:
        return ["no slo records in artifact"]
    bad = []
    for r in recs:
        mon = r.get("monitor", {})
        steady = mon.get("tenants", {}).get("steady", {})
        allowed = steady.get("allowed_bad_steps",
                             mon.get("budget_frac", 0)
                             * mon.get("horizon", 0))
        bad_steps = r.get("bad_steps", 0)
        should_fire = allowed > 0 and bad_steps > allowed
        fired = r.get("slo_burn_incidents", 0) >= 1
        tag = f"{r['arm']}/rep{r.get('repeat')}"
        print(f"{tag}: bad_steps={bad_steps} allowed={allowed} "
              f"incidents={r.get('incidents')} fired={fired}")
        if should_fire != fired:
            bad.append(f"{tag}: re-derived burn verdict {should_fire} "
                       f"!= recorded incident count "
                       f"{r.get('slo_burn_incidents', 0)} "
                       "(burn accounting and firing disagree)")
        if r["arm"] in ("clean", "adv_chunked") and r.get("incidents", 0):
            bad.append(f"{tag}: {r['incidents']} incident(s) on a "
                       "no-burn arm (false positive)")
        if r["arm"] == "clean" and bad_steps:
            bad.append(f"{tag}: {bad_steps} bad steps with no adversary")
        if r.get("incident_crosscheck_failures", 0):
            bad.append(f"{tag}: {r['incident_crosscheck_failures']} "
                       "incident(s) failed their embedded crosscheck")
    replayed = _replay_harness(env, label="slo")
    if replayed is None:
        bad.append("no harness section — bench_slo did not emit gates")
    else:
        bad.extend(replayed)
    # re-run the conservation crosscheck inside every persisted incident
    inc_dir = Path(path).parent / "incidents"
    inc_paths = sorted(glob.glob(str(inc_dir / "incident-*.json")))
    for ipath in inc_paths:
        doc = json.load(open(ipath))
        trace, window = doc.get("trace"), doc.get("telemetry_window")
        if trace is None or window is None:
            print(f"{os.path.basename(ipath)}: no embedded trace window "
                  f"(trigger={doc.get('trigger')}); skipping")
            continue
        check = obs_export.crosscheck(trace, window)
        stored = doc.get("crosscheck", {}).get("ok")
        print(f"{os.path.basename(ipath)}: trigger={doc.get('trigger')} "
              f"crosscheck ok={check['ok']}")
        if not check["ok"]:
            bad.append(f"{ipath}: incident window fails conservation "
                       f"({check['mismatches']})")
        if stored is not None and bool(stored) != bool(check["ok"]):
            bad.append(f"{ipath}: stored crosscheck {stored} != replayed "
                       f"{check['ok']} (artifact lied)")
    if not inc_paths:
        print(f"no persisted incidents under {inc_dir} (earlier step "
              "failed or produced none)")
    return bad


def gate_tenants(path) -> list:
    """Tenant telemetry conservation — per-tenant spawn/join counters
    must sum to the globals and every admitted request must have
    completed — plus the bootstrap-CI isolation gates when the harness
    section is present."""
    if _skip(path):
        return []
    env = load_envelope(path)
    bad = []
    for rec in env["records"]:
        sched = rec.get("sched")
        if sched is None:
            continue
        tenants = sched.get("tenants")
        if not tenants:  # the anonymous-fifo scenario has none
            continue
        s = sum(t["spawns"] for t in tenants.values())
        j = sum(t["joins"] for t in tenants.values())
        print(f"{rec['scenario']}: per-tenant spawns={s} joins={j} "
              f"global spawns={sched['spawns']} joins={sched['joins']}")
        if s != sched["spawns"] or j != sched["joins"]:
            bad.append(f"{rec['scenario']}: per-tenant != global")
        if sched["spawns"] != sched["joins"]:
            bad.append(f"{rec['scenario']}: spawns != joins")
    replayed = _replay_harness(env, label="tenants")
    if replayed:
        bad.extend(replayed)
    return bad


def gate_serve(path) -> list:
    """Serving SLO surfaces from ``batcher.json``: telemetry joins must
    count completed REQUESTS (never prefill chunks — the AFE contract),
    chunked prefill must actually have run when prefill work existed,
    and the stored harness gates (chunked==whole max |Δ| == 0.0, DLBC
    p99 <= LC, decode-cost cap) replay from the raw samples."""
    if _skip(path):
        return []
    env = load_envelope(path)
    bad = []
    for rec in env["records"]:
        sched = rec.get("sched")
        if sched is None:
            continue
        tag = f"{rec.get('policy')}/rep{rec.get('repeat')}"
        print(f"{tag}: spawns={sched['spawns']} joins={sched['joins']} "
              f"done={rec['n_done']} prefill_chunks="
              f"{sched.get('prefill_chunks')} prefill_tokens="
              f"{sched.get('prefill_tokens')}")
        if not (sched["spawns"] == sched["joins"] == rec["n_done"]):
            bad.append(f"{tag}: joins != completed requests "
                       "(AFE regressed: chunks are being joined, or "
                       "requests leaked)")
        if "truncated" in rec and rec["truncated"] is None:
            bad.append(f"{tag}: truncated not recorded")
        if "truncated" not in rec:
            bad.append(f"{tag}: no truncated counter in record")
        if (sched.get("prefill_tokens", 0) > 0
                and sched.get("prefill_chunks", 0) < 1):
            bad.append(f"{tag}: prefill tokens written without chunks "
                       "(counter conservation broken)")
        if (sched.get("prefill_chunks", 0) > 0
                and sched.get("prefill_tokens", 0)
                < sched.get("prefill_chunks", 0)):
            bad.append(f"{tag}: fewer prefill tokens than chunks")
    if not env["records"]:
        bad.append("no serving records in artifact")
    replayed = _replay_harness(env, label="serve")
    if replayed is None:
        bad.append("no harness section — bench_batcher did not emit "
                   "distribution gates")
    else:
        bad.extend(replayed)
    return bad


def gate_faults(path) -> list:
    """Chaos lane from ``faults.json``: zero exceptions lost under
    injection (``injected == telemetry errors == collected-in-
    MultipleExceptions``, re-derived from the raw per-arm counters, both
    fail modes), item/task conservation on every arm including worker
    death, and the stored bootstrap-CI p99-under-faults verdict replayed
    from the samples."""
    if _skip(path):
        return []
    env = load_envelope(path)
    recs = [r for r in env["records"] if r.get("arm") not in (None, "gates")]
    if not recs:
        return ["no chaos records in artifact"]
    last = max(r.get("attempt", 1) for r in recs)
    by = {r["arm"]: r for r in recs if r.get("attempt", 1) == last}
    bad = []
    for arm, r in sorted(by.items()):
        print(f"{arm}: injected={r['injected']} collected={r['collected']} "
              f"errors={r['errors']} deaths={r['worker_deaths']} "
              f"exceptions_lost={r['exceptions_lost']} unaccounted="
              f"{r['items_unaccounted'] + r['tasks_unaccounted']}")
        if r["exceptions_lost"]:
            bad.append(f"{arm}: {r['exceptions_lost']} exception "
                       "count deviations across repeats (an injected "
                       "fault was swallowed or double-counted)")
        if r["items_unaccounted"] or r["tasks_unaccounted"]:
            bad.append(f"{arm}: items/tasks unaccounted under chaos "
                       f"({r['items_unaccounted']} items, "
                       f"{r['tasks_unaccounted']} tasks)")
    # totals re-derived from the artifact, not trusted per-repeat fields:
    # every raised fault must surface as an error AND reach the join
    for arm in ("faulted_rtc", "faulted_ff"):
        r = by.get(arm)
        if r is None:
            bad.append(f"no {arm} arm in artifact")
            continue
        if not (r["injected"] == r["errors"] == r["collected"]):
            bad.append(f"{arm}: injected {r['injected']} != errors "
                       f"{r['errors']} != collected {r['collected']} "
                       "(raised != injected)")
        if r["injected"] < 1:
            bad.append(f"{arm}: chaos lane ran fault-free")
    wd = by.get("worker_death")
    if wd is None:
        bad.append("no worker_death arm in artifact")
    elif wd["worker_deaths"] < 1 or wd["deaths_unaccounted"]:
        bad.append(f"worker deaths not conserved against injections "
                   f"({wd['worker_deaths']} deaths, "
                   f"{wd['deaths_unaccounted']} unaccounted)")
    replayed = _replay_harness(env, label="faults")
    if replayed is None:
        bad.append("no harness section — bench_faults did not emit "
                   "distribution gates")
    else:
        bad.extend(replayed)
    return bad


# ---------------------------------------------------------------------------
# distribution gates (harness section replay)
# ---------------------------------------------------------------------------

def _replay_harness(env: dict, label: str = "dist"):
    """Re-evaluate every stored harness gate from its raw samples.
    Returns None when the artifact has no harness section (pre-harness
    producer), else the list of failures."""
    harness = env.get("harness")
    if not harness:
        return None
    bad = []
    for gate in harness.get("gates", []):
        res = replay_gate(gate, harness.get("arms", {}))
        lo, hi = res["ci"]
        print(f"{label}/{res['gate']}: value={res['value']:.4g} "
              f"ci=[{lo:.4g}, {hi:.4g}] {res['op']} {res['threshold']} "
              f"-> {'ok' if res['ok'] else 'FAIL'}")
        if not res["ok"]:
            bad.append(f"{res['gate']}: ci=[{lo:.4g}, {hi:.4g}] "
                       f"excludes {res['op']} {res['threshold']}")
        if bool(res["ok"]) != bool(gate.get("ok", res["ok"])):
            bad.append(f"{res['gate']}: stored verdict "
                       f"{gate.get('ok')} != replayed {res['ok']} "
                       "(artifact lied)")
    return bad


def gate_dist(path) -> list:
    """Replay the declarative distribution gates of any harness-emitting
    bench artifact (bootstrap CIs recomputed from the stored samples)."""
    if _skip(path):
        return []
    env = load_envelope(path)
    bad = _replay_harness(env, label=env.get("bench", "dist"))
    if bad is None:
        return [f"{path}: no harness section — bench did not emit "
                "distribution gates"]
    return bad


# ---------------------------------------------------------------------------
# cross-PR trajectory
# ---------------------------------------------------------------------------

def collect_trajectory(results_dir) -> dict:
    """Gather every gated metric from a results directory into one
    diffable document: ``{surface -> {value, better, ci?}}``."""
    results_dir = Path(results_dir)
    surfaces, commit = {}, "unknown"
    for path in sorted(results_dir.glob("*.json")):
        if path.name == "trajectory.json":
            continue
        try:
            env = load_envelope(path)
        except (json.JSONDecodeError, OSError):
            continue
        if env.get("schema_version") != SCHEMA_VERSION:
            print(f"[trajectory] {path.name}: schema_version "
                  f"{env.get('schema_version')} != {SCHEMA_VERSION}; "
                  "not collected")
            continue
        if env.get("commit", "unknown") != "unknown":
            commit = env["commit"]
        for metric, rec in (env.get("harness") or {}).get(
                "trajectory", {}).items():
            surfaces[f"{env['bench']}/{metric}"] = rec
    return {"schema_version": SCHEMA_VERSION, "commit": commit,
            "surfaces": surfaces}


def diff_trajectory(current: dict, previous: dict,
                    max_regress: float = MAX_REGRESS) -> list:
    """Fail on >``max_regress`` regression on any gated surface.

    Direction-aware (``better: lower|higher``).  When the current
    metric carries a bootstrap CI, the *conservative edge* is compared
    (CI low for lower-better): a regression must be outside the
    current run's own noise band to fail, matching the gate semantics.
    Schema mismatches refuse to compare (reported, not failed).
    """
    if previous.get("schema_version") != current.get("schema_version"):
        print(f"[trajectory] previous schema_version "
              f"{previous.get('schema_version')} != current "
              f"{current.get('schema_version')}; refusing to compare "
              "(baseline resets this run)")
        return []
    bad = []
    prev_surfaces = previous.get("surfaces", {})
    for name, cur in sorted(current.get("surfaces", {}).items()):
        prev = prev_surfaces.get(name)
        if prev is None:
            print(f"[trajectory] {name}: new surface "
                  f"(value={cur['value']:.4g})")
            continue
        better = cur.get("better", "lower")
        value = cur["value"]
        edge = value
        if cur.get("ci"):
            edge = cur["ci"][0] if better == "lower" else cur["ci"][1]
        pv = prev["value"]
        if better == "lower":
            regressed = pv > 0 and edge > pv * (1 + max_regress)
        else:
            regressed = pv > 0 and edge < pv * (1 - max_regress)
        delta = (value - pv) / pv if pv else 0.0
        print(f"[trajectory] {name}: {pv:.4g} -> {value:.4g} "
              f"({delta:+.1%}, better={better})"
              f"{' REGRESSED' if regressed else ''}")
        if regressed:
            bad.append(f"{name}: {pv:.4g} -> {value:.4g} ({delta:+.1%} "
                       f"beyond the {max_regress:.0%} budget, "
                       f"better={better})")
    dropped = sorted(set(prev_surfaces) - set(current.get("surfaces", {})))
    for name in dropped:
        print(f"[trajectory] {name}: no longer reported")
    return bad


def cmd_trajectory(args) -> list:
    current = collect_trajectory(args.artifact)
    if not current["surfaces"]:
        print("no gated surfaces collected; skipping trajectory gate")
        return []
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(current, indent=1))
        print(f"[trajectory saved {args.out}: "
              f"{len(current['surfaces'])} surfaces @ "
              f"{current['commit'][:12]}]")
    if not args.prev or not os.path.exists(args.prev):
        print("no previous trajectory (first run on this branch); "
              "baseline established")
        return []
    previous = json.loads(Path(args.prev).read_text())
    return diff_trajectory(current, previous, args.max_regress)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

GATES = {
    "afe": gate_afe,
    "grain": gate_grain,
    "ep": gate_ep,
    "trace": gate_trace,
    "tenants": gate_tenants,
    "serve": gate_serve,
    "faults": gate_faults,
    "slo": gate_slo,
    "dist": gate_dist,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.gates",
        description="replay a named CI gate against a saved artifact")
    ap.add_argument("gate", choices=sorted(GATES) + ["trajectory"])
    ap.add_argument("artifact",
                    help="artifact JSON path (or results dir for "
                         "trace/trajectory)")
    ap.add_argument("--prev", default=None,
                    help="[trajectory] previous trajectory.json to diff")
    ap.add_argument("--out", default=None,
                    help="[trajectory] where to write this run's "
                         "trajectory.json")
    ap.add_argument("--max-regress", type=float, default=MAX_REGRESS,
                    help="[trajectory] relative p99 regression budget")
    args = ap.parse_args(argv)
    if args.gate == "trajectory":
        bad = cmd_trajectory(args)
    else:
        bad = GATES[args.gate](args.artifact)
    if bad:
        print(f"GATE {args.gate} FAILED:", file=sys.stderr)
        for b in bad:
            print(f"  - {b}", file=sys.stderr)
        return 1
    print(f"GATE {args.gate} ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
