"""Paper Fig. 10 — dynamic #finish / #async per kernel × scheme.

Reproduces the benchmark-statistics table (scaled inputs; the paper's
count *algebra* — which kernels collapse to 1 finish, which stay flat —
is the claim under test)."""

from __future__ import annotations

from repro.core import build_kernel, run_scheme

from .common import report

KERNELS = ["BFS", "BY", "DR", "DST", "MST", "NQ", "HL", "FL"]
SCHEMES = ["UnOpt", "LC", "DCAFE"]


def run(scale: str = "bench", workers: int = 8):
    rows = []
    records = []
    for kernel in KERNELS:
        k = build_kernel(kernel, scale)
        for scheme in SCHEMES:
            r = run_scheme(k, scheme, workers=workers)
            rows.append([kernel, scheme, r.finishes, r.asyncs,
                         "ok" if r.ok else "FAIL"])
            records.append(r.row())
    report(f"Fig. 10: dynamic task/finish counts "
           f"(workers={workers}, scale={scale})",
           rows, ["kernel", "scheme", "#finish", "#async", "correct"],
           "fig10_counts", records)
    # headline assertions (paper: NQ/BFS collapse to 1 finish under DCAFE)
    by = {(r["kernel"], r["scheme"]): r for r in records}
    assert by[("NQ", "DCAFE")]["finishes"] == 1
    assert by[("BFS", "DCAFE")]["finishes"] == 1
    assert by[("FL", "DCAFE")]["asyncs"] < by[("FL", "LC")]["asyncs"]
    return records


if __name__ == "__main__":
    run()
