"""Adoption-surface policy ladder (ROADMAP "adopt repro.sched").

Runs the three newest `repro.sched` consumers — train-step scheduling,
checkpoint shard-write I/O, and MoE token dispatch — across the policy
ladder and emits Fig. 10-comparable spawn/join counts plus p50/p99
latencies per surface.  The headline regression gate (asserted by CI from
the saved JSON): DCAFE performs **no more joins than LC** on every
surface where both run — the paper's aggressive-finish-elimination claim
carried onto production surfaces.
"""

from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import moe as MOE
from repro.sched import SchedTelemetry
from repro.train.train_step import StepConfig
from repro.train.trainer import TrainerConfig, run_training

from .common import report

POLICIES = ("serial", "lc", "dlbc", "dcafe")


def _row(surface, policy, s):
    return [surface, policy, s["spawns"], s["joins"],
            f"{s['p50_ms']:.2f}", f"{s['p99_ms']:.2f}"]


def _role(policy):
    """The serial arm is each surface's oracle (the baseline DCAFE/DLBC
    must match on counts and beat on joins)."""
    return "oracle" if policy == "serial" else "candidate"


def bench_train_step(records, rows, steps: int = 2):
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    shape = ShapeConfig("bench", 64, 8, "train", microbatches=4)
    for policy in POLICIES:
        d = tempfile.mkdtemp()
        try:
            rep = run_training(
                cfg, shape,
                TrainerConfig(steps=steps, ckpt_every=100, ckpt_dir=d),
                StepConfig(policy="afe_bucket", sched_policy=policy,
                           q_chunk=64, k_chunk=64, ssm_chunk=32),
                eval_loss_hook=False)
        finally:
            shutil.rmtree(d, ignore_errors=True)
        s = rep.sched["train_step"]  # already carries policy=<name>
        rows.append(_row("train_step", policy, s))
        records.append(dict(surface="train_step", role=_role(policy), **s))


def bench_checkpoint(records, rows, n_saves: int = 3):
    tree = {f"layer_{i}": {"w": jnp.ones((64, 64)) * i,
                           "b": jnp.zeros((64,))}
            for i in range(16)}
    for policy in POLICIES:
        d = tempfile.mkdtemp()
        try:
            mgr = CheckpointManager(d, keep=2, sched_policy=policy)
            t0 = time.perf_counter()
            for s in range(n_saves):
                mgr.save(s + 1, tree, blocking=False)
            mgr.wait()
            wall = time.perf_counter() - t0
            summary = mgr.telemetry.summary()
            mgr.close()
        finally:
            shutil.rmtree(d, ignore_errors=True)
        rows.append(_row("checkpoint", policy, summary))
        records.append(dict(surface="checkpoint", policy=policy,
                            role=_role(policy),
                            wall_s=wall, n_saves=n_saves, **summary))


def bench_moe(records, rows, T: int = 512, repeats: int = 3, seed: int = 0):
    import dataclasses

    from .bench_moe_dispatch import skewed_tokens

    cfg0 = get_config("mixtral-8x7b", smoke=True)
    p = MOE.moe_init(jax.random.PRNGKey(seed), cfg0, jnp.float32)
    # clustered tokens: the load skew where static chunking drops tokens
    x = skewed_tokens(jax.random.PRNGKey(seed + 1), T, cfg0.d_model, 4, 0.05)
    for dispatch in ("lc", "dlbc"):
        cfg = dataclasses.replace(cfg0, moe_dispatch=dispatch,
                                  moe_capacity_factor=1.0)
        tel = SchedTelemetry()
        apply = jax.jit(
            lambda px, xx: MOE.moe_apply(px, cfg, xx, return_stats=True))
        y, stats = apply(p, x)  # compile
        jax.block_until_ready(y)
        for _ in range(repeats):
            t0 = time.perf_counter()
            y, stats = apply(p, x)
            jax.block_until_ready(y)
            tel.record_latency(time.perf_counter() - t0)
        tel.spawns = int(stats["spawns"])
        tel.joins = int(stats["joins"])
        s = tel.summary()
        rows.append(_row(f"moe_dispatch(drop={float(stats['dropped_frac']):.3f})",
                         dispatch, s))
        records.append(dict(surface="moe_dispatch", policy=dispatch,
                            # LC is the static baseline this surface is
                            # judged against (no serial arm on device)
                            role="oracle" if dispatch == "lc"
                            else "candidate",
                            dropped_frac=float(stats["dropped_frac"]), **s))


def run(seed: int = 0, repeats: int = 3):
    rows, records = [], []
    bench_train_step(records, rows)
    bench_checkpoint(records, rows)
    bench_moe(records, rows, repeats=max(repeats or 3, 3), seed=seed)
    out = report(
        "repro.sched adoption surfaces: spawn/join/latency per policy",
        rows, ["surface", "policy", "spawns", "joins", "p50_ms", "p99_ms"],
        "adoption", records)
    # The AFE claim on production surfaces: DCAFE never joins more than LC.
    joins = {(r["surface"], r["policy"]): r["joins"] for r in records}
    for surface in ("train_step", "checkpoint"):
        lc, dcafe = joins[(surface, "lc")], joins[(surface, "dcafe")]
        ok = dcafe <= lc
        print(f"{surface}: DCAFE joins ({dcafe}) <= LC joins ({lc}): {ok}")
        assert ok, (surface, dcafe, lc)
    return out


if __name__ == "__main__":
    run()
