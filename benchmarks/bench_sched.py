"""Policy comparison on the host thread pool: Serial vs LC vs DLBC vs
DLBC+stealing under uniform and skewed item costs, plus the DCAFE
finish-scope join-count win.

LC spawns ``n_workers`` static chunks and the caller only joins; DLBC
reads the idle count, keeps the smallest chunk on the caller (so
``idle + 1`` workers execute), and re-probes in the serial fallback —
so DLBC throughput must be ≥ LC, with the gap widening when item costs
are skewed and a static split leaves workers idle.
"""

from __future__ import annotations

import time

from repro.obs import trace as obs
from repro.sched import ThreadExecutor, WorkStealingExecutor

from .common import dist_stats, report, write_trace


def _sleep_work(ms: float):
    # time.sleep releases the GIL → real host-thread parallelism
    time.sleep(ms / 1e3)


def make_costs(n: int, dist: str):
    """Per-item cost in ms.  'skewed': a heavy head (10×) — the worst case
    for contiguous static chunks, which hand one worker the whole hump."""
    if dist == "uniform":
        return [2.0] * n
    assert dist == "skewed"
    return [20.0 if i < n // 8 else 1.0 for i in range(n)]


def _run_once(policy: str, costs, workers: int):
    cls = WorkStealingExecutor if policy == "dlbc-steal" else ThreadExecutor
    pol = "dlbc" if policy == "dlbc-steal" else policy
    ex = cls(n_workers=workers)
    try:
        t0 = time.perf_counter()
        ex.run_loop(costs, _sleep_work, policy=pol)
        dt = time.perf_counter() - t0
        return dt, ex.telemetry
    finally:
        ex.shutdown()


def run(n_items: int = 64, workers: int = 4, repeats: int = 3):
    rows, records = [], []
    best = {}
    for dist in ("uniform", "skewed"):
        costs = make_costs(n_items, dist)
        for policy in ("serial", "lc", "dlbc", "dlbc-steal"):
            runs = [_run_once(policy, costs, workers) for _ in range(repeats)]
            dt, tel = min(runs, key=lambda r: r[0])
            thr = n_items / dt
            best[(dist, policy)] = thr
            s = tel.summary()
            rows.append([dist, policy, f"{dt * 1e3:.1f}", f"{thr:.0f}",
                         s["spawns"], s["joins"], s["serial_items"],
                         s["steals"], f"{s['p50_ms']:.2f}",
                         f"{s['p99_ms']:.2f}"])
            records.append(dict(dist=dist, policy=policy, wall_s=dt,
                                items_per_s=thr,
                                wall_dist=dist_stats([r[0] for r in runs]),
                                **s))

    # DCAFE: many loops, one escaped join (host-side finish elimination)
    ex = ThreadExecutor(n_workers=workers)
    try:
        costs = make_costs(n_items // 4, "uniform")
        t0 = time.perf_counter()
        with ex.finish() as scope:
            for _ in range(4):
                ex.run_loop(costs, _sleep_work, policy="dcafe", scope=scope)
        dt = time.perf_counter() - t0
        s = ex.telemetry.summary()
        rows.append(["4 loops", "dcafe", f"{dt * 1e3:.1f}",
                     f"{n_items / dt:.0f}", s["spawns"], s["joins"],
                     s["serial_items"], s["steals"], f"{s['p50_ms']:.2f}",
                     f"{s['p99_ms']:.2f}"])
        records.append(dict(dist="4loops", policy="dcafe", wall_s=dt,
                            items_per_s=n_items / dt, **s))
    finally:
        ex.shutdown()

    # Traced pass: one skewed stealing run with the obs tracer on, so the
    # artifact CI replays through the exporter covers the richest event
    # mix (spawn/steal/split/park/join) — conservation checked inline.
    obs.clear()
    obs.enable()
    try:
        _, tel = _run_once("dlbc-steal", make_costs(n_items, "skewed"),
                           workers)
        write_trace("sched", tel.summary())
    finally:
        obs.disable()

    out = report(
        f"Host-pool policy comparison ({n_items} items, {workers} workers, "
        f"best of {repeats})",
        rows,
        ["items", "policy", "wall_ms", "items/s", "spawns", "joins",
         "serial", "steals", "p50_ms", "p99_ms"],
        "sched", records)
    ok = best[("skewed", "dlbc")] >= best[("skewed", "lc")]
    print(f"DLBC >= LC under skewed costs: {ok} "
          f"({best[('skewed', 'dlbc')]:.0f} vs {best[('skewed', 'lc')]:.0f} "
          f"items/s)")
    return out


if __name__ == "__main__":
    run()
