"""Policy comparison on the host thread pool: Serial vs LC vs DLBC vs
DLBC+stealing under uniform and skewed item costs, plus the DCAFE
finish-scope join-count win.

LC spawns ``n_workers`` static chunks and the caller only joins; DLBC
reads the idle count, keeps the smallest chunk on the caller (so
``idle + 1`` workers execute), and re-probes in the serial fallback —
so DLBC throughput must be ≥ LC, with the gap widening when item costs
are skewed and a static split leaves workers idle.

Harness shape (oracle-first, distribution-gated): the *serial* arm is
the oracle per workload; every parallel arm is checked for
result-equivalence against it (same multiset of items executed — a
fast arm that drops work fails loudly), every arm runs ``repeats``
seeded repeats emitting its full wall-time distribution, and the gates
are bootstrap-CI verdicts over those repeats, replayed independently by
``python -m benchmarks.gates dist sched.json`` in CI.
"""

from __future__ import annotations

import time

from repro.obs import trace as obs
from repro.sched import ThreadExecutor, WorkStealingExecutor

from .common import report, write_trace
from .harness import Bench

POLICIES = ("serial", "lc", "dlbc", "dlbc-steal")
#: bootstrap-CI gate thresholds (fail only when the CI excludes them).
#: skewed is lower: without stealing the 10x heavy head strands on one
#: static chunk (the stranded-head behavior the grain bench fixes), so
#: the parallel win there is bounded by the head, not the worker count.
PARALLEL_SPEEDUP_MIN = {"uniform": 1.5, "skewed": 1.1}
SKEW_DLBC_VS_LC_MIN = 1.0    # the paper's DLBC >= LC claim, CI-judged
TAIL_RATIO_MAX = 3.0         # repeat wall p99/p50 stays a bounded tail


def _sleep_work(ms: float):
    # time.sleep releases the GIL → real host-thread parallelism
    time.sleep(ms / 1e3)


def make_costs(n: int, dist: str):
    """Per-item cost in ms.  'skewed': a heavy head (10×) — the worst case
    for contiguous static chunks, which hand one worker the whole hump."""
    if dist == "uniform":
        return [2.0] * n
    assert dist == "skewed"
    return [20.0 if i < n // 8 else 1.0 for i in range(n)]


def _run_once(policy: str, costs, workers: int):
    cls = WorkStealingExecutor if policy == "dlbc-steal" else ThreadExecutor
    pol = "dlbc" if policy == "dlbc-steal" else policy
    ex = cls(n_workers=workers)
    done = []  # GIL-atomic append: which items actually executed

    def work(ms):
        _sleep_work(ms)
        done.append(ms)

    try:
        t0 = time.perf_counter()
        ex.run_loop(costs, work, policy=pol)
        dt = time.perf_counter() - t0
        return dt, ex.telemetry, sorted(done)
    finally:
        ex.shutdown()


def run(n_items: int = 64, workers: int = 4, repeats: int = 5,
        seed: int = 0):
    bench = Bench("sched", seed=seed, repeats=max(repeats, 5))
    rows, records = [], []
    for dist in ("uniform", "skewed"):
        costs = make_costs(n_items, dist)
        for policy in POLICIES:
            runs = []

            def once(rep):
                dt, tel, done = _run_once(policy, costs, workers)
                runs.append((dt, tel))
                return done  # the result-equivalence payload

            oracle = policy == "serial"
            bench.measure(f"{dist}/{policy}", once, oracle=oracle,
                          equiv_to=None if oracle else f"{dist}/serial")
            # judge throughput on the arm's own wall clock, not the
            # harness wrapper (executor construction is outside `runs`)
            dt, tel = min(runs, key=lambda r: r[0])
            thr = n_items / dt
            s = tel.summary()
            arm = bench.arms[f"{dist}/{policy}"]
            rows.append([dist, policy, f"{dt * 1e3:.1f}", f"{thr:.0f}",
                         s["spawns"], s["joins"], s["serial_items"],
                         s["steals"], f"{s['p50_ms']:.2f}",
                         f"{s['p99_ms']:.2f}"])
            records.append(dict(dist=dist, policy=policy, wall_s=dt,
                                items_per_s=thr,
                                role=arm["role"],
                                wall_dist=arm["dist"],
                                **s))

    # -- distribution gates (replayed from the artifact by CI) ----------
    for dist in ("uniform", "skewed"):
        bench.gate_speedup(f"{dist}/dlbc-steal", f"{dist}/serial",
                           PARALLEL_SPEEDUP_MIN[dist],
                           name=f"{dist}.steal_vs_oracle")
        bench.gate_speedup(f"{dist}/dlbc", f"{dist}/serial",
                           PARALLEL_SPEEDUP_MIN[dist],
                           name=f"{dist}.dlbc_vs_oracle")
    # the paper's core ordering, now a CI-judged distribution claim:
    # wall(lc)/wall(dlbc) >= 1 under skew unless the whole CI disagrees
    bench.gate_ratio("skewed.dlbc_vs_lc", "skewed/lc", "skewed/dlbc",
                     ">=", SKEW_DLBC_VS_LC_MIN)
    bench.gate_tail_ratio("uniform/dlbc", TAIL_RATIO_MAX)
    bench.gate_tail_ratio("skewed/dlbc-steal", TAIL_RATIO_MAX)

    # DCAFE: many loops, one escaped join (host-side finish elimination)
    ex = ThreadExecutor(n_workers=workers)
    try:
        costs = make_costs(n_items // 4, "uniform")
        t0 = time.perf_counter()
        with ex.finish() as scope:
            for _ in range(4):
                ex.run_loop(costs, _sleep_work, policy="dcafe", scope=scope)
        dt = time.perf_counter() - t0
        s = ex.telemetry.summary()
        rows.append(["4 loops", "dcafe", f"{dt * 1e3:.1f}",
                     f"{n_items / dt:.0f}", s["spawns"], s["joins"],
                     s["serial_items"], s["steals"], f"{s['p50_ms']:.2f}",
                     f"{s['p99_ms']:.2f}"])
        records.append(dict(dist="4loops", policy="dcafe", wall_s=dt,
                            items_per_s=n_items / dt, **s))
        # finish elimination is count arithmetic, not timing: exact gate
        bench.gate_exact("dcafe.one_join", s["joins"], "<=", 1)
    finally:
        ex.shutdown()

    # Traced pass: one skewed stealing run with the obs tracer on, so the
    # artifact CI replays through the exporter covers the richest event
    # mix (spawn/steal/split/park/join) — conservation checked inline.
    obs.clear()
    obs.enable()
    try:
        _, tel, _ = _run_once("dlbc-steal", make_costs(n_items, "skewed"),
                              workers)
        write_trace("sched", tel.summary())
    finally:
        obs.disable()

    out = report(
        f"Host-pool policy comparison ({n_items} items, {workers} workers, "
        f"{bench.repeats} repeats, seed {seed})",
        rows,
        ["items", "policy", "wall_ms", "items/s", "spawns", "joins",
         "serial", "steals", "p50_ms", "p99_ms"],
        "sched", records, harness=bench.payload())
    for g in bench.gates:
        print(f"gate {g['gate']}: value={g['value']:.3g} "
              f"ci=[{g['ci'][0]:.3g}, {g['ci'][1]:.3g}] "
              f"{g['op']} {g['threshold']} -> "
              f"{'ok' if g['ok'] else 'FAIL'}")
    bench.check()
    return out


if __name__ == "__main__":
    run()
