"""Serving: DLBC continuous batching vs LC fixed batching — latency,
utilisation, and the chunked-prefill SLO surface, routed through the
oracle-first harness (seeded repeats, bootstrap-CI gates, trajectory).

Arms (per-repeat samples = end-to-end p99 latency in steps):

* ``lc``    — fixed batching (oracle/reference arm: the static-chunking
  baseline the paper's DLBC story is measured against);
* ``dlbc``  — continuous batching with DLBC-chunked prefill;
* ``dlbc/decode_cost`` — per-token decode cost p99 (token units: 1 +
  the largest prefill chunk sharing the step), the surface the
  long-prompt-adversary gate in ``bench_tenants`` leans on.

Exact gates (no sampling noise, no CI slack):

* chunked prefill == whole-prompt prefill, max |Δ| == 0.0 per repeat
  (the correctness oracle for the prefill-replay bugfix);
* telemetry joins == completed requests on every run (AFE: prefill
  chunks are never joined individually);
* every per-token decode cost ≤ 1 + prefill_chunk (a chunk cap that
  holds structurally is what makes the SLO bound non-vacuous).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MDL
from repro.serve.batcher import ContinuousBatcher, Request

from .common import report
from .harness import Bench

PREFILL_CHUNK = 8
CACHE_LEN = 64


def _make_requests(n_requests, vocab, seed):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=list(rng.integers(0, vocab,
                                             size=int(rng.integers(2, 17)))),
                    max_new=int(rng.integers(3, 28)),
                    arrive_step=int(rng.integers(0, 30)))
            for i in range(n_requests)]


def _prefill_equivalence_delta(cfg, params, seed) -> float:
    """Oracle check: decode logits after chunked prefill (sizes 1, 8)
    vs whole-prompt prefill — returns max |Δ| (must be exactly 0.0)."""
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab, size=12).tolist()
    pre = len(prompt) - 1
    buf = 16

    def fill(sizes):
        cache = MDL.init_cache(cfg, 1, 32)
        pos = 0
        for s in sizes:
            toks = np.zeros((1, buf), np.int32)
            toks[0, :s] = prompt[pos:pos + s]
            _, cache = MDL.prefill_step(
                params, cfg, cache,
                {"tokens": jnp.asarray(toks),
                 "cache_index": jnp.asarray([pos], jnp.int32),
                 "count": jnp.asarray([s], jnp.int32)})
            pos += s
        logits, _ = MDL.decode_step(
            params, cfg, cache,
            {"tokens": jnp.asarray([[prompt[-1]]], jnp.int32),
             "cache_index": jnp.asarray([pre], jnp.int32)})
        return np.asarray(logits)

    ref = fill([pre])
    delta = 0.0
    for sizes in ([1] * pre, [8, pre - 8]):
        delta = max(delta, float(np.abs(ref - fill(sizes)).max()))
    return delta


def run(n_requests: int = 32, slots: int = 4, seed: int = 0,
        repeats: int = 5):
    repeats = max(int(repeats or 5), 5)
    cfg = ModelConfig(name="bench-serve", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=1024)
    params = MDL.init_params(cfg, jax.random.PRNGKey(seed))

    bench = Bench("batcher", seed=seed, repeats=repeats)
    p99s = {"lc": [], "dlbc": []}
    cost_p99s = []
    records = []
    max_delta = 0.0
    joins_mismatch = 0
    worst_cost = 0
    for rep in range(repeats):
        for policy in ("lc", "dlbc"):
            b = ContinuousBatcher(cfg, params, n_slots=slots,
                                  cache_len=CACHE_LEN, policy=policy,
                                  prefill_chunk=PREFILL_CHUNK)
            st = b.run(_make_requests(n_requests, cfg.vocab, seed + rep))
            sched = b.sched.telemetry.summary()
            # AFE: joins count REQUESTS — chunked prefill must not add
            # joins, and every admitted request must complete
            joins_mismatch += abs(sched["joins"] - len(st.latencies))
            joins_mismatch += abs(sched["spawns"] - sched["joins"])
            p99s[policy].append(st.p99_latency)
            if policy == "dlbc":
                cost_p99s.append(st.p99_decode_cost)
                worst_cost = max(worst_cost,
                                 max(st.decode_step_costs, default=0))
            records.append(dict(
                policy=policy, repeat=rep, steps=st.steps,
                utilization=st.utilization,
                mean_latency=float(np.mean(st.latencies)),
                p99_latency=st.p99_latency,
                p99_decode_cost=st.p99_decode_cost,
                n_done=len(st.latencies), truncated=st.truncated,
                vtime=b.vtime, sched=sched))
        max_delta = max(max_delta,
                        _prefill_equivalence_delta(cfg, params, seed + rep))

    bench.add_samples("lc", p99s["lc"], oracle=True, unit="steps")
    bench.add_samples("dlbc", p99s["dlbc"], unit="steps")
    bench.add_samples("dlbc/decode_cost", cost_p99s, unit="tokens")
    # continuous batching must not lose to fixed batching on tail latency
    bench.gate_ratio("dlbc_vs_lc_p99", "dlbc", "lc", "<=", 1.0, p=50)
    # the prefill-replay bugfix's correctness oracle: exact, every repeat
    bench.gate_exact("prefill_chunked_vs_whole_max_abs_delta",
                     max_delta, "<=", 0.0)
    bench.gate_exact("joins_eq_completed_requests", joins_mismatch, "<=", 0)
    # the chunk cap holds structurally: no decoded token ever paid more
    # than one decode + one full prefill chunk
    bench.gate_exact("decode_cost_le_one_plus_chunk",
                     worst_cost, "<=", 1 + PREFILL_CHUNK)
    bench.check()

    rows = []
    for policy in ("lc", "dlbc"):
        recs = [r for r in records if r["policy"] == policy]
        rows.append([policy,
                     f"{np.mean([r['steps'] for r in recs]):.0f}",
                     f"{np.mean([r['utilization'] for r in recs]):.3f}",
                     f"{np.mean([r['mean_latency'] for r in recs]):.1f}",
                     f"{np.percentile(p99s[policy], 50):.1f}",
                     f"{np.mean([r['p99_decode_cost'] for r in recs]):.1f}",
                     sum(r["truncated"] for r in recs)])
    rows.append(["prefill max|Δ|", "", "", "", f"{max_delta:.1f}", "", ""])
    return report(
        "Serving: DLBC continuous batching vs LC fixed batching "
        f"(chunked prefill, cap={PREFILL_CHUNK}, {repeats} repeats)",
        rows,
        ["policy", "steps", "util", "mean_lat", "p99_lat(med)",
         "decode_cost_p99", "truncated"],
        "batcher", records, harness=bench.payload())


if __name__ == "__main__":
    run()
