"""Serving: DLBC continuous batching vs LC fixed batching — latency and
slot utilisation under a bursty arrival pattern."""

from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MDL
from repro.serve.batcher import ContinuousBatcher, Request

from .common import report


def run(n_requests: int = 32, slots: int = 4):
    cfg = ModelConfig(name="bench-serve", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=1024)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))

    def make_requests(seed):
        rng = np.random.default_rng(seed)
        return [Request(rid=i, prompt=list(rng.integers(0, 1024, size=3)),
                        max_new=int(rng.integers(3, 28)),
                        arrive_step=int(rng.integers(0, 30)))
                for i in range(n_requests)]

    rows, records = [], []
    for policy in ("lc", "dlbc"):
        st = ContinuousBatcher(cfg, params, n_slots=slots, cache_len=64,
                               policy=policy).run(make_requests(0))
        rows.append([policy, st.steps, f"{st.utilization:.3f}",
                     f"{np.mean(st.latencies):.1f}",
                     f"{np.percentile(st.latencies, 99):.1f}",
                     f"{np.mean(st.queue_waits):.1f}"])
        records.append(dict(policy=policy, steps=st.steps,
                            utilization=st.utilization,
                            mean_latency=float(np.mean(st.latencies)),
                            p99_latency=float(np.percentile(st.latencies,
                                                            99))))
    return report("Serving: DLBC continuous batching vs LC fixed batching",
                  rows, ["policy", "steps", "util", "mean_lat", "p99_lat",
                         "queue_wait"],
                  "batcher", records)


if __name__ == "__main__":
    run()
