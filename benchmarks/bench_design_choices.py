"""Paper §6 — the DLBC design-choice study.

The paper reports testing (and rejecting) alternatives to its final DLBC
policy: (b) re-checking for idle workers only every k-th serial iteration
("the complexity of the additional checks did not pay off") and (c) a
minimum-parallel-tasks policy instead of full serialization ("may end up
creating more tasks than required ... the cons outweighed the pros").
This benchmark re-runs that study on the task-explosive kernels."""

from __future__ import annotations

from repro.core import build_kernel
from repro.core.afe import apply_afe
from repro.core.dlbc import apply_dlbc
from repro.core.runtime import run_program

from .common import report

VARIANTS = {
    "DCAFE (paper)": {},
    "check-every-2": dict(serial_check_every=2),
    "check-every-4": dict(serial_check_every=4),
    "min-parallel": dict(min_parallel=True),
}

KERNELS = ["NQ", "HL", "FL", "DR"]


def run(scale: str = "bench", workers: int = 16):
    rows, records = [], []
    for kernel in KERNELS:
        k = build_kernel(kernel, scale)
        afe_p, _ = apply_afe(k.program)
        base_time = None
        for name, kw in VARIANTS.items():
            p = apply_dlbc(afe_p, **kw)
            r = run_program(p, n_workers=workers, heap=k.fresh_heap())
            got = k.extract(r.heap)
            want = {kk: v for kk, v in k.expected().items()
                    if kk in k.result_keys}
            ok = r.ok and got == want
            if base_time is None:
                base_time = r.time
            rows.append([kernel, name, r.counters.asyncs,
                         r.counters.finishes, f"{r.time:.0f}",
                         f"{base_time / r.time:.2f}", ok])
            records.append(dict(kernel=kernel, variant=name,
                                asyncs=r.counters.asyncs,
                                finishes=r.counters.finishes,
                                time=r.time, ok=ok))
    report(f"Paper §6 design-choice study (workers={workers}); "
           "speedup relative to the paper's DCAFE",
           rows, ["kernel", "variant", "#async", "#finish", "time",
                  "vs_paper", "correct"],
           "design_choices", records)
    print("(paper §6: per-iteration re-check and full serialization won; "
          "min-parallel 'creates more tasks than required')\n")
    return records


if __name__ == "__main__":
    run()
