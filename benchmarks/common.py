"""Shared benchmark utilities: table printing + JSON result persistence.

Every saved artifact is wrapped in a versioned envelope::

    {"schema_version": 2, "bench": name, "commit": "<git sha>",
     "seed": ..., "repeats": ..., "harness": {...}?, "records": [...]}

so the trajectory differ (``benchmarks.gates trajectory``) can refuse to
compare across incompatible schemas instead of KeyError-ing, and every
record is attributable to the commit that produced it.  Pre-envelope
artifacts (a bare list) are still readable via :func:`load_records` and
are treated as ``schema_version == 1``.
"""

from __future__ import annotations

import json
import subprocess
import time
from pathlib import Path

from repro.sched.telemetry import LogHistogram

from .harness import SCHEMA_VERSION

RESULTS_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"
TRACE_DIR = RESULTS_DIR / "trace"
#: flight-recorder incident reports (bench_slo / bench_faults); CI
#: uploads these and ``gates slo`` re-runs the embedded crosschecks
INCIDENTS_DIR = RESULTS_DIR / "incidents"

#: run-wide context set by ``benchmarks.run`` (--seed / --repeats) so
#: every artifact records what it was measured with — trajectory diffs
#: must compare like with like.
RUN_CONTEXT = {"seed": None, "repeats": None}


def set_run_context(seed=None, repeats=None):
    if seed is not None:
        RUN_CONTEXT["seed"] = int(seed)
    if repeats is not None:
        RUN_CONTEXT["repeats"] = int(repeats)


def git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=Path(__file__).resolve().parent.parent,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def envelope(name: str, records, harness=None) -> dict:
    doc = {
        "schema_version": SCHEMA_VERSION,
        "bench": name,
        "commit": git_commit(),
        "seed": RUN_CONTEXT["seed"],
        "repeats": RUN_CONTEXT["repeats"],
        "records": records,
    }
    if harness is not None:
        doc["harness"] = harness
    return doc


def save(name: str, payload, harness=None):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = RESULTS_DIR / f"{name}.json"
    out.write_text(json.dumps(envelope(name, payload, harness), indent=1,
                              default=str))
    return out


def load_envelope(path) -> dict:
    """Read an artifact in either format; bare-list artifacts come back
    wrapped as ``schema_version == 1`` with no commit."""
    doc = json.loads(Path(path).read_text())
    if isinstance(doc, list):
        return {"schema_version": 1, "bench": Path(path).stem,
                "commit": "unknown", "records": doc}
    return doc


def load_records(path) -> list:
    """The records list, whatever the envelope vintage."""
    return load_envelope(path)["records"]


def table(rows, headers):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    line = "  ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    print()


def report(title, rows, headers, name, records, harness=None):
    """Print a titled results table and persist the records as JSON —
    the one emit path shared by every benchmark.  ``harness`` is a
    :meth:`benchmarks.harness.Bench.payload` dict; when given, the
    saved envelope carries the arms/gates/trajectory section the CI
    ``dist`` and ``trajectory`` gates replay."""
    print(f"== {title}")
    table(rows, headers)
    path = save(name, records, harness)
    print(f"[saved {path}]")
    return records


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()  # monotonic: timers measure deltas
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0


def dist_stats(samples_s):
    """Distribution summary of repeated wall times through the shared
    log-bucketed histogram: p50/p99/max plus the p99/p50 tail ratio, so
    benchmark records report tails with the same bucketing the runtime
    telemetry uses (±1 bucket ≈ ×2 resolution, consistent overestimate).
    """
    hist = LogHistogram()
    hist.extend(samples_s)
    s = hist.summary()
    return {"n": s["n"], "p50_ms": s["p50_ms"], "p99_ms": s["p99_ms"],
            "max_ms": s["max_ms"], "tail_p99_p50": s["tail_p99_p50"]}


def write_trace(name: str, telemetry_summary=None):
    """Drain the obs rings into ``experiments/bench/trace/<name>.trace.json``
    (Chrome trace-event JSON) with the run's telemetry summary embedded,
    then cross-check trace-derived counts against it — the same check CI
    replays on the uploaded artifact.  Returns (path, crosscheck dict)."""
    from repro.obs import export as obs_export
    from repro.obs import trace as obs_trace

    TRACE_DIR.mkdir(parents=True, exist_ok=True)
    path = TRACE_DIR / f"{name}.trace.json"
    extra = {}
    if telemetry_summary is not None:
        extra["telemetry"] = telemetry_summary
    doc = obs_export.write_chrome_trace(str(path), extra=extra)
    check = (obs_export.crosscheck(doc, telemetry_summary)
             if telemetry_summary is not None else {"ok": True})
    obs_trace.clear()
    print(f"[trace {path}] crosscheck ok={check['ok']}")
    if not check["ok"]:
        raise AssertionError(
            f"trace/telemetry count mismatch: {check['mismatches']}")
    return path, check
