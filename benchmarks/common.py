"""Shared benchmark utilities: table printing + JSON result persistence."""

from __future__ import annotations

import json
import time
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"


def save(name: str, payload):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = RESULTS_DIR / f"{name}.json"
    out.write_text(json.dumps(payload, indent=1, default=str))
    return out


def table(rows, headers):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    line = "  ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    print()


def report(title, rows, headers, name, records):
    """Print a titled results table and persist the records as JSON —
    the one emit path shared by every benchmark."""
    print(f"== {title}")
    table(rows, headers)
    path = save(name, records)
    print(f"[saved {path}]")
    return records


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
