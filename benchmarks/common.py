"""Shared benchmark utilities: table printing + JSON result persistence."""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro.sched.telemetry import LogHistogram

RESULTS_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"
TRACE_DIR = RESULTS_DIR / "trace"


def save(name: str, payload):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = RESULTS_DIR / f"{name}.json"
    out.write_text(json.dumps(payload, indent=1, default=str))
    return out


def table(rows, headers):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    line = "  ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    print()


def report(title, rows, headers, name, records):
    """Print a titled results table and persist the records as JSON —
    the one emit path shared by every benchmark."""
    print(f"== {title}")
    table(rows, headers)
    path = save(name, records)
    print(f"[saved {path}]")
    return records


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()  # monotonic: timers measure deltas
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0


def dist_stats(samples_s):
    """Distribution summary of repeated wall times through the shared
    log-bucketed histogram: p50/p99/max plus the p99/p50 tail ratio, so
    benchmark records report tails with the same bucketing the runtime
    telemetry uses (±1 bucket ≈ ×2 resolution, consistent overestimate).
    """
    hist = LogHistogram()
    hist.extend(samples_s)
    s = hist.summary()
    return {"n": s["n"], "p50_ms": s["p50_ms"], "p99_ms": s["p99_ms"],
            "max_ms": s["max_ms"], "tail_p99_p50": s["tail_p99_p50"]}


def write_trace(name: str, telemetry_summary=None):
    """Drain the obs rings into ``experiments/bench/trace/<name>.trace.json``
    (Chrome trace-event JSON) with the run's telemetry summary embedded,
    then cross-check trace-derived counts against it — the same check CI
    replays on the uploaded artifact.  Returns (path, crosscheck dict)."""
    from repro.obs import export as obs_export
    from repro.obs import trace as obs_trace

    TRACE_DIR.mkdir(parents=True, exist_ok=True)
    path = TRACE_DIR / f"{name}.trace.json"
    extra = {}
    if telemetry_summary is not None:
        extra["telemetry"] = telemetry_summary
    doc = obs_export.write_chrome_trace(str(path), extra=extra)
    check = (obs_export.crosscheck(doc, telemetry_summary)
             if telemetry_summary is not None else {"ok": True})
    obs_trace.clear()
    print(f"[trace {path}] crosscheck ok={check['ok']}")
    if not check["ok"]:
        raise AssertionError(
            f"trace/telemetry count mismatch: {check['mismatches']}")
    return path, check
