"""Chaos lane: seeded fault injection on the work-stealing pool.

Four arms on the same :class:`WorkStealingExecutor` loop (fresh pool per
repeat so every repeat's conservation closes on its own telemetry):

* ``clean``        — fault-free oracle: the latency baseline and the
  executed-items reference.
* ``faulted_rtc``  — ~1% of items raise (``every=100``), fail mode
  ``run_to_completion``: every sibling still runs, the join rethrows ONE
  :class:`MultipleExceptions` carrying *all* of them.
* ``faulted_ff``   — same injection under ``fail_fast``: the first error
  trips the scope's cancel token and siblings skip, with every skipped
  item counted ``cancelled_items``.
* ``worker_death`` — one worker thread dies at its loop top; its queued
  ranges are re-placed and every item still executes.

The gates encode the ISSUE's two chaos claims *exactly* (no CI slack on
counters) plus one distribution bound:

* **zero exceptions lost** — per repeat, ``injected == telemetry.errors
  == collected-in-MultipleExceptions``, both fail modes (the fault hook
  only fires inside spawned/claimed items, so the identity is exact);
* **item conservation** — per repeat, ``executed + injected(raise) +
  cancelled_items == n_items`` and ``spawns == completions + cancelled``
  on every arm, deaths included;
* **p99 under faults** — ``p99(faulted_rtc) / p99(clean)`` stays within
  ``P99_FAULT_MAX``, bootstrap-CI verdict (one preempted repeat widens
  the interval instead of flipping the verdict).

CI replays the verdicts from ``faults.json`` via
``python -m benchmarks.gates faults``.
"""

from __future__ import annotations

import time
from contextlib import nullcontext

from repro.obs import trace as obs
from repro.obs.monitor import FlightRecorder, recording
from repro.sched import MultipleExceptions, WorkStealingExecutor
from repro.sched.faults import FaultPlan, FaultSpec, injected_faults

from .common import INCIDENTS_DIR, report, write_trace
from .harness import Bench

N_ITEMS = 400
WORKERS = 4
REPS = 7
ITEM_SLEEP_S = 5e-5     # releases the GIL: real host parallelism
FAULT_EVERY = 100       # ~1% of items raise (exact Nth-poke counter)
ARMS = ("clean", "faulted_rtc", "faulted_ff", "worker_death")
#: p99 wall under 1% injected raises vs fault-free, bootstrap-CI verdict
P99_FAULT_MAX = 1.5


def _plan_for(arm: str, seed: int, rep: int):
    """One fresh plan per repeat: injection counters then close per
    repeat, which is what makes the conservation gates exact."""
    plan_seed = (seed << 8) ^ rep
    if arm in ("faulted_rtc", "faulted_ff"):
        return FaultPlan([FaultSpec(site="sched.item", kind="raise",
                                    every=FAULT_EVERY)], seed=plan_seed)
    if arm == "worker_death":
        return FaultPlan([FaultSpec(site="sched.worker", kind="worker_death",
                                    every=1, max_injections=1)],
                         seed=plan_seed)
    return None


def _one_repeat(arm: str, seed: int, rep: int) -> dict:
    ex = WorkStealingExecutor(n_workers=WORKERS)
    executed = []

    def fn(i):
        executed.append(i)
        time.sleep(ITEM_SLEEP_S)

    plan = _plan_for(arm, seed, rep)
    mode = "fail_fast" if arm == "faulted_ff" else "run_to_completion"
    collected = 0
    try:
        with injected_faults(plan) if plan is not None else nullcontext():
            t0 = time.perf_counter()
            try:
                with ex.finish(fail_mode=mode) as scope:
                    ex.run_loop(list(range(N_ITEMS)), fn, scope=scope)
            except MultipleExceptions as e:
                collected = e.count
            wall = time.perf_counter() - t0
        t = ex.telemetry
        return dict(
            wall_s=wall, executed=len(executed), collected=collected,
            injected=plan.injected_total(kind="raise") if plan else 0,
            deaths_injected=(plan.injected_total(kind="worker_death")
                             if plan else 0),
            errors=t.errors, spawns=t.spawns, completions=t.completions,
            cancelled=t.cancelled, cancelled_items=t.cancelled_items,
            worker_deaths=t.worker_deaths, joins=t.joins)
    finally:
        ex.shutdown()


def _run_arm(arm: str, repeats=None, seed: int = 0) -> dict:
    reps = max(int(repeats), 5) if repeats else REPS
    stats = [_one_repeat(arm, seed, rep) for rep in range(reps)]
    walls = [s["wall_s"] for s in stats]
    rec = dict(arm=arm, reps=reps, wall_s=min(walls), wall_samples_s=walls)
    for k in ("executed", "collected", "injected", "deaths_injected",
              "errors", "spawns", "completions", "cancelled",
              "cancelled_items", "worker_deaths", "joins"):
        rec[k] = sum(s[k] for s in stats)
    # per-repeat absolute deviations: summed AFTER |.| so a leak in one
    # repeat cannot cancel against a double-count in another
    rec["exceptions_lost"] = sum(
        abs(s["collected"] - s["injected"]) + abs(s["errors"] - s["injected"])
        for s in stats)
    rec["items_unaccounted"] = sum(
        abs(s["executed"] + s["injected"] + s["cancelled_items"] - N_ITEMS)
        for s in stats)
    rec["tasks_unaccounted"] = sum(
        abs(s["spawns"] - s["completions"] - s["cancelled"]) for s in stats)
    rec["deaths_unaccounted"] = sum(
        abs(s["worker_deaths"] - s["deaths_injected"]) for s in stats)
    return rec


def _harness(records: list, seed: int) -> Bench:
    """Fold the sweep into the verdicts CI replays from the artifact."""
    bench = Bench("faults", seed=seed)
    by = {r["arm"]: r for r in records}
    for r in records:
        bench.add_samples(r["arm"], r["wall_samples_s"],
                          oracle=r["arm"] == "clean")
    bench.gate_ratio("p99_under_faults", "faulted_rtc", "clean", "<=",
                     P99_FAULT_MAX, p=99)
    # the chaos lane must actually be chaotic: injections happened
    bench.gate_exact("faults_injected", by["faulted_rtc"]["injected"]
                     + by["faulted_ff"]["injected"], ">=", 2)
    bench.gate_exact("deaths_injected",
                     by["worker_death"]["worker_deaths"], ">=", 1)
    # zero exceptions lost: injected == errors == collected, per repeat,
    # both fail modes — exact, no CI slack
    bench.gate_exact("exceptions_conserved",
                     by["faulted_rtc"]["exceptions_lost"]
                     + by["faulted_ff"]["exceptions_lost"], "<=", 0)
    # conservation under chaos: every item and task accounted on every arm
    bench.gate_exact("items_conserved",
                     sum(r["items_unaccounted"] for r in records), "<=", 0)
    bench.gate_exact("tasks_conserved",
                     sum(r["tasks_unaccounted"] for r in records), "<=", 0)
    bench.gate_exact("deaths_conserved",
                     by["worker_death"]["deaths_unaccounted"], "<=", 0)
    # run_to_completion never cancels; clean/death arms never error
    bench.gate_exact("rtc_no_cancellation",
                     by["faulted_rtc"]["cancelled"]
                     + by["clean"]["cancelled"], "<=", 0)
    bench.gate_exact("clean_arm_clean", by["clean"]["errors"]
                     + by["worker_death"]["errors"], "<=", 0)
    return bench


def _gates(records: list, bench: Bench) -> dict:
    by = {r["arm"]: r for r in records}
    gates = {g["gate"]: g for g in bench.gates}
    out = dict(
        p99_under_faults=round(gates["p99_under_faults"]["value"], 3),
        p99_under_faults_ci=gates["p99_under_faults"]["ci"],
        injected_rtc=by["faulted_rtc"]["injected"],
        injected_ff=by["faulted_ff"]["injected"],
        worker_deaths=by["worker_death"]["worker_deaths"],
    )
    for name, g in gates.items():
        out[f"{name}_ok"] = g["ok"]
    return out


def run(attempts: int = 2, repeats: int = None, seed: int = 0):
    history, records, gates = [], [], {}
    bench = None
    for attempt in range(1, attempts + 1):
        records = [_run_arm(arm, repeats, seed) for arm in ARMS]
        for r in records:
            r["attempt"] = attempt
        history.extend(records)
        bench = _harness(records, seed)
        gates = _gates(records, bench)
        gates["attempt"] = attempt
        if not bench.failed():
            break
        print(f"[attempt {attempt}: gates {gates} — "
              f"{'retrying' if attempt < attempts else 'giving up'}]")

    rows = [[r["arm"], f"{r['wall_s'] * 1e3:.2f}", r["injected"],
             r["collected"], r["errors"], r["cancelled_items"],
             r["worker_deaths"], r["executed"] // r["reps"],
             r["exceptions_lost"] + r["items_unaccounted"]
             + r["tasks_unaccounted"]]
            for r in records]
    out = report(
        f"Fault injection chaos lane ({N_ITEMS} items, {WORKERS} workers, "
        f"1/{FAULT_EVERY} raise rate, {records[0]['reps']} repeats, "
        f"seed {seed})",
        rows,
        ["arm", "wall_ms", "injected", "collected", "errors",
         "cancelled_items", "deaths", "executed/rep", "lost"],
        "faults", history + [dict(arm="gates", **gates)],
        harness=bench.payload())
    # Traced pass on the richest arm (rtc: errors AND full completion) —
    # the artifact CI replays through the exporter, proving every error
    # instant carries its site and conservation survives tracing.  A
    # flight recorder rides along: the MultipleExceptions join must fire
    # an incident whose embedded trace window crosschecks, and the same
    # recorder over a fault-free pass must stay silent.
    obs.clear()
    obs.enable()
    try:
        # clean pass first: same settings, zero faults -> zero incidents
        ex = WorkStealingExecutor(n_workers=WORKERS)
        try:
            rec = FlightRecorder(telemetry=ex.telemetry)
            with recording(rec):
                rec.arm()
                with ex.finish() as scope:
                    ex.run_loop(list(range(N_ITEMS)),
                                lambda i: time.sleep(ITEM_SLEEP_S),
                                scope=scope)
            assert rec.count() == 0, (
                f"flight recorder fired {rec.count()} incident(s) on a "
                "fault-free run (false positive)")
        finally:
            ex.shutdown()
        obs.clear()

        ex = WorkStealingExecutor(n_workers=WORKERS)
        plan = _plan_for("faulted_rtc", seed, rep=999)
        try:
            rec = FlightRecorder(telemetry=ex.telemetry,
                                 out_dir=str(INCIDENTS_DIR))
            with recording(rec), injected_faults(plan):
                rec.arm()
                try:
                    with ex.finish() as scope:
                        ex.run_loop(list(range(N_ITEMS)),
                                    lambda i: time.sleep(ITEM_SLEEP_S),
                                    scope=scope)
                except MultipleExceptions:
                    pass
            assert rec.count("multiple_exceptions") >= 1, (
                "MultipleExceptions join fired no incident")
            bad_cross = [i for i in rec.incidents
                         if not i.get("crosscheck", {}).get("ok", False)]
            assert not bad_cross, (
                "incident trace window failed conservation crosscheck: "
                f"{[i.get('crosscheck') for i in bad_cross]}")
            print(f"[flight recorder: {rec.count()} incident(s), "
                  f"crosscheck ok, persisted to {INCIDENTS_DIR}]")
            t = ex.telemetry
            write_trace("faults", dict(
                spawns=t.spawns, joins=t.joins, completions=t.completions,
                errors=t.errors, cancelled=t.cancelled,
                worker_deaths=t.worker_deaths,
                errors_by_site=dict(t.errors_by_site)))
        finally:
            ex.shutdown()
    finally:
        obs.disable()

    print(f"gates: {gates}")
    assert gates["exceptions_conserved_ok"], (
        "exceptions lost under injection: injected != errors != collected "
        f"(rtc+ff deviation {records[1]['exceptions_lost'] + records[2]['exceptions_lost']})")
    assert gates["items_conserved_ok"], (
        "items unaccounted under chaos: executed + raised + cancelled != "
        f"{N_ITEMS} on some repeat")
    assert gates["tasks_conserved_ok"], (
        "spawns != completions + cancelled on some repeat")
    assert gates["deaths_conserved_ok"] and gates["deaths_injected_ok"], (
        "worker deaths not conserved against injections")
    assert gates["faults_injected_ok"], "chaos lane ran fault-free"
    assert gates["rtc_no_cancellation_ok"], (
        "run_to_completion cancelled sibling work")
    assert gates["clean_arm_clean_ok"], "errors on a no-raise arm"
    assert gates["p99_under_faults_ok"], (
        f"p99 under 1% faults is {gates['p99_under_faults']:.2f}x fault-free "
        f"(CI {gates['p99_under_faults_ci']} excludes {P99_FAULT_MAX}x)")
    return out


if __name__ == "__main__":
    run()
