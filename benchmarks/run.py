"""Benchmark entrypoint: `PYTHONPATH=src python -m benchmarks.run [names]`.

One benchmark per paper table/figure plus the TPU-side analogues:

  fig10      — dynamic #finish/#async per kernel × scheme   (paper Fig. 10)
  fig11      — DCAFE vs LC speedup across worker counts     (paper Fig. 11)
  fig12      — full scheme ladder normalised to UnOpt       (paper Fig. 12)
  fig13      — simulated energy                             (paper Fig. 13)
  sync       — HLO collectives per AFE sync policy          (Fig. 10 on TPU)
  moe        — DLBC vs LC MoE dispatch drop rates           (§3.2 on TPU)
  ep         — expert-parallel all-to-all dispatch vs data-parallel:
               exchange telemetry + the one-join-per-round AFE gate
  batcher    — DLBC continuous batching vs LC fixed batches (§3.2 serving)
  tenants    — multi-tenant serving: weighted-DLBC isolation under bursts
  sched      — repro.sched policy ladder on the host pool (uniform/skewed)
  grain      — adaptive-grain work stealing: steal-driven splitting vs
               fixed grains (uniform overhead collapse + skew rebalance)
  adoption   — sched adoption surfaces: train-step / checkpoint / MoE
               spawn-join telemetry + the DCAFE≤LC join regression gate
  design     — paper §6 DLBC design-choice study
  roofline   — per-cell roofline table from dry-run artifacts (§Roofline)
"""

import sys
import time

from . import (
    bench_adoption, bench_batcher, bench_design_choices, bench_ep,
    bench_fig10_counts, bench_fig11_speedup, bench_fig12_schemes,
    bench_fig13_energy, bench_grain, bench_moe_dispatch, bench_roofline,
    bench_sched, bench_sync_policy, bench_tenants,
)

ALL = {
    "adoption": bench_adoption.run,
    "ep": bench_ep.run,
    "grain": bench_grain.run,
    "fig10": bench_fig10_counts.run,
    "fig11": bench_fig11_speedup.run,
    "fig12": bench_fig12_schemes.run,
    "fig13": bench_fig13_energy.run,
    "design": bench_design_choices.run,
    "moe": bench_moe_dispatch.run,
    "batcher": bench_batcher.run,
    "tenants": bench_tenants.run,
    "sched": bench_sched.run,
    "sync": bench_sync_policy.run,
    "roofline": bench_roofline.run,
}


def main(argv=None):
    names = (argv or sys.argv[1:]) or list(ALL)
    t0 = time.perf_counter()
    for name in names:
        print(f"\n{'=' * 72}\nBENCH {name}\n{'=' * 72}")
        t = time.perf_counter()
        ALL[name]()
        print(f"[{name} done in {time.perf_counter() - t:.1f}s]")
    print(f"\nall benchmarks done in {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
