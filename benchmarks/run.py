"""Benchmark entrypoint: `PYTHONPATH=src python -m benchmarks.run [names]`.

One benchmark per paper table/figure plus the TPU-side analogues:

  fig10      — dynamic #finish/#async per kernel × scheme   (paper Fig. 10)
  fig11      — DCAFE vs LC speedup across worker counts     (paper Fig. 11)
  fig12      — full scheme ladder normalised to UnOpt       (paper Fig. 12)
  fig13      — simulated energy                             (paper Fig. 13)
  sync       — HLO collectives per AFE sync policy          (Fig. 10 on TPU)
  moe        — DLBC vs LC MoE dispatch drop rates           (§3.2 on TPU)
  ep         — expert-parallel all-to-all dispatch vs data-parallel:
               exchange telemetry + the one-join-per-round AFE gate
  batcher    — DLBC continuous batching vs LC fixed batches (§3.2 serving)
  tenants    — multi-tenant serving: weighted-DLBC isolation under bursts
  sched      — repro.sched policy ladder on the host pool (uniform/skewed)
  grain      — adaptive-grain work stealing: steal-driven splitting vs
               fixed grains (uniform overhead collapse + skew rebalance)
  faults     — chaos lane: seeded fault injection (raises, fail-fast
               cancellation, worker death) with exact exception/item
               conservation gates and a p99-under-faults CI bound
  slo        — SLO burn-rate lane: adversary bursts burn a tenant's
               error budget and fire a flight-recorder incident; DLBC
               chunking keeps the budget intact at the same load
  adoption   — sched adoption surfaces: train-step / checkpoint / MoE
               spawn-join telemetry + the DCAFE≤LC join regression gate
  design     — paper §6 DLBC design-choice study
  roofline   — per-cell roofline table from dry-run artifacts (§Roofline)

``--seed N`` / ``--repeats N`` thread a deterministic seed and repeat
count into every bench that takes them (signature-inspected), and are
recorded in each saved artifact's envelope so trajectory diffs compare
like with like.
"""

import argparse
import inspect
import time

from . import (
    bench_adoption, bench_batcher, bench_design_choices, bench_ep,
    bench_faults, bench_fig10_counts, bench_fig11_speedup,
    bench_fig12_schemes, bench_fig13_energy, bench_grain,
    bench_moe_dispatch, bench_roofline, bench_sched, bench_slo,
    bench_sync_policy, bench_tenants,
)
from .common import set_run_context

ALL = {
    "adoption": bench_adoption.run,
    "ep": bench_ep.run,
    "faults": bench_faults.run,
    "grain": bench_grain.run,
    "slo": bench_slo.run,
    "fig10": bench_fig10_counts.run,
    "fig11": bench_fig11_speedup.run,
    "fig12": bench_fig12_schemes.run,
    "fig13": bench_fig13_energy.run,
    "design": bench_design_choices.run,
    "moe": bench_moe_dispatch.run,
    "batcher": bench_batcher.run,
    "tenants": bench_tenants.run,
    "sched": bench_sched.run,
    "sync": bench_sync_policy.run,
    "roofline": bench_roofline.run,
}


def _call(fn, seed, repeats):
    """Pass seed/repeats through to benches that accept them — several
    used to hardcode their own repeat counts and seed nothing."""
    params = inspect.signature(fn).parameters
    kwargs = {}
    if seed is not None and "seed" in params:
        kwargs["seed"] = seed
    if repeats is not None and "repeats" in params:
        kwargs["repeats"] = repeats
    return fn(**kwargs)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="run registered benchmarks",
        epilog="names: " + " ".join(ALL))
    ap.add_argument("names", nargs="*", help="benchmarks to run (all)")
    ap.add_argument("--seed", type=int, default=None,
                    help="deterministic seed threaded into every bench")
    ap.add_argument("--repeats", type=int, default=None,
                    help="repeat count for distribution-gated benches")
    args = ap.parse_args(argv)
    names = args.names or list(ALL)
    unknown = [n for n in names if n not in ALL]
    if unknown:
        ap.error(f"unknown benchmarks: {unknown} (have: {' '.join(ALL)})")
    set_run_context(seed=args.seed, repeats=args.repeats)
    t0 = time.perf_counter()
    for name in names:
        print(f"\n{'=' * 72}\nBENCH {name}\n{'=' * 72}")
        t = time.perf_counter()
        _call(ALL[name], args.seed, args.repeats)
        print(f"[{name} done in {time.perf_counter() - t:.1f}s]")
    print(f"\nall benchmarks done in {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
