"""Adaptive-grain work stealing: steal-driven chunk splitting vs fixed
grains on the host pool.

Three arms on the same :class:`WorkStealingExecutor`:

* ``grain1``   — ``chunk_grain = 1``: one task (one latch, one deque
  round-trip) per item.  Perfect balance, maximal overhead — the old
  executor's behaviour.  This is the *oracle* arm: the adaptive grain
  must reproduce its work (and beat it where the gates say so).
* ``coarse``   — one unsplittable range per planned chunk
  (``GrainController(k=1, k_max=1, split_min=huge)``): minimal overhead,
  but a committed chunk can never shed its heavy head.
* ``adaptive`` — the default DLBC grain controller: start coarse
  (``ceil(n / (k·workers))`` items per range), split on steal, recurse.

Two workloads: ``uniform`` (64 near-zero-cost items — wall time IS
scheduling overhead) and ``skewed`` (a 3× heavy head of sleep items —
wall time is load balance).  The gates encode the tentpole claim:

* adaptive ≥ 3× grain1 items/s on uniform (overhead collapse),
* adaptive within 10% of grain1 items/s on skewed (splitting still
  rebalances; ``steals > 0`` proves it),
* spawns collapse from ~n_items (grain1) to ~n_ranges (adaptive).

The speedup/fraction gates are *bootstrap-CI* verdicts over the full
per-repeat wall distributions (not best-of single samples): a gate only
fails when the whole confidence interval lands beyond the threshold, so
one OS-preempted repeat widens the interval instead of flipping the
verdict.  CI replays the same verdicts from ``grain.json`` via
``python -m benchmarks.gates grain``.
"""

from __future__ import annotations

import time

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs
from repro.sched import DLBC, GrainController, WorkStealingExecutor

from .common import report, write_trace
from .harness import Bench

N_ITEMS = 64
WORKERS = 4
UNIFORM_REPS = 9
SKEW_REPS = 5
ARMS = ("grain1", "coarse", "adaptive")
#: gate thresholds (ISSUE acceptance criteria)
UNIFORM_SPEEDUP_MIN = 3.0
SKEW_FRACTION_MIN = 0.9
SPAWNS_PER_LOOP_MAX = N_ITEMS // 4  # "~n_ranges, not ~n_items"
#: tracing overhead budget on the uniform grain loop (wall time there IS
#: scheduling overhead — the harshest denominator for the tracer)
TRACE_OVERHEAD_MAX = 0.05
#: always-on metrics registry budget on the same loop (the registry is
#: default-ON in production, so its bumps must be cheaper still)
METRICS_OVERHEAD_MAX = 0.05
OVERHEAD_ITEMS = 512   # larger loop: µs-scale emit cost needs a stable base
OVERHEAD_REPS = 9


def _cpu_item(x):
    return x * x  # near-zero cost: the scheduler IS the workload


def _sleep_item(ms):
    time.sleep(ms / 1e3)  # releases the GIL: real host parallelism


def make_workload(dist: str):
    if dist == "uniform":
        return list(range(N_ITEMS)), _cpu_item
    assert dist == "skewed"
    # contiguous 3x-heavy head: the worst case for a committed coarse
    # chunk, which strands the whole head on one worker unless stolen
    costs = [3.0 if i < N_ITEMS // 4 else 1.0 for i in range(N_ITEMS)]
    return costs, _sleep_item


def _reps_for(dist: str, repeats) -> int:
    if repeats:  # --repeats overrides, never below the CI-gate floor
        return max(int(repeats), 5)
    return UNIFORM_REPS if dist == "uniform" else SKEW_REPS


def _run_arm(arm: str, dist: str, repeats=None) -> dict:
    items, fn = make_workload(dist)
    ex = WorkStealingExecutor(n_workers=WORKERS)
    policy = DLBC()
    if arm == "grain1":
        ex.chunk_grain = 1
    elif arm == "coarse":
        policy = DLBC(grain=GrainController(k=1, k_max=1,
                                            split_min=1 << 30))
    reps = _reps_for(dist, repeats)
    try:
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            # one persistent policy instance: the adaptive arm's grain
            # controller carries steal feedback across loops
            ex.run_loop(items, fn, policy=policy)
            walls.append(time.perf_counter() - t0)
        best = min(walls)
        rec = dict(dist=dist, arm=arm, reps=reps, wall_s=best,
                   wall_samples_s=walls,
                   items_per_s=N_ITEMS / best, grain_k=policy.grain.k,
                   **ex.telemetry.summary())
        rec["spawns_per_loop"] = rec["spawns"] / reps
        return rec
    finally:
        ex.shutdown()


def _sweep(repeats=None) -> list:
    return [_run_arm(arm, dist, repeats)
            for dist in ("uniform", "skewed") for arm in ARMS]


def _overhead_check() -> dict:
    """Tracer cost on the uniform loop: best-of wall time with tracing
    off vs on, same executor and adaptive policy.  Events are only
    emitted at scheduling edges (per range, not per item), so the
    enabled run must stay within ``TRACE_OVERHEAD_MAX`` of baseline."""
    items = list(range(OVERHEAD_ITEMS))
    ex = WorkStealingExecutor(n_workers=WORKERS)
    policy = DLBC()

    def one():
        t0 = time.perf_counter()
        ex.run_loop(items, _cpu_item, policy=policy)
        return time.perf_counter() - t0

    try:
        one()  # warm the pool/ranges before either arm is timed
        base = traced = float("inf")
        # interleaved off/on pairs: host drift hits both arms equally
        for _ in range(OVERHEAD_REPS):
            obs.disable()
            base = min(base, one())
            obs.enable()
            traced = min(traced, one())
    finally:
        obs.disable()
        obs.clear()
        ex.shutdown()
    frac = traced / base - 1.0
    return dict(base_wall_s=base, traced_wall_s=traced,
                trace_overhead_frac=round(frac, 4),
                trace_overhead_ok=frac <= TRACE_OVERHEAD_MAX)


def _metrics_overhead_check() -> dict:
    """Always-on metrics plane cost on the same uniform loop: best-of
    wall with the registry disabled vs enabled (tracing off both arms).
    Bumps are per scheduling edge (per loop, never per item), so the
    default-ON registry must stay within ``METRICS_OVERHEAD_MAX``."""
    items = list(range(OVERHEAD_ITEMS))
    ex = WorkStealingExecutor(n_workers=WORKERS)
    policy = DLBC()

    def one():
        t0 = time.perf_counter()
        ex.run_loop(items, _cpu_item, policy=policy)
        return time.perf_counter() - t0

    try:
        one()  # warm the pool/ranges before either arm is timed
        base = enabled = float("inf")
        # interleaved off/on pairs: host drift hits both arms equally
        for _ in range(OVERHEAD_REPS):
            obs_metrics.disable()
            base = min(base, one())
            obs_metrics.enable()
            enabled = min(enabled, one())
    finally:
        obs_metrics.enable()  # the registry is default-ON
        ex.shutdown()
    frac = enabled / base - 1.0
    return dict(metrics_base_wall_s=base, metrics_wall_s=enabled,
                metrics_overhead_frac=round(frac, 4),
                metrics_overhead_ok=frac <= METRICS_OVERHEAD_MAX)


def _harness(records: list, seed: int) -> Bench:
    """Fold the sweep's per-repeat wall distributions into bootstrap-CI
    gates — the verdicts CI replays from the artifact."""
    bench = Bench("grain", seed=seed)
    by = {(r["dist"], r["arm"]): r for r in records}
    for (dist, arm), r in by.items():
        bench.add_samples(f"{dist}/{arm}", r["wall_samples_s"],
                          oracle=arm == "grain1")
    # walls are lower-better: speedup = p50(grain1) / p50(adaptive)
    bench.gate_speedup("uniform/adaptive", "uniform/grain1",
                       UNIFORM_SPEEDUP_MIN, name="uniform_speedup")
    bench.gate_speedup("skewed/adaptive", "skewed/grain1",
                       SKEW_FRACTION_MIN, name="skew_fraction")
    # structural counters carry no sampling noise: exact gates
    bench.gate_exact("spawns_per_loop",
                     by["uniform", "adaptive"]["spawns_per_loop"],
                     "<=", SPAWNS_PER_LOOP_MAX)
    bench.gate_exact("skew_steals",
                     by["skewed", "adaptive"]["steals"], ">=", 1)
    for r in records:
        if r["completions"] != r["spawns"]:
            bench.gate_exact(f"quiescence.{r['dist']}.{r['arm']}",
                             r["completions"], ">=", r["spawns"])
    return bench


def _gates(records: list, bench: Bench) -> dict:
    by = {(r["dist"], r["arm"]): r for r in records}
    gates = {g["gate"]: g for g in bench.gates}
    uniform_speedup = (by["uniform", "adaptive"]["items_per_s"]
                       / by["uniform", "grain1"]["items_per_s"])
    skew_fraction = (by["skewed", "adaptive"]["items_per_s"]
                     / by["skewed", "grain1"]["items_per_s"])
    return dict(
        uniform_speedup=round(uniform_speedup, 3),
        uniform_speedup_ok=gates["uniform_speedup"]["ok"],
        uniform_speedup_ci=gates["uniform_speedup"]["ci"],
        skew_fraction=round(skew_fraction, 3),
        skew_fraction_ok=gates["skew_fraction"]["ok"],
        skew_fraction_ci=gates["skew_fraction"]["ci"],
        spawns_collapsed=(
            by["uniform", "adaptive"]["spawns_per_loop"]
            <= SPAWNS_PER_LOOP_MAX
            < by["uniform", "grain1"]["spawns_per_loop"]),
        skew_steals_ok=by["skewed", "adaptive"]["steals"] > 0,
        # quiescence: every spawned task reported completion (errors are
        # a subset of completions — the containment contract)
        quiescence_ok=all(r["completions"] == r["spawns"]
                          for r in records),
    )


def run(attempts: int = 2, repeats: int = None, seed: int = 0):
    history, records, gates = [], [], {}
    bench = None
    for attempt in range(1, attempts + 1):
        records = _sweep(repeats)
        for r in records:
            r["attempt"] = attempt
        history.extend(records)
        bench = _harness(records, seed)
        gates = _gates(records, bench)
        gates.update(_overhead_check())
        gates.update(_metrics_overhead_check())
        gates["attempt"] = attempt
        if not bench.failed() and all(
                v for k, v in gates.items()
                if k.endswith("_ok") or k == "spawns_collapsed"):
            break
        print(f"[attempt {attempt}: gates {gates} — "
              f"{'retrying' if attempt < attempts else 'giving up'}]")

    bench.gate_exact("trace_overhead", gates["trace_overhead_frac"],
                     "<=", TRACE_OVERHEAD_MAX)
    bench.gate_exact("metrics_overhead", gates["metrics_overhead_frac"],
                     "<=", METRICS_OVERHEAD_MAX)
    rows = [[r["dist"], r["arm"], f"{r['wall_s'] * 1e3:.2f}",
             f"{r['items_per_s']:.0f}", f"{r['spawns_per_loop']:.1f}",
             r["steals"], r["splits"], r["grain_k"],
             r.get("steal_victims", {})]
            for r in records]
    out = report(
        f"Adaptive-grain work stealing ({N_ITEMS} items, {WORKERS} workers, "
        f"{records[0]['reps']}/{records[-1]['reps']} repeats, seed {seed})",
        rows,
        ["dist", "arm", "wall_ms", "items/s", "spawns/loop", "steals",
         "splits", "k", "steal_victims"],
        # every attempt's measurements are preserved in the artifact;
        # the gates record names the attempt that was judged
        "grain", history + [dict(dist="-", arm="gates", **gates)],
        harness=bench.payload())
    # Traced pass on the richest arm (skewed + adaptive: steals AND
    # splits) — the artifact the CI gate replays through the exporter.
    obs.clear()
    obs.enable()
    try:
        traced = _run_arm("adaptive", "skewed", repeats)
        write_trace("grain", {k: traced[k] for k in
                              ("spawns", "joins", "steals", "splits",
                               "completions", "errors")})
    finally:
        obs.disable()

    print(f"gates: {gates}")
    assert gates["uniform_speedup_ok"], (
        f"adaptive grain is only {gates['uniform_speedup']:.2f}x grain=1 "
        f"items/s on the uniform workload (CI {gates['uniform_speedup_ci']} "
        f"excludes {UNIFORM_SPEEDUP_MIN}x)")
    assert gates["skew_fraction_ok"], (
        f"adaptive grain fell to {gates['skew_fraction']:.2f} of grain=1 "
        f"items/s on the skewed workload (CI {gates['skew_fraction_ci']} "
        f"excludes {SKEW_FRACTION_MIN})")
    assert gates["spawns_collapsed"], "spawns did not collapse to ~n_ranges"
    assert gates["skew_steals_ok"], (
        "no steals on the skewed workload — splitting killed rebalancing")
    assert gates["quiescence_ok"], "completions != spawns at quiescence"
    assert gates["trace_overhead_ok"], (
        f"tracing overhead {gates['trace_overhead_frac']:.1%} on the "
        f"uniform grain loop (budget {TRACE_OVERHEAD_MAX:.0%})")
    assert gates["metrics_overhead_ok"], (
        f"always-on metrics overhead {gates['metrics_overhead_frac']:.1%} "
        f"on the uniform grain loop (budget {METRICS_OVERHEAD_MAX:.0%})")
    return out


if __name__ == "__main__":
    run()
