"""AFE sync-policy ladder on TPU (DESIGN.md §2.2): HLO collective count /
bytes per policy — the Fig. 10 "#finish" analogue for the training step.

Runs in a subprocess with an 8-device host mesh so the device-count
override stays contained."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import report

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.distributed.sharding import mesh_context, named_shardings
    from repro.models import model as MDL
    from repro.roofline.hlo_analyzer import analyze_hlo
    from repro.train.optimizer import AdamWConfig, opt_state_shapes
    from repro.train.train_step import StepConfig, build_train_step

    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    shape = ShapeConfig("t", 64, 8, "train", microbatches=4)
    ocfg = AdamWConfig()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    pshapes = MDL.param_shapes(cfg)
    out = {}
    for policy in ("unopt", "lc", "afe", "afe_bucket"):
        with mesh_context(mesh):
            scfg = StepConfig(policy=policy, q_chunk=32, k_chunk=32,
                              ssm_chunk=16)
            step, dp = build_train_step(cfg, shape, scfg, ocfg)
            pshard = named_shardings(pshapes, cfg, dp_shard=dp)
            oshard = {
                "m": named_shardings(pshapes, cfg, dp_shard=dp),
                "v": named_shardings(pshapes, cfg, dp_shard=dp),
                "step": NamedSharding(mesh, P()),
                "master": named_shardings(pshapes, cfg, dp_shard=dp),
            }
            oshapes = opt_state_shapes(pshapes, ocfg)
            oshapes = {k: oshapes[k] for k in oshard}
            bspec = {
                "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            }
            bshard = {k: NamedSharding(mesh, P("data", None))
                      for k in bspec}
            compiled = jax.jit(
                step, in_shardings=(pshard, oshard, bshard),
            ).lower(pshapes, oshapes, bspec).compile()
            cost = analyze_hlo(compiled.as_text())
            out[policy] = {
                "coll_count": {k: v for k, v in cost.coll_count.items()},
                "coll_bytes": {k: v for k, v in cost.coll_bytes.items()},
                "total_count": cost.total_coll_count,
                "total_bytes": cost.total_coll_bytes,
            }
    print("RESULT " + json.dumps(out))
""")


def run():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    result = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            result = json.loads(line[len("RESULT "):])
    if result is None:
        print("bench_sync_policy FAILED:\n", proc.stdout[-2000:],
              proc.stderr[-2000:])
        return {}
    rows = []
    for policy, r in result.items():
        rows.append([
            policy, int(r["total_count"]),
            f"{r['total_bytes'] / 2**20:.1f}",
            int(r["coll_count"].get("all-reduce", 0)),
            int(r["coll_count"].get("reduce-scatter", 0)),
            int(r["coll_count"].get("all-gather", 0)),
        ])
    report("Sync-policy ladder (granite smoke, 4x2 mesh, 4 microbatches):"
           " collectives per step",
           rows, ["policy", "#coll", "MB", "all-reduce", "reduce-scatter",
                  "all-gather"],
           "sync_policy", result)
    print("(the paper's dynamic-#finish table, as compiled collectives)\n")
    return result


if __name__ == "__main__":
    run()
