"""Distribution tests on a small in-process host mesh (subprocess so the
device-count override never leaks into other tests).

Verifies:
* the train step lowers+compiles for every sync policy on a (2,2) mesh
  and the HLO collective mix matches the policy ladder
  (unopt ≥ lc all-reduces; afe introduces reduce-scatter/all-gather);
* sharded and single-device execution agree numerically;
* a tiny multi-pod (2,2,2) mesh compiles (the "pod" axis shards).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.distributed.sharding import mesh_context, named_shardings
    from repro.models import model as MDL
    from repro.roofline.analysis import collective_stats
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_step import StepConfig, build_train_step

    cfg = get_config("phi3-mini-3.8b", smoke=True)
    shape = ShapeConfig("t", 32, 8, "train", microbatches=2)
    ocfg = AdamWConfig()

    def batch():
        k = jax.random.PRNGKey(0)
        t = jax.random.randint(k, (8, 32), 0, cfg.vocab)
        return {"tokens": t, "labels": jnp.roll(t, -1, axis=1)}

    # --- single-device reference ------------------------------------------
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, ocfg)
    scfg = StepConfig(policy="afe", q_chunk=32, k_chunk=32, ssm_chunk=16)
    step, _ = build_train_step(cfg, shape, scfg, ocfg)
    p_ref, o_ref, m_ref = jax.jit(step)(params, opt, batch())
    ref_gnorm = float(m_ref["grad_norm"])

    results = {}
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    for policy in ("unopt", "lc", "afe", "afe_bucket"):
        with mesh_context(mesh):
            scfg = StepConfig(policy=policy, q_chunk=32, k_chunk=32,
                              ssm_chunk=16)
            step, dp_shard = build_train_step(cfg, shape, scfg, ocfg)
            pshapes = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
            pshard = named_shardings(pshapes, cfg, dp_shard=dp_shard)
            oshard = {
                "m": named_shardings(pshapes, cfg, dp_shard=dp_shard),
                "v": named_shardings(pshapes, cfg, dp_shard=dp_shard),
                "step": NamedSharding(mesh, P()),
                "master": named_shardings(pshapes, cfg, dp_shard=dp_shard),
            }
            bshard = {k: NamedSharding(mesh, P("data", None))
                      for k in ("tokens", "labels")}
            jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard))
            lowered = jitted.lower(params, opt, batch())
            compiled = lowered.compile()
            stats = collective_stats(compiled.as_text())
            p2, o2, m2 = jitted(params, opt, batch())
            results[policy] = {
                "gnorm": float(m2["grad_norm"]),
                "colls": {k: v["count"] for k, v in stats.items()},
            }
    # --- multi-pod tiny mesh compiles ---------------------------------------
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    with mesh_context(mesh3):
        scfg = StepConfig(policy="afe", q_chunk=32, k_chunk=32, ssm_chunk=16)
        step, dp_shard = build_train_step(cfg, shape, scfg, ocfg)
        pshapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        pshard = named_shardings(pshapes, cfg, dp_shard=True)
        jax.jit(step, in_shardings=(pshard, None, None)).lower(
            params, opt, batch()).compile()
    results["ref_gnorm"] = ref_gnorm
    print("RESULT " + json.dumps(results))
""")


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200,
                         cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    import json

    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError("no RESULT line:\n" + out.stdout)


def test_policies_numerically_agree(dist_results):
    r = dist_results
    for policy in ("unopt", "lc", "afe", "afe_bucket"):
        assert r[policy]["gnorm"] == pytest.approx(r["ref_gnorm"], rel=2e-2), \
            policy


def test_policy_ladder_collective_mix(dist_results):
    r = dist_results
    ar = lambda p: r[p]["colls"]["all-reduce"]
    rs = lambda p: r[p]["colls"]["reduce-scatter"]
    ag = lambda p: r[p]["colls"]["all-gather"]
    # unopt syncs per microbatch → at least as many all-reduces as lc
    assert ar("unopt") >= ar("lc")
    # afe shards params: all-gathers appear (and usually reduce-scatters)
    assert ag("afe") + rs("afe") > 0
    # NOTE (refuted hypothesis, EXPERIMENTS.md §Perf): afe_bucket was
    # expected to cut the static collective count via fused flat buckets;
    # on GSPMD the concat/slice resharding around the buckets EMITS MORE
    # collectives than it fuses.  We assert only that it compiles and
    # stays numerically correct; the count is reported, not gated.
    assert sum(r["afe_bucket"]["colls"].values()) > 0
