"""Chunked prefill: exactness, isolation, validation, AFE accounting.

The serving-path prefill claims pinned here:

* chunked prefill == whole-prompt prefill, BITWISE (every chunk runs
  through the same static launch buffer and each query's attention
  reduces over the full cache, so chunk boundaries cannot move a single
  bit — the harness gates max |Δ| == 0.0);
* a padded/inert row of the batched prefill launch leaves its cache
  untouched bit-for-bit (neighbour isolation);
* a refill that starts a long prefill next to a slot deep in decode
  leaves the neighbour's tokens exactly as in its solo run;
* `submit()` validates prompts (empty, out-of-vocab, overlong) instead
  of crashing or silently wrapping inside `step()`;
* cache-bound kills are counted as `truncated`, apart from completions;
* telemetry joins count REQUESTS, never prefill chunks (AFE).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import model as MDL
from repro.serve.batcher import ContinuousBatcher, Request


def _cfg(vocab=128):
    return ModelConfig(name="prefill-test", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab=vocab)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prefill_in_chunks(cfg, params, prompt, sizes, *, buf=16, bsz=2,
                       cache_len=32):
    """Write ``prompt`` through prefill_step in the given chunk sizes
    (row 0 live, row 1 inert), all through one static ``buf``-wide
    launch buffer like the batcher."""
    assert sum(sizes) == len(prompt) and max(sizes) <= buf
    cache = MDL.init_cache(cfg, bsz, cache_len)
    pos = 0
    for s in sizes:
        toks = np.zeros((bsz, buf), np.int32)
        toks[0, :s] = prompt[pos:pos + s]
        _, cache = MDL.prefill_step(
            params, cfg, cache,
            {"tokens": jnp.asarray(toks),
             "cache_index": jnp.asarray([pos] + [0] * (bsz - 1), jnp.int32),
             "count": jnp.asarray([s] + [0] * (bsz - 1), jnp.int32)})
        pos += s
    return cache


def _decode_logits(cfg, params, cache, token, pos, bsz=2):
    toks = np.zeros((bsz, 1), np.int32)
    toks[0, 0] = token
    logits, _ = MDL.decode_step(
        params, cfg, cache,
        {"tokens": jnp.asarray(toks),
         "cache_index": jnp.asarray([pos] + [0] * (bsz - 1), jnp.int32)})
    return np.asarray(logits)


def test_chunked_prefill_is_bitwise_equal_to_whole(setup):
    """Chunk size ∈ {1, 8, prompt_len}: the KV cache and the next-token
    logits are EXACTLY equal — max |Δ| == 0.0, not allclose."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=12).tolist()
    pre = len(prompt) - 1  # decode consumes the last prompt token
    whole = _prefill_in_chunks(cfg, params, prompt[:-1], [pre])
    by_one = _prefill_in_chunks(cfg, params, prompt[:-1], [1] * pre)
    by_eight = _prefill_in_chunks(cfg, params, prompt[:-1], [8, pre - 8])
    ref = _decode_logits(cfg, params, whole, prompt[-1], pre)
    for cache in (by_one, by_eight):
        for k in ("k", "v"):
            assert np.array_equal(np.asarray(whole["layers"][k]),
                                  np.asarray(cache["layers"][k]))
        logits = _decode_logits(cfg, params, cache, prompt[-1], pre)
        assert float(np.abs(ref - logits).max()) == 0.0


def test_prefill_first_token_matches_forward(setup):
    """The decode-after-prefill argmax equals the training-path forward
    argmax on the same prompt (numerics differ — online vs full softmax
    — but the picked token must not)."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=9).tolist()
    pre = len(prompt) - 1
    cache = _prefill_in_chunks(cfg, params, prompt[:-1], [pre])
    logits = _decode_logits(cfg, params, cache, prompt[-1], pre)
    fwd = np.asarray(MDL.forward(params, cfg,
                                 {"tokens": jnp.asarray([prompt])},
                                 last_only=True))
    assert int(np.argmax(fwd[0].ravel()[:cfg.vocab])) \
        == int(np.argmax(logits[0, :cfg.vocab]))


def test_inert_rows_untouched_bitwise(setup):
    """A row with count == 0 in the batched launch keeps its cache
    bit-for-bit — seeded with garbage first so zeros can't mask a
    spurious write."""
    cfg, params = setup
    cache = MDL.init_cache(cfg, 2, 32)
    k0 = jax.random.normal(jax.random.PRNGKey(1),
                           cache["layers"]["k"].shape,
                           cache["layers"]["k"].dtype)
    cache["layers"]["k"] = k0
    toks = np.zeros((2, 16), np.int32)
    toks[0, :5] = [1, 2, 3, 4, 5]
    _, new_cache = MDL.prefill_step(
        params, cfg, cache,
        {"tokens": jnp.asarray(toks),
         "cache_index": jnp.asarray([0, 0], jnp.int32),
         "count": jnp.asarray([5, 0], jnp.int32)})
    assert np.array_equal(np.asarray(new_cache["layers"]["k"])[:, 1],
                          np.asarray(k0)[:, 1])
    # and the live row's tail (past its span) is untouched too
    assert np.array_equal(np.asarray(new_cache["layers"]["k"])[:, 0, 5:],
                          np.asarray(k0)[:, 0, 5:])


def test_prefill_rejected_for_unsupported_cache_families(setup):
    cfg, params = setup
    windowed = ModelConfig(name="win", family="dense", n_layers=2,
                           d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                           vocab=128, sliding_window=8)
    with pytest.raises(NotImplementedError, match="ring-buffer"):
        MDL.prefill_step(MDL.init_params(windowed, jax.random.PRNGKey(0)),
                         windowed, MDL.init_cache(windowed, 1, 16),
                         {"tokens": jnp.zeros((1, 4), jnp.int32),
                          "cache_index": jnp.zeros(1, jnp.int32),
                          "count": jnp.ones(1, jnp.int32)})


# -- batcher-level ----------------------------------------------------------


def test_refill_mid_prefill_neighbour_decode_unperturbed(setup):
    """A long-prompt request refilled next to a slot deep in decode must
    not perturb the neighbour: its tokens match the solo run exactly.
    And the long request's own tokens match ITS solo run — chunked
    prefill beside a decoder changes nothing either way."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    long_prompt = rng.integers(0, cfg.vocab, size=14).tolist()

    def batcher():
        return ContinuousBatcher(cfg, params, n_slots=2, cache_len=32,
                                 policy="dlbc", prefill_chunk=4)

    def steady():
        return Request(rid=0, prompt=[7, 8, 9], max_new=12, arrive_step=0)

    def adversary():
        # arrives once the steady slot is several tokens deep in decode
        return Request(rid=1, prompt=list(long_prompt), max_new=4,
                       arrive_step=4)

    solo_s = steady()
    batcher().run([solo_s])
    solo_a = adversary()
    batcher().run([solo_a])
    s, a = steady(), adversary()
    both = batcher()
    both.run([s, a])
    # the adversary's 13-token prefix really was chunked (cap 4)
    assert both.sched.telemetry.prefill_chunks >= 4
    assert s.tokens == solo_s.tokens
    assert a.tokens == solo_a.tokens


def test_submit_rejects_empty_prompt(setup):
    cfg, params = setup
    b = ContinuousBatcher(cfg, params, n_slots=2, cache_len=16)
    with pytest.raises(ValueError, match="empty prompt"):
        b.submit(Request(rid=0, prompt=[], max_new=4))


def test_submit_rejects_out_of_vocab(setup):
    cfg, params = setup
    b = ContinuousBatcher(cfg, params, n_slots=2, cache_len=16)
    with pytest.raises(ValueError, match="outside"):
        b.submit(Request(rid=0, prompt=[1, cfg.vocab], max_new=4))
    with pytest.raises(ValueError, match="outside"):
        b.submit(Request(rid=1, prompt=[-1], max_new=4))


def test_submit_rejects_overlong_prompt(setup):
    cfg, params = setup
    b = ContinuousBatcher(cfg, params, n_slots=2, cache_len=16)
    with pytest.raises(ValueError, match="cannot fit"):
        b.submit(Request(rid=0, prompt=list(range(17)), max_new=4))


def test_submit_rejects_windowed_multi_token_prompt():
    cfg = ModelConfig(name="win", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      sliding_window=8)
    b = ContinuousBatcher(cfg, params=MDL.init_params(
        cfg, jax.random.PRNGKey(0)), n_slots=2, cache_len=16)
    with pytest.raises(NotImplementedError, match="single-token"):
        b.submit(Request(rid=0, prompt=[1, 2, 3], max_new=2))
    # single-token prompts still serve on windowed configs
    b.submit(Request(rid=1, prompt=[1], max_new=2))


def test_truncated_counter_separates_cache_kills(setup):
    """A request that hits the cache bound before max_new is counted in
    `truncated`, not silently folded into normal completions."""
    cfg, params = setup
    b = ContinuousBatcher(cfg, params, n_slots=2, cache_len=16,
                          policy="dlbc")
    b.run([Request(rid=0, prompt=[1, 2], max_new=500, arrive_step=0),
           Request(rid=1, prompt=[3], max_new=2, arrive_step=0)])
    assert b.stats.truncated == 1
    assert len(b.stats.latencies) == 2  # both still complete + record
    assert "truncated" in b.stats.summary()
    assert b.stats.summary()["truncated"] == 1


def test_joins_count_requests_not_chunks(setup):
    """AFE over the serving path: a request whose prefill ran in many
    chunks still joins exactly once — spawns == joins == requests, with
    chunk work in its own counters."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, size=13).tolist()
               for _ in range(3)]
    b = ContinuousBatcher(cfg, params, n_slots=2, cache_len=32,
                          policy="dlbc", prefill_chunk=4)
    b.run([Request(rid=i, prompt=p, max_new=3, arrive_step=2 * i)
           for i, p in enumerate(prompts)])
    tele = b.sched.telemetry
    assert tele.spawns == tele.joins == 3
    assert tele.prefill_chunks >= 3 * 2  # 12-token prefixes, chunk cap 4
    assert tele.prefill_tokens == 3 * 12
    assert b.stats.summary()["n_done"] == 3


def test_decode_cost_accounting_charges_shared_prefill(setup):
    """Per-token decode costs: steps shared with prefill chunks cost
    1 + chunk, and the whole-prefill baseline's worst token cost is
    strictly larger than chunked's (the SLO mechanism the adversary
    bench gates)."""
    cfg, params = setup
    rng = np.random.default_rng(13)
    long_prompt = rng.integers(0, cfg.vocab, size=25).tolist()

    def run(mode):
        reqs = [Request(rid=0, prompt=[5, 6], max_new=30, arrive_step=0),
                Request(rid=1, prompt=list(long_prompt), max_new=2,
                        arrive_step=3)]
        b = ContinuousBatcher(cfg, params, n_slots=2, cache_len=48,
                              policy="dlbc", prefill_chunk=6,
                              prefill_mode=mode)
        b.run(reqs)
        return b, reqs
    chunked, creqs = run("chunked")
    whole, wreqs = run("whole")
    assert max(chunked.stats.decode_step_costs) \
        <= 1 + chunked.prefill_chunk
    assert max(whole.stats.decode_step_costs) \
        > max(chunked.stats.decode_step_costs)
    # chunking changes scheduling, never tokens (bitwise prefill)
    assert [r.tokens for r in creqs] == [r.tokens for r in wreqs]
