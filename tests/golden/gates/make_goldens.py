"""Regenerate the golden gate artifacts in this directory.

Run from the repo root after an intentional schema change::

    PYTHONPATH=src:. python tests/golden/gates/make_goldens.py

Each gate gets one PASSING and one FAILING artifact; the replay tests
(``tests/test_bench_gates.py``) assert the verdicts.  The harness
sections are built with the real :class:`benchmarks.harness.Bench` so
the goldens can never drift from the producer format silently.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.harness import SCHEMA_VERSION, Bench

HERE = Path(__file__).resolve().parent


def envelope(bench, records, harness=None):
    doc = {"schema_version": SCHEMA_VERSION, "bench": bench,
           "commit": "golden", "seed": 0, "repeats": 5,
           "records": records}
    if harness is not None:
        doc["harness"] = harness
    return doc


def dump(name, doc):
    (HERE / name).write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {name}")


# -- afe --------------------------------------------------------------------

def afe(joins_dcafe_train):
    recs = []
    for surface in ("train_step", "checkpoint"):
        for policy, joins in (("serial", 0), ("lc", 2),
                              ("dlbc", 2),
                              ("dcafe", joins_dcafe_train
                               if surface == "train_step" else 1)):
            recs.append(dict(surface=surface, policy=policy, joins=joins,
                             spawns=8, p50_ms=1.0, p99_ms=2.0))
    return envelope("adoption", recs)


# -- grain ------------------------------------------------------------------

def grain(adaptive_uniform_ms):
    """grain1 uniform ~0.6ms; a passing adaptive is ~0.1ms (6x), a
    failing one is ~0.5ms (1.2x: the bootstrap CI excludes 3x)."""
    walls = {
        ("uniform", "grain1"): [0.60e-3, 0.62e-3, 0.61e-3, 0.63e-3, 0.60e-3],
        ("uniform", "coarse"): [0.12e-3, 0.13e-3, 0.12e-3, 0.12e-3, 0.13e-3],
        ("uniform", "adaptive"): [adaptive_uniform_ms * 1e-3 * f
                                  for f in (1.0, 1.05, 0.98, 1.02, 1.0)],
        ("skewed", "grain1"): [21.5e-3, 21.8e-3, 21.6e-3, 21.9e-3, 21.7e-3],
        ("skewed", "coarse"): [27.9e-3, 28.1e-3, 28.0e-3, 27.8e-3, 28.2e-3],
        ("skewed", "adaptive"): [21.8e-3, 22.0e-3, 21.9e-3, 22.1e-3, 21.8e-3],
    }
    bench = Bench("grain", seed=0)
    records = []
    for (dist, arm), ws in walls.items():
        bench.add_samples(f"{dist}/{arm}", ws, oracle=arm == "grain1")
        spawns = 260 if arm == "grain1" else 20
        records.append(dict(
            dist=dist, arm=arm, attempt=1, reps=5, wall_s=min(ws),
            wall_samples_s=ws, items_per_s=64 / min(ws),
            spawns=spawns, joins=5, steals=17 if dist == "skewed" else 0,
            splits=17 if (dist, arm) == ("skewed", "adaptive") else 0,
            completions=spawns, errors=0,
            spawns_per_loop=spawns / 5))
    bench.gate_speedup("uniform/adaptive", "uniform/grain1", 3.0,
                       name="uniform_speedup")
    bench.gate_speedup("skewed/adaptive", "skewed/grain1", 0.9,
                       name="skew_fraction")
    bench.gate_exact("spawns_per_loop", 4.0, "<=", 16)
    bench.gate_exact("skew_steals", 17, ">=", 1)
    bench.gate_exact("trace_overhead", 0.03, "<=", 0.05)
    records.append(dict(dist="-", arm="gates", attempt=1,
                        trace_overhead_frac=0.03))
    return envelope("grain", records, bench.payload())


# -- ep ---------------------------------------------------------------------

def ep(joins):
    recs = []
    for router, dropped in (("balanced", 0), ("hot", 6)):
        recs.append(dict(arm="dp", role="oracle", router=router,
                         capacity_factor=1.0, ms=1.0, spawns=510,
                         joins=1, rounds=1, dropped_frac=0.0))
        recs.append(dict(arm="ep", role="candidate", router=router,
                         capacity_factor=1.0, ms=2.0, spawns=512,
                         joins=joins, rounds=1, sent=512, received=512,
                         dropped=dropped, dropped_frac=dropped / 512))
    return envelope("ep", recs)


# -- tenants ----------------------------------------------------------------

def tenants(global_spawns, iso_ratios):
    bench = Bench("tenants", seed=0)
    bench.add_samples("solo", [3.0] * 5, unit="steps", oracle=True)
    bench.add_samples("weighted", [7.0] * 5, unit="steps")
    bench.add_samples("fifo", [47.0] * 5, unit="steps")
    bench.add_samples("isolation_ratio", iso_ratios, unit="ratio")
    bench.gate_samples("isolation", "isolation_ratio", "<=", 1.0, p=50)
    recs = []
    for rep in range(5):
        for scenario in ("solo", "fifo", "weighted"):
            tenants_ctr = ({"steady": dict(spawns=50, joins=50)}
                           if scenario == "solo" else
                           {} if scenario == "fifo" else
                           {"steady": dict(spawns=50, joins=50),
                            "bursty": dict(spawns=48, joins=48)})
            total = sum(t["spawns"] for t in tenants_ctr.values()) or 98
            recs.append(dict(
                scenario=scenario, repeat=rep, steady_p99=7.0,
                sched=dict(spawns=global_spawns if scenario == "weighted"
                           else total,
                           joins=global_spawns if scenario == "weighted"
                           else total,
                           tenants=tenants_ctr)))
    return envelope("tenants", recs, bench.payload())


# -- faults -----------------------------------------------------------------

def faults(lost):
    """Chaos-lane golden: ``lost`` collected-exception deficits on the
    run_to_completion arm (0 = conserved, the pass variant — a nonzero
    deficit is an injected fault the join swallowed)."""
    walls = {
        "clean": [9.5e-3, 9.7e-3, 9.6e-3, 9.8e-3, 9.5e-3],
        "faulted_rtc": [10.1e-3, 10.4e-3, 10.2e-3, 10.5e-3, 10.3e-3],
        "faulted_ff": [3.5e-3, 3.6e-3, 3.4e-3, 3.7e-3, 3.5e-3],
        "worker_death": [12.4e-3, 12.6e-3, 12.5e-3, 12.7e-3, 12.4e-3],
    }
    counters = {
        "clean": dict(injected=0, collected=0, errors=0,
                      worker_deaths=0, deaths_injected=0, cancelled=0),
        "faulted_rtc": dict(injected=20, collected=20 - lost, errors=20,
                            worker_deaths=0, deaths_injected=0, cancelled=0),
        "faulted_ff": dict(injected=6, collected=6, errors=6,
                           worker_deaths=0, deaths_injected=0, cancelled=9),
        "worker_death": dict(injected=0, collected=0, errors=0,
                             worker_deaths=5, deaths_injected=5, cancelled=0),
    }
    bench = Bench("faults", seed=0)
    records = []
    for arm, ws in walls.items():
        c = counters[arm]
        bench.add_samples(arm, ws, oracle=arm == "clean")
        records.append(dict(
            arm=arm, attempt=1, reps=5, wall_s=min(ws), wall_samples_s=ws,
            executed=2000 - c["injected"], spawns=80,
            completions=80 - c["cancelled"], cancelled_items=0, joins=5,
            exceptions_lost=lost if arm == "faulted_rtc" else 0,
            items_unaccounted=0, tasks_unaccounted=0,
            deaths_unaccounted=0, **c))
    bench.gate_ratio("p99_under_faults", "faulted_rtc", "clean", "<=",
                     1.5, p=99)
    bench.gate_exact("faults_injected", 26, ">=", 2)
    bench.gate_exact("deaths_injected", 5, ">=", 1)
    bench.gate_exact("exceptions_conserved", lost, "<=", 0)
    bench.gate_exact("items_conserved", 0, "<=", 0)
    bench.gate_exact("tasks_conserved", 0, "<=", 0)
    bench.gate_exact("deaths_conserved", 0, "<=", 0)
    bench.gate_exact("rtc_no_cancellation", 0, "<=", 0)
    bench.gate_exact("clean_arm_clean", 0, "<=", 0)
    records.append(dict(arm="gates", attempt=1))
    return envelope("faults", records, bench.payload())


# -- dist -------------------------------------------------------------------

def dist(samples, lie=False):
    bench = Bench("sched", seed=0)
    bench.add_samples("uniform/dlbc", samples)
    bench.gate_tail_ratio("uniform/dlbc", 2.0)
    # the p50 gate is what flips between pass and fail: the fail
    # variant's samples sit entirely above 2.0, so the bootstrap CI
    # conclusively excludes the threshold (a tail-only fail would be
    # inconclusive: resamples omitting the outlier straddle)
    bench.gate_samples("uniform_p50", "uniform/dlbc", "<=", 2.0, p=50)
    payload = bench.payload()
    if lie:  # producer wrote ok=true over a failing CI (tamper check)
        for g in payload["gates"]:
            g["ok"] = True
    return envelope("sched", [], payload)


# -- trace ------------------------------------------------------------------

def trace(spawns_in_telemetry):
    events = [
        {"name": "spawn", "cat": "ws", "ph": "i", "ts": 1.0, "pid": 0,
         "tid": 1, "s": "t", "args": {"n": 5}},
        {"name": "join", "cat": "scope", "ph": "i", "ts": 2.0, "pid": 0,
         "tid": 1, "s": "t", "args": {"n": 1}},
        {"name": "complete", "cat": "ws", "ph": "i", "ts": 3.0, "pid": 0,
         "tid": 1, "s": "t", "args": {"n": 5}},
        {"name": "task", "cat": "worker", "ph": "X", "ts": 1.0,
         "dur": 100.0, "pid": 0, "tid": 1, "args": {"n": 1}},
    ]
    telemetry = dict(spawns=spawns_in_telemetry, joins=1, steals=0,
                     splits=0, completions=5, errors=0)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "telemetry": telemetry}


def main():
    dump("afe_pass.json", afe(joins_dcafe_train=1))
    dump("afe_fail.json", afe(joins_dcafe_train=3))
    dump("grain_pass.json", grain(adaptive_uniform_ms=0.10))
    dump("grain_fail.json", grain(adaptive_uniform_ms=0.50))
    dump("ep_pass.json", ep(joins=1))
    dump("ep_fail.json", ep(joins=2))
    dump("tenants_pass.json",
         tenants(global_spawns=98, iso_ratios=[0.4] * 5))
    dump("tenants_fail.json",
         tenants(global_spawns=99, iso_ratios=[0.4] * 5))
    dump("faults_pass.json", faults(lost=0))
    dump("faults_fail.json", faults(lost=3))
    dump("dist_pass.json", dist([1.0, 1.1, 1.05, 0.95, 1.02]))
    dump("dist_fail.json", dist([5.0, 5.1, 5.05, 4.95, 5.02], lie=True))
    (HERE / "trace_pass" / "trace").mkdir(parents=True, exist_ok=True)
    (HERE / "trace_fail" / "trace").mkdir(parents=True, exist_ok=True)
    (HERE / "trace_pass" / "trace" / "mini.trace.json").write_text(
        json.dumps(trace(spawns_in_telemetry=5), indent=1) + "\n")
    (HERE / "trace_fail" / "trace" / "mini.trace.json").write_text(
        json.dumps(trace(spawns_in_telemetry=6), indent=1) + "\n")
    print("wrote trace_pass/ trace_fail/")
    # trajectory pair: current regresses sched p99 by 12% over previous
    prev = {"schema_version": SCHEMA_VERSION, "commit": "prev",
            "surfaces": {
                "sched/skewed/dlbc.p99_s": {"value": 0.170,
                                            "better": "lower"},
                "grain/gate.uniform_speedup": {"value": 6.0,
                                               "better": "higher"},
            }}
    cur = {"schema_version": SCHEMA_VERSION, "commit": "cur",
           "surfaces": {
               "sched/skewed/dlbc.p99_s": {
                   "value": 0.1904, "better": "lower",
                   "ci": [0.189, 0.192]},
               "grain/gate.uniform_speedup": {"value": 6.1,
                                              "better": "higher"},
           }}
    dump("trajectory_prev.json", prev)
    dump("trajectory_regressed.json", cur)


if __name__ == "__main__":
    main()
