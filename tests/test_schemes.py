"""The paper's Fig. 10/11/12 claims on the eight RTP kernels (scaled
inputs): correctness under every scheme, finish-count algebra, DCAFE's
task reduction, and the speedup ordering."""

import pytest

from repro.core import build_kernel, run_scheme

KERNELS = ["NQ", "BFS", "BY", "DR", "DST", "MST", "HL", "FL"]


@pytest.mark.parametrize("kernel", KERNELS)
@pytest.mark.parametrize("scheme", ["Serial", "UnOpt", "UnOpt+AFE", "LC",
                                    "LC+AFE", "DLBC", "DCAFE"])
def test_scheme_correct(kernel, scheme):
    k = build_kernel(kernel, "test")
    r = run_scheme(k, scheme, workers=4)
    assert r.ok, (kernel, scheme, r.result)


@pytest.mark.parametrize("kernel,expect_single_finish", [
    ("NQ", True),    # paper: 27M → 1
    ("BFS", True),   # paper: 58k → 1
    ("DR", False),   # MHBD blocks the pull (paper: 28k → 17k)
    ("HL", False),   # MHBD blocks the pull
    ("FL", False),   # finish outside doubly-nested loop survives
])
def test_afe_pull_pattern(kernel, expect_single_finish):
    k = build_kernel(kernel, "test")
    r = run_scheme(k, "DCAFE", workers=4)
    assert r.ok
    if expect_single_finish:
        assert r.finishes == 1, (kernel, r.finishes)
    else:
        assert r.finishes > 1, (kernel, r.finishes)


@pytest.mark.parametrize("kernel", KERNELS)
def test_dcafe_reduces_tasks_and_time(kernel):
    k = build_kernel(kernel, "test")
    unopt = run_scheme(k, "UnOpt", workers=8)
    dcafe = run_scheme(k, "DCAFE", workers=8)
    assert dcafe.ok and unopt.ok
    assert dcafe.asyncs <= unopt.asyncs, kernel
    assert dcafe.finishes <= unopt.finishes, kernel
    # Fig. 11: DCAFE at least matches LC/UnOpt performance on every kernel
    # at this scale (it strictly wins on the task-explosive ones).
    assert dcafe.time <= unopt.time * 1.10, kernel


def test_nq_task_explosion_ratio():
    """The headline: NQ asyncs drop by >5× and finishes collapse to 1."""
    k = build_kernel("NQ", "test")
    unopt = run_scheme(k, "UnOpt", workers=8)
    dcafe = run_scheme(k, "DCAFE", workers=8)
    assert dcafe.finishes == 1
    assert unopt.asyncs / max(1, dcafe.asyncs) > 5.0
    assert unopt.finishes > 100


def test_speedup_grows_with_workers():
    """Fig. 11 trend: DCAFE's advantage over LC grows with workers (at
    1 worker LC spawns one chunk per loop, so both schemes are near-serial
    — the paper's observation that low-core gains are insignificant)."""
    k = build_kernel("NQ", "test")
    speedups = []
    for w in (1, 4, 16):
        u = run_scheme(k, "LC", workers=w)
        d = run_scheme(k, "DCAFE", workers=w)
        speedups.append(u.time / d.time)
    assert speedups[-1] > speedups[0]


def test_energy_tracks_time():
    """Fig. 13: DCAFE consumes less simulated energy than LC on the
    task-explosive kernels."""
    for kernel in ("NQ", "BFS", "HL"):
        k = build_kernel(kernel, "test")
        lc = run_scheme(k, "LC", workers=8)
        dc = run_scheme(k, "DCAFE", workers=8)
        assert dc.energy <= lc.energy, kernel


def test_dlbc_design_variants_preserve_semantics():
    """Paper §6 alternatives (check-every-k, min-parallel) stay correct."""
    from repro.core.afe import apply_afe
    from repro.core.dlbc import apply_dlbc
    from repro.core.runtime import run_program

    for kernel in ("NQ", "HL"):
        k = build_kernel(kernel, "test")
        afe_p, _ = apply_afe(k.program)
        for kw in ({}, dict(serial_check_every=3), dict(min_parallel=True)):
            p = apply_dlbc(afe_p, **kw)
            r = run_program(p, n_workers=4, heap=k.fresh_heap())
            got = k.extract(r.heap)
            want = {kk: v for kk, v in k.expected().items()
                    if kk in k.result_keys}
            assert r.ok and got == want, (kernel, kw)


def test_dlbc_min_parallel_spawns_more():
    """Paper §6(c): min-parallel 'may end up creating more tasks'."""
    from repro.core.afe import apply_afe
    from repro.core.dlbc import apply_dlbc
    from repro.core.runtime import run_program

    k = build_kernel("NQ", "test")
    afe_p, _ = apply_afe(k.program)
    base = run_program(apply_dlbc(afe_p), n_workers=8, heap=k.fresh_heap())
    minp = run_program(apply_dlbc(afe_p, min_parallel=True), n_workers=8,
                       heap=k.fresh_heap())
    assert minp.counters.asyncs > base.counters.asyncs
