"""Property-based tests (hypothesis): the DCAFE schemes are semantics
preserving on randomly generated RTP programs, and never increase the
dynamic finish count.

Generated programs are RACE-FREE by construction (only commutative heap
updates, declared ``x[+]``): the async-finish model guarantees
deterministic results only for race-free programs, so output equality is
a sound oracle exactly on this class.  (A plain read racing an unjoined
sibling's write legally yields schedule-dependent values — a transformed
program picking a different legal schedule is not a bug; hypothesis
found precisely such a case when an earlier version generated racy
post-finish reads.)  Dependence-*blocking* behaviour — transforms
refusing to move statements across real dependences — is covered by the
deterministic unit tests in test_ir_transforms.py and the DR/HL/FL
kernels whose MHBD reads must keep their finishes (test_schemes.py)."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.afe import apply_afe
from repro.core.dlbc import apply_dcafe, apply_dlbc
from repro.core.ir import (
    Assign, Async, Call, Compute, Finish, ForLoop, If, MethodDef, Program,
    Seq, Skip, binop, const, expr, seq, var,
)
from repro.core.lc import apply_lc
from repro.core.runtime import run_program

HEAP_VARS = ("g0", "g1", "g2")


def bump(name, amount):
    return Compute(
        fn=lambda env, _n=name, _a=amount: env.set_heap(_n, env[_n] + _a),
        reads=frozenset({f"{name}[+]"}), writes=frozenset({f"{name}[+]"}),
        cost=0.3, label=f"{name}+={amount}")


@st.composite
def stmt_strategy(draw, depth, allow_call):
    choices = ["bump", "seq", "async", "finish"]
    if depth > 0:
        choices += ["loop", "if", "finish_async"]
    if allow_call:
        choices += ["call", "call"]
    kind = draw(st.sampled_from(choices))
    if kind == "bump" or depth <= 0:
        return bump(draw(st.sampled_from(HEAP_VARS)),
                    draw(st.integers(1, 3)))
    sub = lambda: draw(stmt_strategy(depth=depth - 1, allow_call=allow_call))
    if kind == "seq":
        return seq(sub(), sub())
    if kind == "async":
        return Async(body=sub())
    if kind == "finish":
        return Finish(body=sub())
    if kind == "finish_async":
        return Finish(body=Async(body=sub()))
    if kind == "loop":
        return ForLoop(loopvar=f"i{depth}", lo=const(0),
                       hi=const(draw(st.integers(1, 3))), step=const(1),
                       body=sub())
    if kind == "if":
        thr = draw(st.integers(0, 1))
        return If(
            cond=expr(lambda env, _t=thr: env["g0"] >= _t, "g0",
                      label=f"g0>={thr}"),
            then=sub(), els=sub())
    if kind == "call":
        return If(
            cond=expr(lambda env: env["d"] > 0, "d", label="d>0"),
            then=Call(callee="rec",
                      args=(binop("-", var("d"), const(1)),)),
        )
    raise AssertionError(kind)


@st.composite
def program_strategy(draw):
    main_body = draw(stmt_strategy(depth=3, allow_call=False))
    rec_body = draw(stmt_strategy(depth=2, allow_call=True))
    rec = MethodDef(name="rec", params=("d",), body=rec_body)
    main = MethodDef(
        name="main", params=(),
        body=seq(main_body, Call(callee="rec", args=(const(2),))))
    return Program(methods=(main, rec))


def fresh_heap():
    return {"g0": 0, "g1": 0, "g2": 0}


SCHEMES = {
    "AFE": lambda p: apply_afe(p)[0],
    "LC": apply_lc,
    "DLBC": apply_dlbc,
    "DCAFE": lambda p: apply_dcafe(p)[0],
}


@pytest.mark.parametrize("scheme", sorted(SCHEMES))
@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(prog=program_strategy(), workers=st.sampled_from([1, 3]))
def test_scheme_preserves_semantics(scheme, prog, workers):
    base = run_program(prog, n_workers=workers, heap=fresh_heap(),
                       max_events=2_000_000)
    assert base.ok, base.error
    transformed = SCHEMES[scheme](prog)
    out = run_program(transformed, n_workers=workers, heap=fresh_heap(),
                      max_events=2_000_000)
    assert out.ok, out.error
    for k in fresh_heap():
        assert out.heap[k] == base.heap[k], (scheme, k)
    # NOTE: no per-program finish-count assertion here — the paper's own
    # Finish-If Interchange (Fig. 4 #1) legally raises the dynamic count
    # when the guard is false (the finish becomes unconditional).  The
    # count-reduction claims are asserted on the paper's kernels in
    # test_schemes.py, matching Fig. 10.


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(prog=program_strategy())
def test_afe_halts_and_is_idempotent_on_counts(prog):
    p1, rep1 = apply_afe(prog)
    p2, rep2 = apply_afe(p1)
    r1 = run_program(p1, n_workers=2, heap=fresh_heap(),
                     max_events=2_000_000)
    r2 = run_program(p2, n_workers=2, heap=fresh_heap(),
                     max_events=2_000_000)
    assert r1.ok and r2.ok
    for k in fresh_heap():
        assert r1.heap[k] == r2.heap[k]
