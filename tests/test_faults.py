"""Exception semantics + fault injection (repro.sched.faults) across
every adopter surface.

The paper's exception extension, made testable: AFE may move WHERE a
join happens but never WHETHER an exception surfaces.  These tests pin

* **FaultPlan determinism** — ``every=N`` makes the injection COUNT a
  pure function of the poke count (no thread-interleaving dependence);
  rate-based plans are seed-deterministic;
* **RetryPolicy** — deterministic backoff+jitter, telemetry bumps per
  retry, unwrapped propagation after the budget;
* **executor fault semantics** — MultipleExceptions carries per-task
  cause/range/site, fail_fast cancels siblings with exact
  ``spawns == completions + cancelled`` accounting, worker death loses
  no work, and ``FinishScope.wait(timeout=)`` returns a typed
  JoinOutcome distinguishing "timed out" from "done with failures";
* **adopters** — checkpoint shard writes retry without aborting the
  save (and a permanent failure can never COMMIT); the batcher contains
  a poisoned request per-slot while its neighbour decodes bitwise
  identically to a fault-free run; tenant SLO deadlines expire stale
  requests without breaking spawns == joins.

(EP shard-loss degradation needs a multi-device mesh and lives in the
``tests/test_ep.py`` subprocess suite.)
"""

import pathlib
import threading
import time

import jax
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.models import model as MDL
from repro.sched import (
    MultipleExceptions, ThreadExecutor, WorkStealingExecutor,
)
from repro.sched.executors import JoinOutcome
from repro.sched.faults import (
    FaultPlan, FaultSpec, InjectedFault, RetryPolicy, injected_faults,
)
from repro.serve.batcher import ContinuousBatcher, Request

EXECUTORS = [ThreadExecutor, WorkStealingExecutor]


# -- FaultPlan determinism ---------------------------------------------------

def test_fault_plan_every_n_count_is_poke_deterministic():
    """``every=N`` fires on exactly every Nth poke of the site: the
    injection count over M pokes is M // N regardless of which threads
    poked — the property the exact conservation gates rest on."""
    plan = FaultPlan([FaultSpec(site="sched.item", kind="raise", every=7)],
                     seed=0)
    raised = 0
    for _ in range(100):
        try:
            plan.poke("sched.item")
        except InjectedFault:
            raised += 1
    assert raised == 100 // 7
    assert plan.injected_total() == raised
    assert plan.injected_total(site="sched.item") == raised
    assert plan.injected_total(site="other") == 0


def test_fault_plan_every_n_count_deterministic_across_threads():
    plan = FaultPlan([FaultSpec(site="sched.item", kind="raise", every=5)],
                     seed=3)
    raised = []
    lock = threading.Lock()

    def poke_some(k):
        for _ in range(k):
            try:
                plan.poke("sched.item")
            except InjectedFault:
                with lock:
                    raised.append(1)

    threads = [threading.Thread(target=poke_some, args=(25,))
               for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(raised) == 100 // 5 == plan.injected_total()


def test_fault_plan_rate_is_seed_deterministic():
    def run(seed):
        plan = FaultPlan([FaultSpec(site="s", kind="raise", rate=0.3)],
                         seed=seed)
        fired = []
        for i in range(50):
            try:
                plan.poke("s")
                fired.append(0)
            except InjectedFault:
                fired.append(1)
        return fired

    assert run(7) == run(7)          # same seed, same sequence
    assert run(7) != run(8)          # different seed, different draws


def test_fault_plan_max_injections_caps():
    plan = FaultPlan([FaultSpec(site="s", kind="raise", every=2,
                                max_injections=3)], seed=0)
    raised = 0
    for _ in range(40):
        try:
            plan.poke("s")
        except InjectedFault:
            raised += 1
    assert raised == 3 == plan.injected_total()


def test_fault_plan_validates_specs():
    with pytest.raises(ValueError):
        FaultSpec(site="s", kind="nope", every=1)
    with pytest.raises(ValueError):
        FaultSpec(site="s", kind="raise")  # neither every nor rate


# -- RetryPolicy -------------------------------------------------------------

def test_retry_policy_delay_deterministic_and_bounded():
    p = RetryPolicy(attempts=5, base_delay_s=0.01, max_delay_s=0.05,
                    seed=42)
    q = RetryPolicy(attempts=5, base_delay_s=0.01, max_delay_s=0.05,
                    seed=42)
    for attempt in range(1, 5):
        for key in (0, 1, 7):
            d1, d2 = p.delay_s(attempt, key), q.delay_s(attempt, key)
            assert d1 == d2                      # seeded, reproducible
            # capped base, with up to +jitter on top
            assert 0.0 <= d1 <= 0.05 * (1 + p.jitter)
    # different keys de-correlate (thundering-herd protection)
    assert p.delay_s(3, 0) != p.delay_s(3, 1)
    # zero base = never sleep (the test/bench default)
    assert RetryPolicy(attempts=3).delay_s(2, 5) == 0.0


def test_retry_policy_runs_and_counts_retries():
    from repro.sched import SchedTelemetry
    tel = SchedTelemetry()
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    p = RetryPolicy(attempts=3)  # base_delay_s=0 → no sleeping in tests
    assert p.run(flaky, key=0, site="t", telemetry=tel) == "ok"
    assert len(calls) == 3
    assert tel.retries == 2


def test_retry_policy_exhaustion_propagates_unwrapped():
    p = RetryPolicy(attempts=2)

    def always():
        raise OSError("permanent")

    with pytest.raises(OSError, match="permanent"):
        p.run(always)


# -- executor fault semantics ------------------------------------------------

@pytest.mark.parametrize("cls", EXECUTORS)
def test_multiple_exceptions_carry_cause_range_and_site(cls):
    ex = cls(n_workers=2)
    try:
        def fn(i):
            if i % 4 == 0:
                raise KeyError(i)

        with pytest.raises(MultipleExceptions) as ei:
            ex.run_loop(list(range(20)), fn, policy="lc")
        me = ei.value
        assert me.count == 5
        assert me.__cause__ is me.errors[0].exc
        for err in me.errors:
            assert isinstance(err.exc, KeyError)
            assert err.site == "sched.item"
            assert 0 <= err.lo < err.hi <= 20    # the raising item's range
            assert "KeyError" in err.summary()
            assert "KeyError" in err.tb          # traceback preserved
    finally:
        ex.shutdown()


@pytest.mark.parametrize("cls", EXECUTORS)
def test_fail_fast_cancels_siblings_with_exact_accounting(cls):
    """fail_fast: the first raising chunk cancels its siblings via the
    scope's CancelToken; cancelled tasks/items are ACCOUNTED, so the
    conservation gate ``spawns == completions + cancelled`` still
    closes."""
    ex = cls(n_workers=3)
    try:
        n = 400

        def fn(i):
            if i == 0:
                raise ValueError("poison")
            time.sleep(0.0002)

        with pytest.raises(MultipleExceptions):
            with ex.finish(fail_mode="fail_fast") as scope:
                ex.run_loop(list(range(n)), fn, policy="dcafe",
                            scope=scope)
        t = ex.telemetry
        assert t.errors >= 1
        assert t.spawns == t.completions + t.cancelled, (
            t.spawns, t.completions, t.cancelled)
        assert ex.idle_workers() == ex.n_workers
    finally:
        ex.shutdown()


@pytest.mark.parametrize("cls", EXECUTORS)
def test_injected_faults_conserved_exactly(cls):
    """The chaos gate in miniature: injected == recorded == collected,
    exactly, under the default run_to_completion mode."""
    ex = cls(n_workers=3)
    try:
        plan = FaultPlan([FaultSpec(site="sched.item", kind="raise",
                                    every=9)], seed=5)
        collected = 0
        with injected_faults(plan):
            try:
                with ex.finish() as scope:
                    ex.run_loop(list(range(100)), lambda i: None,
                                policy="dcafe", scope=scope)
            except MultipleExceptions as e:
                collected = e.count
        assert collected == plan.injected_total() == ex.telemetry.errors
        assert collected > 0
    finally:
        ex.shutdown()


@pytest.mark.parametrize("cls", EXECUTORS)
def test_worker_death_loses_no_work(cls):
    """A worker dying mid-run (fault hook) re-queues/re-places its
    claimed work: every item still executes, deaths are counted, and
    the loop completes with the surviving workers."""
    ex = cls(n_workers=3)
    try:
        plan = FaultPlan([FaultSpec(site="sched.worker",
                                    kind="worker_death", every=2,
                                    max_injections=2)], seed=0)
        lock = threading.Lock()
        seen = []

        def fn(i):
            with lock:
                seen.append(i)
            time.sleep(0.0005)

        with injected_faults(plan):
            with ex.finish() as scope:
                ex.run_loop(list(range(60)), fn, policy="dcafe",
                            scope=scope)
        assert sorted(seen) == list(range(60))   # nothing lost
        assert ex.telemetry.worker_deaths == 2
        assert ex.idle_workers() == ex.n_workers - 2
    finally:
        ex.shutdown()


@pytest.mark.parametrize("cls", EXECUTORS)
def test_finish_scope_wait_timeout_is_typed(cls):
    """``wait(timeout=)`` distinguishes "timed out" (pending work, no
    join counted, scope reusable) from "done"."""
    ex = cls(n_workers=1)
    try:
        release = threading.Event()

        def slow():
            release.wait(timeout=10)

        with pytest.raises(MultipleExceptions):
            # exercise "done with failures" on the same scope type
            with ex.finish() as probe:
                probe.add([ex.submit(lambda: (_ for _ in ()).throw(
                    RuntimeError("x")))])

        scope = ex.finish()
        scope.add([ex.submit(slow)])
        out = scope.wait(timeout=0.05)
        assert isinstance(out, JoinOutcome)
        assert out.status == "timeout" and out.pending == 1
        assert ex.telemetry.joins == 1           # only the probe's join
        release.set()
        out2 = scope.wait(timeout=10)
        assert out2.status == "done" and not out2.errors
        out2.raise_if_failed()                   # no-op on success
        assert ex.telemetry.joins == 2
    finally:
        release.set()
        ex.shutdown()


def test_join_outcome_raise_if_failed():
    from repro.sched.executors import TaskError
    err = TaskError(exc=ValueError("boom"), lo=3, hi=4)
    out = JoinOutcome(status="failed", errors=(err,), error_count=1)
    assert out.failed
    with pytest.raises(MultipleExceptions) as ei:
        out.raise_if_failed()
    assert ei.value.count == 1


# -- checkpoint adopter ------------------------------------------------------

@pytest.fixture
def tree():
    return {"a": np.arange(12.0), "b": {"c": np.ones((3, 3)),
                                        "d": np.zeros(5)}}


def test_ckpt_transient_shard_faults_retried_away(tmp_path, tree):
    plan = FaultPlan([FaultSpec(site="ckpt.shard", kind="raise", every=2,
                                max_injections=2)], seed=1)
    with injected_faults(plan):
        with CheckpointManager(str(tmp_path), sched_policy="dcafe") as mgr:
            mgr.save(0, tree, blocking=True)
    assert mgr.latest_step() == 0                # published despite faults
    # every injection caused exactly one retry (attempts=3 covers the
    # worst case of both injections landing on one shard)
    assert mgr.telemetry.retries == plan.injected_total() >= 1
    step, got = mgr.restore(0)
    assert step == 0
    np.testing.assert_array_equal(got["a"], tree["a"])


@pytest.mark.parametrize("policy", ["dcafe", "lc"])
def test_ckpt_permanent_shard_failure_never_commits(tmp_path, tree,
                                                    policy):
    """Exhausted retries fail the PUBLISH (escaped-join and per-loop
    paths alike): no COMMIT appears and the temp dir is left for
    forensics."""
    plan = FaultPlan([FaultSpec(site="ckpt.shard", kind="raise",
                                every=1)], seed=1)
    with injected_faults(plan):
        mgr = CheckpointManager(str(tmp_path), sched_policy=policy,
                                retry=RetryPolicy(attempts=2))
        with pytest.raises(RuntimeError, match="shard write"):
            mgr.save(0, tree, blocking=True)
        mgr.close()
    assert mgr.latest_step() is None             # nothing COMMITted
    assert list(pathlib.Path(tmp_path).glob("tmp_*"))  # forensics dir


# -- serving adopter ---------------------------------------------------------

def _serve_cfg():
    return ModelConfig(name="faults-serve", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab=64)


@pytest.fixture(scope="module")
def serve_params():
    return MDL.init_params(_serve_cfg(), jax.random.PRNGKey(0))


def _reqs():
    return [Request(rid=i, prompt=[1 + i % 5, 2, 3], max_new=6,
                    arrive_step=i // 2) for i in range(8)]


def test_batcher_contains_poisoned_requests(serve_params):
    """A poisoned request frees its slot and is retried then failed —
    the loop finishes, every request is accounted (done or failed), and
    spawns == joins survives the failure path."""
    cfg = _serve_cfg()
    plan = FaultPlan([FaultSpec(site="serve.request", kind="raise",
                                every=7)], seed=3)
    with injected_faults(plan):
        b = ContinuousBatcher(cfg, serve_params, n_slots=4, cache_len=64,
                              retry=RetryPolicy(attempts=2))
        stats = b.run(_reqs())
    t = b.sched.telemetry
    assert stats.failed > 0
    assert stats.failed + len(stats.latencies) == 8
    assert t.spawns == t.joins                   # conservation intact
    assert t.errors == plan.injected_total()
    assert t.errors_by_site.get("serve.request") == t.errors


def test_batcher_neighbour_decodes_bitwise_identically(serve_params):
    """Refill-mid-decode under faults: the requests that survive a
    poisoned neighbour decode EXACTLY the tokens they decode in a
    fault-free run — per-slot cache isolation holds through failure,
    eviction, and refill."""
    cfg = _serve_cfg()
    ref = _reqs()
    clean = ContinuousBatcher(cfg, serve_params, n_slots=2, cache_len=64)
    clean.run(ref)
    want = {r.rid: list(r.tokens) for r in ref if r.done_step is not None}
    assert len(want) == 8

    plan = FaultPlan([FaultSpec(site="serve.request", kind="raise",
                                every=5)], seed=9)
    faulted = _reqs()
    with injected_faults(plan):
        b = ContinuousBatcher(cfg, serve_params, n_slots=2, cache_len=64,
                              retry=RetryPolicy(attempts=1))
        b.run(faulted)
    done = [r for r in faulted if r.done_step is not None]
    assert done, "no request survived — fault rate too high for the test"
    assert b.stats.failed > 0, "no request failed — poke cadence drifted"
    for r in done:
        assert list(r.tokens) == want[r.rid], (
            f"request {r.rid} decoded differently next to a poisoned "
            f"neighbour")


def test_batcher_slo_deadline_expires_stale_requests(serve_params):
    cfg = _serve_cfg()
    b = ContinuousBatcher(cfg, serve_params, n_slots=2, cache_len=64,
                          slos={"default": 3})
    stats = b.run([Request(rid=i, prompt=[1, 2], max_new=20)
                   for i in range(4)])
    t = b.sched.telemetry
    assert stats.expired == 4                    # all far past a 3-step SLO
    assert t.spawns == t.joins
    assert b.registry is None                    # single-queue spelling


def test_batcher_tenant_slo_spellings_agree(serve_params):
    cfg = _serve_cfg()
    b = ContinuousBatcher(cfg, serve_params, n_slots=2, cache_len=64,
                          tenants={"a": 1.0, "b": 1.0}, slos={"a": 3})
    assert b.registry.get("a").slo_steps == 3
    assert b._slo_of("a") == 3 and b._slo_of("b") == 0
