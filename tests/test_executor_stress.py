"""Deterministic stress tests for ThreadExecutor / WorkStealingExecutor +
FinishScope: N producer threads × M tasks, with injected exceptions.

These guard the AFE CI gate from flaking: the gate counts spawns/joins at
quiescence, so a lost task, a silently-dead worker thread, or a racy
counter increment shows up there as a phantom regression.  Invariants:

* exactly ONE join per finish scope (the aggressive-finish-elimination
  contract), even when tasks raise;
* no lost task — every submitted task's done event fires, every item of
  every concurrent ``run_loop`` executes exactly once;
* telemetry conservation at quiescence — ``spawns == completions``
  (every spawned task finished), ``errors`` counts exactly the injected
  raises, and the pool's idle count returns to ``n_workers``;
* the pool stays functional after exceptions (workers survive — before
  containment, a raising task silently killed its worker thread and
  every later join of a full pool would hang).

The work-stealing lanes add the adaptive-grain invariants: *work
conservation across splits* (every index of every range executes exactly
once no matter how thieves and helpers carved it up) and *locked-counter
consistency* (``steals == sum(steal_victims)``, ``splits <= steals`` —
the counters are bumped under ``telemetry.lock``, so concurrent steals
must never lose an increment).

Deterministic: fixed producer/task counts, seeded cost patterns and a
fixed injection pattern; the only waits are bounded event waits on work
the pool must finish.
"""

import random
import threading
import time

import pytest

from repro.sched import (
    DCAFE, DLBC, MultipleExceptions, ThreadExecutor, WorkStealingExecutor,
)
from repro.sched.faults import FaultPlan, FaultSpec, injected_faults

EXECUTORS = [ThreadExecutor, WorkStealingExecutor]
N_PRODUCERS = 4
M_TASKS = 60
RAISE_EVERY = 5  # every 5th injected task raises


def _run_producers(target):
    threads = [threading.Thread(target=target, args=(p,))
               for p in range(N_PRODUCERS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "producer deadlocked"


@pytest.mark.parametrize("cls", EXECUTORS)
def test_concurrent_run_loops_lose_no_items(cls):
    """N producers drive run_loop on ONE shared pool, each under its own
    DCAFE finish scope: every item runs exactly once, one join per
    scope, and spawns == completions at quiescence."""
    ex = cls(n_workers=3)
    try:
        lock = threading.Lock()
        seen = []

        def produce(p):
            items = [(p, i) for i in range(M_TASKS)]

            def fn(item):
                with lock:
                    seen.append(item)

            with ex.finish() as scope:
                ex.run_loop(items, fn, policy="dcafe", scope=scope)

        _run_producers(produce)
        assert sorted(seen) == sorted(
            (p, i) for p in range(N_PRODUCERS) for i in range(M_TASKS))
        t = ex.telemetry
        assert t.joins == N_PRODUCERS          # exactly one per scope
        assert t.completions == t.spawns       # quiescence conservation
        assert t.errors == 0
        assert t.serial_items + t.parallel_items == N_PRODUCERS * M_TASKS
        assert ex.idle_workers() == ex.n_workers
    finally:
        ex.shutdown()


@pytest.mark.parametrize("cls", EXECUTORS)
def test_injected_exceptions_lose_no_tasks_and_kill_no_workers(cls):
    """N producers submit M tasks each; every RAISE_EVERY-th raises.
    All done events fire, errors are counted exactly, and the pool still
    schedules (workers survived containment)."""
    ex = cls(n_workers=3)
    try:
        lock = threading.Lock()
        ran = []
        events = {}

        def produce(p):
            evs = []
            for i in range(M_TASKS):
                def task(p=p, i=i):
                    with lock:
                        ran.append((p, i))
                    if i % RAISE_EVERY == 0:
                        raise ValueError(f"injected {p}/{i}")

                evs.append(ex.submit(task))
            with lock:
                events[p] = evs

        _run_producers(produce)
        for p, evs in events.items():
            for i, ev in enumerate(evs):
                assert ev.wait(timeout=30), f"lost task {p}/{i}"
        t = ex.telemetry
        n_total = N_PRODUCERS * M_TASKS
        n_raised = N_PRODUCERS * len(range(0, M_TASKS, RAISE_EVERY))
        assert sorted(ran) == sorted(
            (p, i) for p in range(N_PRODUCERS) for i in range(M_TASKS))
        assert t.spawns == n_total
        assert t.completions == n_total        # raising tasks complete too
        assert t.errors == n_raised
        assert ex.idle_workers() == ex.n_workers  # nobody died mid-task

        # the pool is still fully functional: a post-stress loop with a
        # finish scope joins promptly (pre-containment this hung once
        # enough workers had been killed by raises)
        done = []
        with ex.finish() as scope:
            ex.run_loop(list(range(10)), done.append, policy="dcafe",
                        scope=scope)
        assert sorted(done) == list(range(10))
        assert t.joins == 1  # the one scope join above
    finally:
        ex.shutdown()


@pytest.mark.parametrize("cls", EXECUTORS)
def test_run_loop_spawned_chunk_survives_raising_item(cls):
    """An item raising inside a spawned chunk must not drop the chunk's
    remaining items: every spawned item is attempted, raises are counted
    in telemetry.errors, and the per-loop join rethrows them all as ONE
    MultipleExceptions (the X10 finish contract — AFE may move the join,
    never lose the exception).  (LC spawns every chunk, so no
    caller-side items propagate here.)"""
    ex = cls(n_workers=2)
    try:
        lock = threading.Lock()
        attempted = []

        def fn(i):
            with lock:
                attempted.append(i)
            if i % 3 == 0:
                raise ValueError(f"injected {i}")

        with pytest.raises(MultipleExceptions) as ei:
            ex.run_loop(list(range(30)), fn, policy="lc")
        assert sorted(attempted) == list(range(30))  # nothing dropped
        n_raised = len(range(0, 30, 3))
        assert ei.value.count == n_raised           # none lost, none extra
        assert all(isinstance(e.exc, ValueError) for e in ei.value.errors)
        assert ex.telemetry.errors == n_raised
        assert ex.telemetry.parallel_items == 30
    finally:
        ex.shutdown()


def _steal_counters_consistent(t):
    """PR-3 locked-counter contract, extended to the steal counters: the
    histogram must add up to the steal count exactly (both are bumped in
    the same ``telemetry.lock`` hold), and splits are a subset of
    steals."""
    assert t.steals == sum(t.steal_victims.values()), (
        t.steals, dict(t.steal_victims))
    assert 0 <= t.splits <= t.steals
    assert all(v >= 0 for v in t.steal_victims.values())


def test_work_stealing_skewed_ranges_conserve_work():
    """N producers × seeded skewed range loops on ONE stealing pool, with
    injected exceptions: every index of every producer's range executes
    exactly once — across however many steal-splits and helper claims
    carved it — one join per scope, spawns == completions, and the steal
    counters stay consistent under concurrent bumping."""
    n_items = 48
    rng = random.Random(0xDCAFE)
    # seeded skewed costs: a contiguous heavy head per producer, heavy
    # positions jittered so producers collide on different workers
    costs = {}
    for p in range(N_PRODUCERS):
        head = rng.randrange(4, 10)
        costs[p] = [1.5 if i < head else 0.1 for i in range(n_items)]

    ex = WorkStealingExecutor(n_workers=3)
    try:
        lock = threading.Lock()
        seen = []

        def boom():
            raise RuntimeError("injected")

        def produce(p):
            def fn(item):
                pp, i = item
                time.sleep(costs[pp][i] / 1e3)
                with lock:
                    seen.append(item)

            items = [(p, i) for i in range(n_items)]
            # DCAFE = DLBC chunking + escaped joins; per-producer grain
            # controller adapts across the three loops
            policy = DCAFE()
            # the scope's ONE join rethrows the booms as an aggregate —
            # exactly 6 per producer (2 per loop × 3 loops), none lost
            with pytest.raises(MultipleExceptions) as ei:
                with ex.finish() as scope:
                    for _ in range(3):
                        # injected failures ride along as scoped single
                        # tasks (caller-chunk raises would abort the loop
                        # like a plain for loop — that contract has its
                        # own test)
                        scope.add([ex.submit(boom), ex.submit(boom)])
                        ex.run_loop(items, fn, policy=policy, scope=scope)
            assert ei.value.count == 3 * 2

        _run_producers(produce)
        want = sorted((p, i) for p in range(N_PRODUCERS)
                      for i in range(n_items)) * 3
        assert sorted(seen) == sorted(want)  # exactly once per loop
        t = ex.telemetry
        assert t.joins == N_PRODUCERS  # one join per scope, 3 loops each
        assert t.completions == t.spawns
        assert t.serial_items + t.parallel_items == len(want)
        assert t.errors == N_PRODUCERS * 3 * 2  # every boom contained
        _steal_counters_consistent(t)
        assert set(t.steal_victims) <= set(range(ex.n_workers))
        assert ex.idle_workers() == ex.n_workers
    finally:
        ex.shutdown()


def test_work_stealing_victim_scan_not_worker0_hotspot():
    """The steal-victim scan starts at a randomised index: over many
    forced steals the histogram must hit more than one victim (the old
    deterministic scan always hammered the lowest live worker id)."""
    ex = WorkStealingExecutor(n_workers=4)
    try:
        lock = threading.Lock()
        ran = []

        def fn(i):
            time.sleep(0.002)  # heavy enough that thieves must split
            with lock:
                ran.append(i)

        for _ in range(6):
            ex.run_loop(list(range(24)), fn, policy=DLBC())
        t = ex.telemetry
        assert sorted(ran) == sorted(list(range(24)) * 6)
        _steal_counters_consistent(t)
        if t.steals >= 8:  # enough samples to judge the spread
            assert len(t.steal_victims) > 1, dict(t.steal_victims)
    finally:
        ex.shutdown()


def test_work_stealing_producers_of_single_tasks_rebalance():
    """N producers × M single submits (1-item ranges): whole-task
    stealing still drains everything, latches all fire, and the counter
    contract holds — the grain machinery must not strand scalar tasks."""
    ex = WorkStealingExecutor(n_workers=3)
    try:
        lock = threading.Lock()
        ran = []
        events = {}

        def produce(p):
            evs = []
            for i in range(M_TASKS):
                def task(p=p, i=i):
                    with lock:
                        ran.append((p, i))
                    if i % RAISE_EVERY == 0:
                        raise ValueError(f"injected {p}/{i}")

                evs.append(ex.submit(task))
            with lock:
                events[p] = evs

        _run_producers(produce)
        for p, evs in events.items():
            for i, ev in enumerate(evs):
                assert ev.wait(timeout=30), f"lost task {p}/{i}"
        t = ex.telemetry
        assert t.spawns == t.completions == N_PRODUCERS * M_TASKS
        assert t.errors == N_PRODUCERS * len(range(0, M_TASKS, RAISE_EVERY))
        _steal_counters_consistent(t)
    finally:
        ex.shutdown()


@pytest.mark.parametrize("cls", EXECUTORS)
def test_finish_scope_joins_once_despite_raises(cls):
    """A scope over raising tasks joins exactly once (the join is
    counted BEFORE the rethrow), never hangs, and surfaces every error
    in one MultipleExceptions."""
    ex = cls(n_workers=2)
    try:
        def boom():
            raise RuntimeError("injected")

        with pytest.raises(MultipleExceptions) as ei:
            with ex.finish() as scope:
                scope.add([ex.submit(boom) for _ in range(8)])
        assert ei.value.count == 8
        t = ex.telemetry
        assert t.joins == 1
        assert t.errors == 8
        assert t.completions == t.spawns == 8
    finally:
        ex.shutdown()


def test_fault_seed_sweep_conserves_exceptions():
    """Hypothesis sweep over FaultPlan seeds × injection cadence ×
    executor × fail mode: however the grain controller, thieves, and
    helpers interleave the chunks, exception-count conservation holds
    EXACTLY — every injected fault is recorded in ``telemetry.errors``
    and collected into the scope's MultipleExceptions (none lost, none
    double-counted), and task accounting closes as
    ``spawns == completions + cancelled``."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 2 ** 16 - 1), every=st.integers(3, 13),
           cls_i=st.integers(0, 1), fail_fast=st.booleans())
    def run(seed, every, cls_i, fail_fast):
        ex = EXECUTORS[cls_i](n_workers=3)
        try:
            plan = FaultPlan([FaultSpec(site="sched.item", kind="raise",
                                        every=every)], seed=seed)
            mode = "fail_fast" if fail_fast else "run_to_completion"
            collected = 0
            with injected_faults(plan):
                try:
                    with ex.finish(fail_mode=mode) as scope:
                        ex.run_loop(list(range(64)), lambda i: None,
                                    policy="dcafe", scope=scope)
                except MultipleExceptions as e:
                    collected = e.count
            t = ex.telemetry
            injected = plan.injected_total()
            # exact conservation, independent of interleaving: only
            # spawned items poke the hook, so every injection is both
            # recorded and collected
            assert collected == injected == t.errors, (
                collected, injected, t.errors)
            assert t.spawns == t.completions + t.cancelled, (
                t.spawns, t.completions, t.cancelled)
            if not fail_fast:
                assert t.cancelled == 0 and t.cancelled_items == 0
            assert ex.idle_workers() == ex.n_workers
        finally:
            ex.shutdown()

    run()
