"""Property-based tests for the weighted-DLBC admission layer and the
Fig. 6 chunk arithmetic.

Each property is a plain ``check_*`` function driven two ways:

* **hypothesis** (random strategies, shrinking) — extends the
  ``importorskip`` pattern of ``test_afe_property.py``: the hypothesis
  section only exists when the library is importable (CI installs it via
  the ``dev`` extra; zero deselects there), so an environment without it
  still runs the seeded drivers below instead of losing the coverage;
* **seeded numpy sweeps** — deterministic random cases that exercise the
  same checks everywhere.

Properties (the tenancy module's contract, see ``repro/sched/tenancy.py``):

(a) work conservation — no idle slot while any tenant queue is
    non-empty;
(b) weighted fairness — over any backlogged prefix, every tenant's
    admission count stays within ±1 of its weight share (exact at full
    cycles of ``W = sum(weights)``);
(c) no starvation — a request at position ``p`` in tenant ``i``'s queue
    is admitted within ``(p + 1) * ceil(W / w_i)`` admissions;
(d) ``chunk_plan`` partitions exactly, the caller keeps the smallest
    chunk, and the remainder spreads one-per-chunk from the front.
"""

import math

import numpy as np
import pytest

from repro.sched import SlotExecutor, TenantRegistry, WeightedRefillPolicy
from repro.sched.policy import chunk_plan

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI always has hypothesis
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# The properties, as plain checkable functions
# ---------------------------------------------------------------------------


def make_registry(weights, depths):
    reg = TenantRegistry(
        {f"t{i}": float(w) for i, w in enumerate(weights)})
    for i, (t, d) in enumerate(zip(reg, depths)):
        t.queue.extend((i, j) for j in range(d))
    return reg


def check_work_conservation(weights, depths, n_slots, n_busy):
    """After a refill, an idle slot remains only if every queue is empty
    — and admissions are exactly ``min(idle, queued)`` (DLBC base)."""
    reg = make_registry(weights, depths)
    slots = [None] * n_slots
    for i in range(min(n_busy, n_slots)):
        slots[i] = "busy"
    idle = n_slots - min(n_busy, n_slots)
    queued = sum(depths)
    ex = SlotExecutor(n_slots, policy="wdlbc")
    placements = ex.refill(slots, reg)
    assert len(placements) == min(idle, queued)
    assert reg.total_queued() == queued - len(placements)
    taken = [s for s, _ in placements]
    assert len(set(taken)) == len(taken)               # distinct slots
    assert all(slots[s] is None for s in taken)        # only idle ones
    # conservation restated: slots left idle ⇒ nothing left queued
    if len(placements) < idle:
        assert reg.total_queued() == 0
    # telemetry conservation: per-tenant spawns sum to global spawns
    assert ex.telemetry.tenant_totals()["spawns"] == ex.telemetry.spawns \
        == len(placements)


def check_fair_share(weights, extra):
    """All tenants backlogged: every prefix of the admission stream keeps
    each tenant within ±1 admission of its weight share; full cycles of
    ``W`` are exact."""
    W = sum(weights)
    n = W + extra  # at least one full cycle, plus a partial one
    reg = make_registry(weights, [n] * len(weights))
    picks = WeightedRefillPolicy().pick(reg, n)
    assert len(picks) == n
    counts = {t.name: 0 for t in reg}
    for m, (t, _) in enumerate(picks, 1):
        counts[t.name] += 1
        for i, w in enumerate(weights):
            ideal = m * w / W
            assert abs(counts[f"t{i}"] - ideal) <= 1.0, \
                (weights, m, counts, ideal)
    if extra == 0:  # exactly one cycle: shares are exact
        for i, w in enumerate(weights):
            assert counts[f"t{i}"] == w


def check_no_starvation(weights, depths):
    """Every queued request is admitted within its bound: position ``p``
    in tenant ``i``'s queue → at most ``(p+1) * ceil(W / w_i)`` total
    admissions before it runs."""
    reg = make_registry(weights, depths)
    W = sum(weights)
    total = sum(depths)
    picks = WeightedRefillPolicy().pick(reg, total)
    assert len(picks) == total  # work conservation, again
    admitted_at = {item: m for m, (_, item) in enumerate(picks)}
    for i, (w, d) in enumerate(zip(weights, depths)):
        bound_per_service = math.ceil(W / w)
        for p in range(d):
            at = admitted_at[(i, p)]
            assert at < (p + 1) * bound_per_service, \
                (weights, depths, i, p, at)
    # FIFO within each tenant
    for i, d in enumerate(depths):
        order = [admitted_at[(i, p)] for p in range(d)]
        assert order == sorted(order)


def check_single_tenant_fifo(depth, weight):
    reg = TenantRegistry({"solo": float(weight)})
    reg.get("solo").queue.extend(range(depth))
    picks = WeightedRefillPolicy().pick(reg, depth)
    assert [item for _, item in picks] == list(range(depth))
    assert reg.get("solo").deficit == 0.0


def check_chunk_plan(lo, n, idle):
    plan = chunk_plan(lo, lo + n, idle)
    tot = idle + 1
    eq, r = divmod(n, tot)
    # exact partition, in order
    pos = lo
    for a, b in plan.chunks:
        assert a == pos and b >= a
        pos = b
    assert pos == lo + n
    # caller keeps the smallest chunk
    caller_sz = plan.caller[1] - plan.caller[0]
    assert caller_sz == eq
    assert all(b - a >= caller_sz for a, b in plan.spawned)
    # remainder spread one-per-chunk from the front
    sizes = [b - a for a, b in plan.spawned]
    if eq > 0:
        assert sizes == [eq + 1] * r + [eq] * (tot - 1 - r)
    else:
        assert sizes == [1] * r


# ---------------------------------------------------------------------------
# hypothesis drivers (CI: installed via the dev extra, zero deselects)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:
    weights_st = st.lists(st.integers(1, 9), min_size=1, max_size=5)

    @settings(max_examples=120, deadline=None)
    @given(weights=weights_st,
           depths=st.lists(st.integers(0, 12), min_size=1, max_size=5),
           n_slots=st.integers(1, 12), n_busy=st.integers(0, 12))
    def test_hyp_work_conservation(weights, depths, n_slots, n_busy):
        depths = (depths + [0] * len(weights))[:len(weights)]
        check_work_conservation(weights, depths, n_slots, n_busy)

    @settings(max_examples=120, deadline=None)
    @given(weights=weights_st, extra=st.integers(0, 40))
    def test_hyp_fair_share_within_one(weights, extra):
        check_fair_share(weights, extra)

    @settings(max_examples=120, deadline=None)
    @given(weights=weights_st,
           depths=st.lists(st.integers(1, 10), min_size=1, max_size=5))
    def test_hyp_no_starvation(weights, depths):
        depths = (depths + [1] * len(weights))[:len(weights)]
        check_no_starvation(weights, depths)

    @settings(max_examples=80, deadline=None)
    @given(depth=st.integers(0, 50), weight=st.integers(1, 9))
    def test_hyp_single_tenant_fifo(depth, weight):
        check_single_tenant_fifo(depth, weight)

    @settings(max_examples=200, deadline=None)
    @given(lo=st.integers(0, 1000), n=st.integers(0, 5000),
           idle=st.integers(0, 64))
    def test_hyp_chunk_plan(lo, n, idle):
        check_chunk_plan(lo, n, idle)


# ---------------------------------------------------------------------------
# seeded sweeps (deterministic; run with or without hypothesis)
# ---------------------------------------------------------------------------


def test_seeded_work_conservation_sweep():
    rng = np.random.default_rng(0)
    for _ in range(150):
        nt = int(rng.integers(1, 6))
        weights = [int(w) for w in rng.integers(1, 9, size=nt)]
        depths = [int(d) for d in rng.integers(0, 12, size=nt)]
        check_work_conservation(weights, depths,
                                int(rng.integers(1, 12)),
                                int(rng.integers(0, 12)))


def test_seeded_fair_share_sweep():
    rng = np.random.default_rng(1)
    for _ in range(150):
        nt = int(rng.integers(1, 6))
        weights = [int(w) for w in rng.integers(1, 9, size=nt)]
        check_fair_share(weights, int(rng.integers(0, 40)))


def test_seeded_no_starvation_sweep():
    rng = np.random.default_rng(2)
    for _ in range(150):
        nt = int(rng.integers(1, 6))
        weights = [int(w) for w in rng.integers(1, 9, size=nt)]
        depths = [int(d) for d in rng.integers(1, 10, size=nt)]
        check_no_starvation(weights, depths)


def test_seeded_single_tenant_fifo_sweep():
    for depth, weight in [(0, 1), (1, 5), (17, 2), (50, 9)]:
        check_single_tenant_fifo(depth, weight)


def test_seeded_chunk_plan_sweep():
    rng = np.random.default_rng(3)
    for _ in range(300):
        check_chunk_plan(int(rng.integers(0, 1000)),
                         int(rng.integers(0, 5000)),
                         int(rng.integers(0, 64)))


def test_midrun_tenant_stats_share_global_denominators():
    """Conservation of the per-tenant stats denominators: a tenant first
    seen via ``submit()`` MID-RUN is backfilled to the global
    step/slot-step counts, so ``utilization`` is comparable across
    tenants regardless of when each first appeared (the skew this
    pins: late tenants used to integrate from their arrival, inflating
    their utilization denominator-relative)."""
    import jax

    from repro.configs.base import ModelConfig
    from repro.models import model as MDL
    from repro.serve.batcher import ContinuousBatcher, Request

    cfg = ModelConfig(name="mid", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    b = ContinuousBatcher(cfg, params, n_slots=2, cache_len=16,
                          policy="wdlbc", tenants={"early": 1.0})
    reqs = [Request(rid=0, prompt=[1, 2], max_new=4, arrive_step=0,
                    tenant="early"),
            # "late" does not exist in the registry until this arrives
            Request(rid=1, prompt=[3, 4], max_new=4, arrive_step=6,
                    tenant="late"),
            Request(rid=2, prompt=[5], max_new=3, arrive_step=9,
                    tenant="early")]
    b.run(reqs)
    late = b.tenant_stats["late"]
    assert late.first_step == 6  # created at its first submit
    for name, st in b.tenant_stats.items():
        # every tenant integrates the SAME denominators as the globals
        assert st.steps == b.stats.steps, name
        assert st.total_slot_steps == b.stats.total_slot_steps, name
    # numerators still conserve: per-tenant busy sums to global busy
    assert sum(st.busy_slot_steps for st in b.tenant_stats.values()) \
        == b.stats.busy_slot_steps
