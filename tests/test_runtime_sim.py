"""Runtime simulator invariants: determinism, clocks, exception (ME)
semantics, idle-worker intrinsic, deadlock detection."""

import pytest

from repro.core.errors import ExcValue
from repro.core.ir import (
    Assign, Async, Barrier, Call, Compute, Finish, ForLoop, If, MethodDef,
    NewClock, Program, Seq, Throw, TryCatch, const, expr, idle_workers, seq,
    var,
)
from repro.core.runtime import CostModel, run_program


def bump(name, amount=1, cost=0.5):
    return Compute(
        fn=lambda env, _n=name, _a=amount: env.set_heap(_n, env[_n] + _a),
        reads=frozenset({f"{name}[+]"}), writes=frozenset({f"{name}[+]"}),
        cost=cost, label=f"{name}+={amount}")


def prog_of(body, extra=()):
    return Program(methods=(MethodDef(name="main", params=(), body=body),)
                   + tuple(extra))


def test_determinism():
    body = Finish(body=ForLoop(
        loopvar="i", lo=const(0), hi=const(20), step=const(1),
        body=Async(body=bump("x"))))
    p = prog_of(body)
    runs = [run_program(p, n_workers=3, heap={"x": 0}) for _ in range(3)]
    assert len({r.time for r in runs}) == 1
    assert len({r.counters.asyncs for r in runs}) == 1
    assert all(r.heap["x"] == 20 for r in runs)


def test_clock_barrier_phases():
    """Phase 2 writes must observe every phase-1 write (BSP)."""

    def phase1(env):
        env["a"][env["i"]] = 1

    def phase2(env):
        env.set_heap("total", env["total"] + sum(env["a"]))

    body = seq(
        NewClock(target="c"),
        Finish(body=ForLoop(
            loopvar="i", lo=const(0), hi=const(4), step=const(1),
            body=Async(clocks=("c",), body=seq(
                Compute(fn=phase1, reads=frozenset({"i"}),
                        writes=frozenset({"a[i]"}), cost=1.0, label="p1"),
                Barrier(),
                Compute(fn=phase2, reads=frozenset({"a[*]", "total[+]"}),
                        writes=frozenset({"total[+]"}), cost=1.0,
                        label="p2"),
            )))),
    )
    r = run_program(prog_of(body), n_workers=2,
                    heap={"a": [0] * 4, "total": 0})
    assert r.ok, r.error
    # every phase-2 task saw all four phase-1 writes
    assert r.heap["total"] == 16


def test_exception_me_wrapping_and_sibling_survival():
    """An exception in one async does not kill siblings (paper §2.1)."""
    body = TryCatch(
        body=Finish(body=Seq((
            Async(body=Throw(exc_type="Boom")),
            Async(body=bump("survivor")),
        ))),
        exc_var="e",
        handler=Compute(
            fn=lambda env: env.set_heap(
                "types", tuple(x.type_name for x in env["e"].flatten())),
            reads=frozenset({"e"}), writes=frozenset({"types"}), cost=0.0,
            label="rec"),
        exc_types=("ME",),
    )
    r = run_program(prog_of(body), n_workers=2,
                    heap={"survivor": 0, "types": None})
    assert r.ok, r.error
    assert r.heap["survivor"] == 1  # sibling completed
    assert r.heap["types"] == ("Boom",)


def test_uncaught_exception_reported():
    r = run_program(prog_of(Throw(exc_type="Fatal")), n_workers=1, heap={})
    assert not r.ok
    assert "Fatal" in [e.type_name for e in r.error.flatten()]


def test_idle_workers_intrinsic_bounds():
    body = seq(
        Assign(target="w0", value=idle_workers()),
        Finish(body=ForLoop(
            loopvar="i", lo=const(0), hi=const(8), step=const(1),
            body=Async(body=bump("x", cost=5.0)))),
        Compute(fn=lambda env: env.set_heap("w_seen", env["w0"]),
                reads=frozenset({"w0"}), writes=frozenset({"w_seen"}),
                cost=0.0, label="rec"),
    )
    r = run_program(prog_of(body), n_workers=4, heap={"x": 0, "w_seen": -1})
    assert r.ok
    assert 0 <= r.heap["w_seen"] <= 4


def test_deadlock_detected():
    """A clocked async waiting forever must be flagged, not hang."""
    body = seq(
        NewClock(target="c"),
        # Spawned escaping task advances; nobody else ever does within the
        # finish (the parent holds registration but blocks at the join of
        # a DIFFERENT never-satisfied structure) — simplest reliable hang:
        # a task that waits on a clock where a sibling never arrives.
        Finish(body=Seq((
            Async(clocks=("c",), body=seq(Barrier(), bump("x"))),
            Async(clocks=("c",), body=Compute(
                fn=lambda env: None, reads=frozenset(),
                writes=frozenset(), cost=100.0, label="never_advances")),
        ))),
    )
    # Second task terminates (deregisters) → barrier releases; to force a
    # hang the second task must block forever instead — termination
    # deregistration makes THIS program live.  Assert liveness:
    r = run_program(prog_of(body), n_workers=2, heap={"x": 0})
    assert r.ok and r.heap["x"] == 1


def test_blocked_worker_helps_policy():
    """With help-first stealing, nested recursion completes even when the
    recursion depth exceeds the worker count."""
    rec = MethodDef(
        name="rec", params=("d",),
        body=If(
            cond=expr(lambda env: env["d"] > 0, "d", label="d>0"),
            then=Finish(body=Async(body=seq(
                bump("x"),
                Call(callee="rec",
                     args=(expr(lambda env: env["d"] - 1, "d",
                                label="d-1"),)),
            ))),
        ))
    main = MethodDef(name="main", params=(),
                     body=Call(callee="rec", args=(const(10),)))
    p = Program(methods=(main, rec))
    r = run_program(p, n_workers=2, heap={"x": 0})
    assert r.ok and r.heap["x"] == 10


def test_serial_elision_matches_parallel():
    from repro.core.runtime import serial_program

    body = Finish(body=ForLoop(
        loopvar="i", lo=const(0), hi=const(6), step=const(1),
        body=Async(body=bump("x"))))
    p = prog_of(body)
    r1 = run_program(p, n_workers=4, heap={"x": 0})
    r2 = run_program(serial_program(p), n_workers=1, heap={"x": 0})
    assert r1.heap["x"] == r2.heap["x"] == 6
    assert r2.counters.asyncs == 0
