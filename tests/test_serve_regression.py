"""Seeded end-to-end serving regression: a fixed request trace through
``ContinuousBatcher`` under ``lc``, ``dlbc``, and two-tenant
weighted-DLBC.

The admission ORACLE below is a pure-Python replica of the pre-refactor
scheduling semantics (written against the single-queue ``SlotExecutor``
before the tenant generalisation): DLBC admits into every idle slot at
every step (oldest request → lowest slot), LC waits for a fully idle
slot array, a placed request holds its slot for
``min(max_new, cache_len - 1)`` decode steps.  The batcher's recorded
admission trace must match the oracle step for step — if the executor
refactor moves a single admission, these goldens break.

The tenant layer is pinned two ways:

* single-tenant ``wdlbc`` must be *step-for-step identical* to plain
  ``dlbc`` (the deficit round-robin is FIFO-transparent for one queue);
* two-tenant ``wdlbc`` must match an independent reimplementation of
  the smoothed deficit-round-robin arithmetic.

Also covers the refill-mid-decode cache fix: per-slot cache positions
mean a request's decoded tokens are identical whether it runs alone or
is refilled into a slot while a neighbour is deep into its sequence.
"""

import jax
import pytest

from repro.configs.base import ModelConfig
from repro.models import model as MDL
from repro.serve.batcher import ContinuousBatcher, Request


def _cfg(vocab=128):
    return ModelConfig(name="serve-reg", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab=vocab)


@pytest.fixture(scope="module")
def params():
    return MDL.init_params(_cfg(), jax.random.PRNGKey(0))


def make_trace(with_tenants=False):
    """A fixed, seedless trace (hand-written so the goldens are stable)."""
    spec = [
        # (rid, arrive, max_new, tenant)
        (0, 0, 3, "a"), (1, 0, 5, "b"), (2, 0, 4, "a"), (3, 1, 2, "b"),
        (4, 2, 6, "a"), (5, 4, 2, "b"), (6, 4, 3, "a"), (7, 7, 5, "b"),
        (8, 8, 2, "a"), (9, 8, 4, "b"), (10, 12, 3, "a"), (11, 12, 2, "b"),
    ]
    return [Request(rid=r, prompt=[1, 2], max_new=m, arrive_step=t,
                    tenant=(ten if with_tenants else "default"))
            for r, t, m, ten in spec]


# ---------------------------------------------------------------------------
# The pre-refactor admission oracle (pure Python, no model, no sched pkg)
# ---------------------------------------------------------------------------


def oracle_trace(requests, n_slots, cache_len, policy,
                 weights=None, max_steps=10_000):
    """Simulate the serving loop's scheduling only.  Returns
    (admissions [(step, slot, rid, tenant)], utilization)."""
    pending = sorted(requests, key=lambda r: r.arrive_step)
    slots = [None] * n_slots       # rid or None
    remaining = {}                 # rid -> decode steps left
    queues = {}                    # tenant -> [request, ...]
    deficits = {}                  # tenant -> DRR credit
    order = list(weights) if weights else ["default"]
    for t in order:
        queues[t] = []
        deficits[t] = 0.0
    admissions, busy, total = [], 0, 0
    nxt = now = 0

    def queued():
        return sum(len(q) for q in queues.values())

    def pick_tenant():
        # independent smoothed-DRR reimplementation (weights=None → FIFO)
        if not weights:
            return "default"
        for t in order:
            if not queues[t]:
                deficits[t] = 0.0
        active = [t for t in order if queues[t]]
        w_total = sum(weights[t] for t in active)
        best = active[0]
        for t in active:
            deficits[t] += weights[t]
            if deficits[t] > deficits[best]:
                best = t
        deficits[best] -= w_total
        if len(queues[best]) == 1:
            deficits[best] = 0.0  # about to be served dry
        return best

    while (nxt < len(pending) or queued()
           or any(s is not None for s in slots)) and now < max_steps:
        while nxt < len(pending) and pending[nxt].arrive_step <= now:
            r = pending[nxt]
            queues.setdefault(r.tenant, [])
            deficits.setdefault(r.tenant, 0.0)
            if r.tenant not in order:
                order.append(r.tenant)
            queues[r.tenant].append(r)
            nxt += 1
        idle = [i for i, s in enumerate(slots) if s is None]
        if policy == "lc":
            k = min(len(idle), queued()) if len(idle) == n_slots else 0
        else:  # dlbc (weighted or not): every idle slot, every step
            k = min(len(idle), queued())
        for j in range(k):
            tenant = pick_tenant()
            r = queues[tenant].pop(0)
            slot = idle[j]
            slots[slot] = r.rid
            # Decode-step service time.  Every trace prompt is [1, 2]:
            # its single-token prefix prefills in the placement step's
            # prefill phase (chunk of 1 regardless of contention), so
            # the slot decodes that same step and holds for max_new
            # decode steps (the cache bound — slot_pos starts at
            # len(prompt) - 1 — is never hit at these max_new values).
            remaining[r.rid] = min(r.max_new,
                                   cache_len - len(r.prompt))
            admissions.append((now, slot, r.rid, r.tenant))
        active = [i for i, s in enumerate(slots) if s is not None]
        total += n_slots
        busy += len(active)
        for i in active:
            remaining[slots[i]] -= 1
            if remaining[slots[i]] <= 0:
                slots[i] = None
        now += 1
    return admissions, busy / max(1, total)


def run_batcher(params, policy, tenants=None, with_tenant_labels=False,
                n_slots=3, cache_len=16):
    b = ContinuousBatcher(_cfg(), params, n_slots=n_slots,
                          cache_len=cache_len, policy=policy,
                          tenants=tenants)
    b.run(make_trace(with_tenants=with_tenant_labels))
    return b


# ---------------------------------------------------------------------------
# Goldens
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["dlbc", "lc"])
def test_single_queue_admissions_match_prerefactor_oracle(params, policy):
    b = run_batcher(params, policy)
    want, util = oracle_trace(make_trace(), 3, 16, policy)
    assert b.admissions == want
    assert b.stats.utilization == pytest.approx(util)
    # quiescence conservation: every admitted request completed
    assert b.sched.telemetry.spawns == b.sched.telemetry.joins \
        == len(make_trace())


def test_single_tenant_wdlbc_is_step_for_step_dlbc(params):
    """The deficit round-robin must be invisible with one tenant: the
    weighted batcher reproduces the plain-DLBC admission trace exactly."""
    plain = run_batcher(params, "dlbc")
    weighted = run_batcher(params, "wdlbc")  # implicit single "default"
    assert weighted.admissions == plain.admissions
    assert weighted.stats.steps == plain.stats.steps
    assert weighted.stats.utilization == pytest.approx(
        plain.stats.utilization)
    assert weighted.stats.latencies == plain.stats.latencies
    assert weighted.stats.queue_waits == plain.stats.queue_waits


def test_two_tenant_wdlbc_matches_drr_oracle(params):
    weights = {"a": 3.0, "b": 1.0}
    b = run_batcher(params, "wdlbc", tenants=weights,
                    with_tenant_labels=True)
    want, util = oracle_trace(make_trace(with_tenants=True), 3, 16,
                              "dlbc", weights=weights)
    assert b.admissions == want
    assert b.stats.utilization == pytest.approx(util)
    # per-tenant telemetry conservation (the CI gate's invariant)
    tele = b.sched.telemetry
    totals = tele.tenant_totals()
    assert totals["spawns"] == tele.spawns == 12
    assert totals["joins"] == tele.joins == 12
    for name in weights:
        assert tele.tenant(name).spawns == tele.tenant(name).joins == 6


def test_admission_golden_trace_two_tenants(params):
    """Literal golden of the first admissions — a tripwire for ANY change
    to the deficit arithmetic, tie-breaking, or slot ordering."""
    b = run_batcher(params, "wdlbc", tenants={"a": 3.0, "b": 1.0},
                    with_tenant_labels=True)
    # step 0, three idle slots: weight 3 front-loads tenant "a" (deficits
    # a=3 > b=1, then the a=2/b=2 tie breaks to registration order), so
    # a's two queued requests land before b's one
    assert b.admissions[:6] == [
        (0, 0, 0, "a"), (0, 1, 2, "a"), (0, 2, 1, "b"),
        (3, 0, 4, "a"), (4, 1, 6, "a"), (5, 2, 3, "b"),
    ]


# ---------------------------------------------------------------------------
# Refill-mid-decode: per-slot cache positions
# ---------------------------------------------------------------------------


def test_escape_join_base_policy_rejected_at_construction():
    """DCAFE's escaped joins are meaningless for per-request admission;
    tenant mode must refuse the base policy in __init__, not mid-run."""
    from repro.sched.policy import DCAFE

    with pytest.raises(ValueError, match="escape-join"):
        ContinuousBatcher(_cfg(), params={}, n_slots=2, cache_len=16,
                          policy=DCAFE(), tenants={"a": 1.0})


def test_recurrent_families_are_rejected():
    """SSM/hybrid recurrent state is not position-indexed, so a slot
    refill would leak the previous occupant's state into the newcomer —
    the batcher must refuse rather than decode corrupted tokens."""
    cfg = ModelConfig(name="serve-ssm", family="ssm", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab=128)
    with pytest.raises(NotImplementedError, match="recurrent"):
        ContinuousBatcher(cfg, params={}, n_slots=2, cache_len=16)


def test_refill_mid_decode_tokens_match_solo_run(params):
    """A request refilled into a freed slot while its neighbour is deep
    into decoding must produce EXACTLY the tokens it produces alone —
    the per-slot cache index isolates its KV writes and attention mask.
    (The old shared ``max(slot_pos)`` index wrote the newcomer's KV at
    the neighbour's position and attended over stale entries.)"""
    cfg = _cfg()
    solo_req = Request(rid=1, prompt=[7, 8, 9], max_new=8, arrive_step=4)
    solo = ContinuousBatcher(cfg, params, n_slots=2, cache_len=32,
                             policy="dlbc")
    solo.run([solo_req])

    # contended: slot 0 busy with a long sequence from step 0; the late
    # request lands in slot 1 at step 4, while the neighbour is at pos 4
    late = Request(rid=1, prompt=[7, 8, 9], max_new=8, arrive_step=4)
    long_req = Request(rid=0, prompt=[1, 2], max_new=20, arrive_step=0)
    cont = ContinuousBatcher(cfg, params, n_slots=2, cache_len=32,
                             policy="dlbc")
    cont.run([long_req, late])
    assert cont.admissions[1][0] == 4  # really refilled mid-decode
    assert late.tokens == solo_req.tokens
