"""repro.obs: tracer no-op guarantees, ring bounds, Chrome export schema,
and the trace↔telemetry conservation cross-check."""

import json
import time

import pytest

from repro.obs import export as obs_export
from repro.obs import trace as obs
from repro.sched import (
    LogHistogram, MultipleExceptions, SchedTelemetry, ThreadExecutor,
    WorkStealingExecutor,
)
from repro.sched.telemetry import ExchangeCounters


@pytest.fixture(autouse=True)
def _tracer_off():
    """Every test starts and ends with the tracer disabled and empty —
    the default-off contract the rest of the suite relies on."""
    obs.disable()
    obs.clear()
    yield
    obs.disable()
    obs.clear()


# -- disabled-by-default is a true no-op ------------------------------------

def test_disabled_emits_nothing():
    obs.instant("sched", "spawn", n=3)
    with obs.trace_span("worker", "task"):
        pass
    obs.complete_span("sched", "steal", obs.perf_counter_ns())
    assert obs.snapshot() == []
    assert obs.ring_stats() == []


def test_disabled_span_is_shared_noop():
    # no allocation when disabled: the same singleton every call
    assert obs.trace_span("a", "b") is obs.trace_span("c", "d")


def test_disabled_executor_run_emits_nothing():
    ex = WorkStealingExecutor(n_workers=2)
    try:
        ex.run_loop(list(range(32)), lambda x: x * x)
    finally:
        ex.shutdown()
    assert obs.snapshot() == []


def test_disabled_emit_cost_is_negligible():
    # generous wall bound: 200k disabled emits must be ~instant (each is
    # one global read + return); catches an accidental allocation or
    # clock read sneaking into the disabled path
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        obs.instant("sched", "spawn")
        with obs.trace_span("worker", "task"):
            pass
    dt = time.perf_counter() - t0
    assert dt < 1.0, f"{n} disabled emits took {dt:.2f}s"


# -- enabled semantics -------------------------------------------------------

def test_span_and_instant_recorded():
    obs.enable()
    with obs.trace_span("worker", "task", {"k": 1}):
        time.sleep(0.001)
    obs.instant("sched", "spawn", n=4)
    evs = obs.snapshot()
    spans = [e for e in evs if e["ph"] == "X"]
    insts = [e for e in evs if e["ph"] == "i"]
    assert len(spans) == 1 and len(insts) == 1
    assert spans[0]["dur_ns"] >= 1_000_000
    assert spans[0]["args"] == {"k": 1}
    assert insts[0]["n"] == 4


def test_disable_mid_span_drops_event():
    obs.enable()
    with obs.trace_span("worker", "task"):
        obs.disable()
    assert obs.snapshot() == []


def test_ring_bounded_and_counts_drops():
    obs.enable(capacity=64)
    for i in range(1000):
        obs.instant("sched", "spawn")
    (stats,) = [s for s in obs.ring_stats() if s["n_events"]]
    assert stats["n_events"] == 64
    assert stats["dropped"] == 1000 - 64
    # oldest events were overwritten: the survivors are the newest 64
    assert len(obs.snapshot()) == 64


def test_ring_bounds_hold_under_executor_stress():
    obs.enable(capacity=128)
    ex = WorkStealingExecutor(n_workers=4)
    try:
        skew = [0.003 if i < 8 else 0.0 for i in range(64)]
        for _ in range(10):
            ex.run_loop(skew, time.sleep)
    finally:
        ex.shutdown()
    stats = obs.ring_stats()
    assert stats, "no rings registered under stress"
    for s in stats:
        assert s["n_events"] <= 128, s
    assert len(obs.snapshot()) <= 128 * len(stats)


def test_clear_resets_between_passes():
    obs.enable()
    obs.instant("sched", "spawn")
    assert obs.snapshot()
    obs.clear()
    assert obs.snapshot() == []
    obs.instant("sched", "join")  # same thread re-registers post-epoch
    assert len(obs.snapshot()) == 1


# -- Chrome trace-event export ----------------------------------------------

def _traced_run():
    obs.enable()
    ex = WorkStealingExecutor(n_workers=4)
    try:
        skew = [0.005 if i < 8 else 0.001 for i in range(64)]
        ex.run_loop(skew, time.sleep)
        return ex.telemetry.summary()
    finally:
        ex.shutdown()


def test_chrome_trace_schema():
    summary = _traced_run()
    doc = obs_export.chrome_trace(extra={"telemetry": summary})
    # the whole doc must survive a JSON roundtrip (CI writes/reads it)
    doc = json.loads(json.dumps(doc))
    evs = doc["traceEvents"]
    assert evs, "trace is empty after a traced run"
    names = set()
    for e in evs:
        assert e["ph"] in ("X", "i", "M"), e
        if e["ph"] == "M":
            assert e["name"] == "thread_name" and e["args"]["name"]
            continue
        names.add(e["name"])
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        assert isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
        else:
            assert e["s"] == "t"
    # scheduling-edge vocabulary present
    assert {"spawn", "join", "complete"} <= names
    # every emitting thread has a named track
    tracks = {e["tid"] for e in evs if e["ph"] == "M"}
    assert {e["tid"] for e in evs if e["ph"] != "M"} <= tracks


def test_crosscheck_matches_telemetry():
    summary = _traced_run()
    doc = obs_export.chrome_trace()
    check = obs_export.crosscheck(doc, summary)
    assert check["ok"], check["mismatches"]
    # the counts are real, not vacuous zeros
    assert check["trace"]["spawns"] > 0
    assert check["trace"]["completions"] == check["trace"]["spawns"]


def test_crosscheck_detects_mismatch():
    summary = _traced_run()
    summary["spawns"] += 1
    check = obs_export.crosscheck(obs_export.chrome_trace(), summary)
    assert not check["ok"]
    assert any("spawns" in m for m in check["mismatches"])


def test_derived_metrics_occupancy():
    _traced_run()
    doc = obs_export.chrome_trace()
    d = obs_export.derived_metrics(doc)
    assert d["wall_ms"] > 0
    assert d["per_worker"], "no worker occupancy derived"
    for w in d["per_worker"].values():
        assert 0.0 <= w["occupancy"] <= 1.0
        assert 0.0 <= w["idle_frac"] <= 1.0
    assert any(k.startswith("worker.") for k in d["span_stats"])


def test_write_chrome_trace_file(tmp_path):
    _traced_run()
    path = tmp_path / "t.trace.json"
    doc = obs_export.write_chrome_trace(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk["traceEvents"]
    assert on_disk["derived"]["counts"] == doc["derived"]["counts"]


def test_errors_traced_and_contained():
    obs.enable()
    tel = SchedTelemetry()
    ex = ThreadExecutor(n_workers=2, telemetry=tel)

    def boom(x):
        if x == 3:
            raise ValueError(x)

    try:
        # spawned-item exceptions are contained (counted, collected) and
        # the per-loop join rethrows them all as ONE MultipleExceptions;
        # a caller-chunk raise would propagate raw like a plain for loop
        with pytest.raises((MultipleExceptions, ValueError)):
            ex.run_loop(list(range(8)), boom)
    finally:
        ex.shutdown()
    check = obs_export.crosscheck(obs_export.chrome_trace(), tel.summary())
    assert check["ok"], check["mismatches"]
    # containment: a raising spawned task still completes, so the task
    # counters close even though the join rethrew
    assert check["trace"]["completions"] == check["trace"]["spawns"]
    if tel.errors:
        # item 3 ran in a spawned chunk: the error instant carries its
        # site, and the per-site breakdown crosschecks (already covered
        # by check["ok"] — assert the count explicitly for clarity)
        assert check["trace"]["errors"] == 1
        assert tel.errors_by_site == {"sched.item": 1}


# -- open spans at export time (truncated, not dropped) ----------------------

def test_open_span_survives_export_as_truncated():
    obs.enable()
    span = obs.trace_span("serve", "decode", {"slot": 1})
    span.__enter__()  # still open when the export happens
    try:
        doc = obs_export.chrome_trace()
        xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
        assert len(xs) == 1, "open span was silently dropped at export"
        (e,) = xs
        assert e["trunc"] is True
        assert e["args"]["trunc"] is True
        assert e["name"] == "decode" and e["cat"] == "serve"
        assert e["dur"] >= 0
    finally:
        span.__exit__(None, None, None)
    # after a normal exit the span is emitted once, closed, not truncated
    evs = obs.snapshot()
    spans = [e for e in evs if e["ph"] == "X"]
    assert len(spans) == 1 and not spans[0].get("trunc")
    assert obs.open_span_events() == []


def test_closed_spans_not_marked_truncated():
    obs.enable()
    with obs.trace_span("worker", "task"):
        pass
    doc = obs_export.chrome_trace()
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert len(xs) == 1 and "trunc" not in xs[0]


def test_truncated_spans_are_conservation_safe():
    # an open span swept into the export must not disturb the counter
    # crosscheck: spans are never counted, only instants are
    summary = _traced_run()
    span = obs.trace_span("serve", "step")
    span.__enter__()
    try:
        doc = obs_export.chrome_trace()
        check = obs_export.crosscheck(doc, summary)
        assert check["ok"], check["mismatches"]
        assert any(e.get("trunc") for e in doc["traceEvents"]
                   if e.get("ph") == "X")
    finally:
        span.__exit__(None, None, None)


def test_export_without_open_spans_flag():
    obs.enable()
    span = obs.trace_span("serve", "decode")
    span.__enter__()
    try:
        doc = obs_export.chrome_trace(include_open=False)
        assert [e for e in doc["traceEvents"] if e.get("ph") == "X"] == []
    finally:
        span.__exit__(None, None, None)


# -- telemetry growth (satellites) ------------------------------------------

def test_summary_has_completions_errors_and_hist():
    tel = SchedTelemetry()
    tel.record_latency(0.002)
    tel.record_latency(0.1)
    s = tel.summary()
    assert s["completions"] == 0 and s["errors"] == 0
    h = s["latency_hist"]
    assert h["n"] == 2 and h["p99_ms"] >= h["p50_ms"]
    assert h["tail_p99_p50"] >= 1.0


def test_log_histogram_buckets_and_merge():
    a, b = LogHistogram(), LogHistogram()
    a.extend([1e-6, 2e-6, 4e-6])
    b.extend([1e-3] * 97)
    a.merge(b)
    s = a.summary()
    assert s["n"] == 100
    # p50 lands in the 1ms bucket; upper-edge convention overestimates
    # by at most one bucket (×2)
    assert 1.0 <= s["p50_ms"] <= 2.1
    assert s["max_ms"] >= 1.0
    assert s["tail_p99_p50"] >= 1.0


def test_log_histogram_diff_windows():
    old = LogHistogram()
    old.extend([1e-3] * 10)
    new = old.copy()
    new.extend([5e-2] * 5)
    d = new.diff(old)
    s = d.summary()
    assert s["n"] == 5
    # the window holds only the 50ms observations: p50 lands in that
    # bucket (upper-edge convention overestimates by at most x2)
    assert 50.0 <= s["p50_ms"] <= 110.0
    # the originals are untouched (diff never resets global state)
    assert old.summary()["n"] == 10 and new.summary()["n"] == 15


def test_log_histogram_diff_rejects_negative_window():
    a, b = LogHistogram(), LogHistogram()
    b.extend([1e-3, 1e-3])
    a.extend([1e-3])
    with pytest.raises(ValueError):
        a.diff(b)  # "newer" has fewer observations than "older"


def test_log_histogram_merge_rejects_bucket_mismatch():
    a, b = LogHistogram(), LogHistogram()
    b.counts = b.counts[:-1]  # simulate a deserialized foreign shape
    with pytest.raises(ValueError):
        a.merge(b)
    with pytest.raises(ValueError):
        a.diff(b)


def test_exchange_posted_completed_split():
    ex = ExchangeCounters()
    ex.posted += 2
    ex.completed += 1
    assert ex.in_flight == 1
    assert ex.rounds == 1  # legacy alias == completed
    s = ex.summary()
    assert s["posted"] == 2 and s["completed"] == 1 and s["rounds"] == 1


def test_record_exchange_legacy_rounds_alias():
    tel = SchedTelemetry()
    tel.record_exchange(sent=4, received=4, rounds=2)
    assert tel.exchange.posted == 2 and tel.exchange.completed == 2
    tel.record_exchange(posted=1)
    tel.record_exchange(completed=1, sent=1, received=1)
    assert tel.exchange.posted == 3 and tel.exchange.completed == 3
    assert tel.exchange.in_flight == 0
    assert tel.summary()["exchange"]["rounds"] == 3
