"""Expert-parallel dispatch (repro.ep): exchange-plan properties +
EP ↔ single-host numerical equivalence.

Three layers:

* **Plan properties** — deterministic units plus a hypothesis sweep
  asserting the `ExchangePlan` send/recv matrix is *conservative*: for
  every source shard, planned sends + drops == routed pair counts, no
  lane exceeds capacity, and drops appear only when a source's total
  routed pairs exceed its total lane capacity.
* **Capacity-provider overflow** — the `residual` clamp never goes
  negative and `overflow` exposes the clamped excess (the EP planner
  consumes both sides of this split).
* **Device equivalence** — on a 2-shard ``expert`` mesh (subprocess, so
  the host-device-count override never leaks), ``ep_dispatch_combine``
  matches the single-host ``dispatch_combine`` output up to token order,
  the ``ppermute`` ring matches the fused ``all_to_all``, and telemetry
  shows exactly one join for the round.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.ep.plan import lane_capacity, plan_exchange
from repro.sched import ExpertCapacityProvider

# ---------------------------------------------------------------------------
# ExchangePlan arithmetic (host-side, no devices)
# ---------------------------------------------------------------------------


def test_plan_exchange_reassigns_before_dropping():
    # shard 0 overflows its own lane; shards 2/3 have idle rows
    p = plan_exchange([[10, 2, 0, 0], [3, 3, 3, 3],
                       [0, 0, 20, 0], [4, 4, 4, 4]], lane_capacity=8)
    for i in range(4):
        assert sum(p.send[i]) + p.dropped[i] == sum(p.counts[i])
        assert all(c <= 8 for c in p.send[i])
    # 10+2 pairs fit in 4 lanes of 8 — reassigned, nothing dropped
    assert p.dropped == (0, 0, 0, 0)
    assert p.reassigned[0] == 2 and p.reassigned[2] == 12
    # recv is the transpose: what shard j finds in its incoming block
    assert p.recv[0][2] == p.send[2][0]
    assert p.sent_total == sum(map(sum, p.counts))


def test_plan_exchange_drops_only_above_total_capacity():
    # 40 routed pairs, 4 lanes × 8 rows = 32 total: 8 must drop, and the
    # plan fills every lane to capacity before giving up
    p = plan_exchange([[40, 0, 0, 0]] + [[0, 0, 0, 0]] * 3,
                      lane_capacity=8)
    assert p.send[0] == (8, 8, 8, 8)
    assert p.dropped[0] == 8
    assert p.reassigned[0] == 24
    assert p.summary()["dropped"] == 8


def test_plan_exchange_zero_capacity_drops_everything():
    p = plan_exchange([[3, 1], [0, 2]], lane_capacity=0)
    assert p.send == ((0, 0), (0, 0))
    assert p.dropped == (4, 2)


def test_lane_capacity_holds_balanced_load():
    # S lanes jointly hold every locally routed pair at cf >= 1.0
    for Tl, K, S in ((128, 2, 2), (64, 2, 4), (96, 3, 4)):
        assert lane_capacity(Tl, K, S, 1.0) * S >= Tl * K


def _check_conservation(counts, cap):
    p = plan_exchange(counts, cap)
    S = len(counts)
    for i in range(S):
        routed = sum(counts[i])
        assert sum(p.send[i]) + p.dropped[i] == routed
        assert all(0 <= c <= cap for c in p.send[i])
        assert 0 <= p.reassigned[i] <= routed
        # drops only when the row exceeds its total lane capacity, and
        # then exactly by the excess (the plan never strands idle rows)
        assert p.dropped[i] == max(0, routed - S * cap)
    # recv is a permutation of the same pairs (column transpose)
    assert sum(map(sum, p.recv)) == sum(map(sum, p.send))


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(
        st.integers(min_value=1, max_value=6).flatmap(
            lambda s: st.lists(
                st.lists(st.integers(min_value=0, max_value=64),
                         min_size=s, max_size=s),
                min_size=s, max_size=s)),
        st.integers(min_value=0, max_value=48),
    )
    def test_plan_exchange_conservation_property(counts, cap):
        _check_conservation(counts, cap)
except ImportError:  # pragma: no cover - hypothesis is a dev extra
    def test_plan_exchange_conservation_property():
        for counts, cap in (
            ([[64, 0], [32, 32]], 16),
            ([[5, 7, 9], [0, 0, 0], [21, 1, 2]], 8),
            ([[1]], 0),
        ):
            _check_conservation(counts, cap)


# ---------------------------------------------------------------------------
# ExpertCapacityProvider overflow handling (the path the planner consumes)
# ---------------------------------------------------------------------------


def test_capacity_residual_clamps_and_overflow_exposes_drop():
    import jax.numpy as jnp

    cap = ExpertCapacityProvider(n_experts=4, slots_per_expert=8)
    # per-expert loads above capacity — and a total (45) above total()
    load = jnp.asarray([20, 8, 12, 5])
    assert int(jnp.sum(load)) > cap.total()
    resid = np.asarray(cap.residual(load))
    over = np.asarray(cap.overflow(load))
    np.testing.assert_array_equal(resid, [0, 0, 0, 3])   # never negative
    np.testing.assert_array_equal(over, [12, 0, 4, 0])   # clamped excess
    # conservation: admitted + dropped == load, even above total capacity
    admitted = np.minimum(np.asarray(load), cap.slots_per_expert)
    np.testing.assert_array_equal(admitted + over, np.asarray(load))


# ---------------------------------------------------------------------------
# EP ↔ single-host equivalence (2-shard expert mesh, subprocess)
# ---------------------------------------------------------------------------

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.distributed.sharding import mesh_context
    from repro.launch.mesh import make_test_mesh
    from repro.models import moe as MOE
    from repro.ep.dispatch import ep_dispatch_combine, ep_round
    from repro.sched import SchedTelemetry

    # ample capacity: no admission differences, outputs must agree
    cfg = dataclasses.replace(get_config("mixtral-8x7b", smoke=True),
                              moe_capacity_factor=8.0,
                              expert_parallel=True)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))

    cfg_host = dataclasses.replace(cfg, expert_parallel=False)
    y_ref = MOE.moe_apply(p, cfg_host, x)

    results = {}
    mesh = make_test_mesh(data=1, model=1, expert=2)
    with mesh_context(mesh):
        y_ep, st = MOE.moe_apply(p, cfg, x, return_stats=True)
        y_pp = ep_dispatch_combine(p, cfg, x, mesh=mesh, impl="ppermute")
        tel = SchedTelemetry()
        y_rd, st_rd = ep_round(p, cfg, x, mesh=mesh, telemetry=tel)
    results["max_diff"] = float(jnp.max(jnp.abs(y_ep - y_ref)))
    # sorted-token comparison: order-insensitive equivalence oracle
    results["sorted_diff"] = float(np.max(np.abs(
        np.sort(np.asarray(y_ep), axis=0) -
        np.sort(np.asarray(y_ref), axis=0))))
    results["ppermute_diff"] = float(jnp.max(jnp.abs(y_pp - y_ep)))
    results["stats"] = {k: float(v) for k, v in st.items()}
    results["round"] = {k: float(v) for k, v in st_rd.items()}
    results["telemetry"] = dict(joins=tel.joins, spawns=tel.spawns,
                                exchange=tel.exchange.summary())

    # 4-shard hot-expert pressure: reassignment, conservation
    cfg_hot = dataclasses.replace(cfg, moe_capacity_factor=1.0)
    p_hot = dict(p)
    p_hot["router"] = p["router"].at[:, 0].add(4.0)
    mesh4 = make_test_mesh(data=1, model=1, expert=4)
    with mesh_context(mesh4):
        xh = jax.random.normal(jax.random.PRNGKey(3), (128, cfg.d_model))
        _, sth = MOE.moe_apply(p_hot, cfg_hot, xh, return_stats=True)
    results["hot"] = {k: float(v) for k, v in sth.items()}

    # shard-loss degradation: losing shard 1 closes its lanes, the
    # round retries once with the traffic rerouted to live shards
    from repro.sched.faults import FaultPlan, FaultSpec, injected_faults
    plan = FaultPlan([FaultSpec(site="ep.round", kind="shard_loss",
                                every=1, shard=1, max_injections=1)],
                     seed=0)
    tel_d = SchedTelemetry()
    with mesh_context(mesh4):
        with injected_faults(plan):
            _, st_d = ep_round(p, cfg, x, mesh=mesh4, telemetry=tel_d)
    results["degraded"] = {
        "stats": {k: float(v) for k, v in st_d.items()},
        "retries": tel_d.retries, "joins": tel_d.joins,
        "exchange": tel_d.exchange.summary()}
    print("RESULT " + json.dumps(results))
""")


@pytest.fixture(scope="module")
def ep_results():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=1200,
        cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError("no RESULT line:\n" + out.stdout)


def test_ep_matches_single_host_dispatch(ep_results):
    # token-order-preserving equality AND the order-insensitive oracle
    assert ep_results["max_diff"] < 1e-5
    assert ep_results["sorted_diff"] < 1e-5


def test_ep_ppermute_matches_all_to_all(ep_results):
    assert ep_results["ppermute_diff"] < 1e-6


def test_ep_single_join_per_round(ep_results):
    st = ep_results["stats"]
    assert st["joins"] == 1 and st["rounds"] == 1
    tel = ep_results["telemetry"]
    assert tel["joins"] == 1
    assert tel["exchange"]["rounds"] == 1
    assert tel["exchange"]["sent"] == tel["exchange"]["received"]
    assert tel["spawns"] == ep_results["round"]["spawns"]


def test_ep_stats_conservation(ep_results):
    # ample capacity: every (token, choice) pair admitted, none dropped
    st = ep_results["stats"]
    assert st["dropped_frac"] == 0.0
    assert st["sent"] == st["received"] == st["spawns"] == 64 * 2


def test_ep_hot_router_reassigns_under_pressure(ep_results):
    hot = ep_results["hot"]
    assert hot["reassigned"] > 0          # DLBC moved overflow pre-collective
    assert hot["sent"] == hot["received"]
    # spawns + dropped == T*K pairs (the shared vocabulary invariant)
    assert hot["spawns"] + hot["dropped"] == 128 * 2


def test_ep_shard_loss_degrades_not_aborts(ep_results):
    """A lost shard degrades the round (lanes rerouted pre-collective),
    it does not abort it: one retry, one join, posted == completed, the
    degraded flag set — and with ample capacity nothing drops."""
    d = ep_results["degraded"]
    st, ex = d["stats"], d["exchange"]
    assert st["degraded"] == 1 and st["dead_shards"] == 1
    assert st["reassigned"] > 0           # the dead shard's traffic moved
    assert st["dropped"] == 0             # ample capacity absorbed it
    assert d["retries"] == 1
    assert d["joins"] == 1                # still ONE join for the round
    assert ex["degraded_rounds"] == 1
    assert ex["posted"] == ex["completed"] == 1
