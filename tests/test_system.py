"""End-to-end behaviour: the quickstart ladder, a short real training run
with loss decrease, the serving batcher, and the dry-run single-cell path
(in-process, small mesh via subprocess in test_distributed.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_kernel, run_scheme


def test_quickstart_ladder_end_to_end():
    k = build_kernel("NQ", "test")
    rows = {s: run_scheme(k, s, workers=8)
            for s in ("UnOpt", "LC", "DLBC", "DCAFE")}
    assert all(r.ok for r in rows.values())
    assert rows["DCAFE"].time < rows["UnOpt"].time
    assert rows["DCAFE"].finishes == 1


def test_training_loss_decreases():
    from repro.configs.base import ModelConfig, ShapeConfig
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import StepConfig
    from repro.train.trainer import TrainerConfig, run_training
    import tempfile, shutil

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
    shape = ShapeConfig("t", 64, 8, "train", microbatches=2)
    d = tempfile.mkdtemp()
    try:
        rep = run_training(
            cfg, shape,
            TrainerConfig(steps=30, ckpt_every=100, ckpt_dir=d),
            StepConfig(q_chunk=32, k_chunk=32),
            AdamWConfig(lr=1e-3, warmup_steps=5))
        assert rep.completed == 30
        assert rep.losses[-1] < rep.losses[0]
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_serving_batcher_dlbc_beats_lc():
    from repro.configs.base import ModelConfig
    from repro.models import model as MDL
    from repro.serve.batcher import ContinuousBatcher, Request

    cfg = ModelConfig(name="serve", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def reqs():
        return [Request(rid=i, prompt=[1, 2], max_new=int(rng.integers(2, 16)),
                        arrive_step=int(rng.integers(0, 10)))
                for i in range(16)]

    rng = np.random.default_rng(0)
    lc = ContinuousBatcher(cfg, params, n_slots=4, cache_len=32,
                           policy="lc").run(reqs())
    rng = np.random.default_rng(0)
    dl = ContinuousBatcher(cfg, params, n_slots=4, cache_len=32,
                           policy="dlbc").run(reqs())
    assert dl.utilization >= lc.utilization
    assert np.mean(dl.latencies) <= np.mean(lc.latencies)


def test_dryrun_artifacts_complete():
    """Every (arch × applicable shape × mesh) cell has an OK artifact —
    the multi-pod dry-run deliverable (produced by repro.launch.dryrun)."""
    import json
    from pathlib import Path

    from repro.configs import all_cells

    d = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run artifacts not generated yet")
    missing, bad = [], []
    for cell in all_cells():
        for mesh in ("16x16", "2x16x16"):
            tag = f"{mesh}_{cell['arch']}_{cell['shape']}_afe_masked"
            f = d / f"{tag}.json"
            if not f.exists():
                missing.append(tag)
                continue
            rec = json.loads(f.read_text())
            expected = "ok" if cell["applicable"] else "skipped"
            if rec["status"] != expected:
                bad.append((tag, rec["status"]))
            # HBM fit is an analysis outcome, not a compile gate: the
            # known over-budget cells are documented in EXPERIMENTS.md
            # §Dry-run with causes and next levers (PP for llama-90b
            # train; chunked prefill for MoE prefill dispatch buffers).
            known_over = {
                ("llama-3.2-vision-90b", "train_4k"),
                ("mixtral-8x7b", "train_4k"),
                ("mixtral-8x7b", "prefill_32k"),
                ("granite-moe-1b-a400m", "prefill_32k"),
            }
            if rec["status"] == "ok" and not rec["fits_hbm"] and \
                    (cell["arch"], cell["shape"]) not in known_over:
                bad.append((tag, "undocumented over-HBM"))
    assert not missing, f"missing cells: {missing[:10]}"
    assert not bad, f"bad cells: {bad[:10]}"
