"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode — the TPU lowering path shares the same kernel body)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_dispatch.moe_gmm import moe_gmm
from repro.kernels.moe_dispatch.ref import moe_gmm_ref
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ssm_scan.ssm_scan import ssm_scan

RNG = np.random.default_rng(42)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,S,H,KV,dh", [
    (1, 256, 4, 2, 64),
    (2, 128, 8, 8, 64),
    (1, 512, 4, 1, 128),
    (2, 256, 6, 2, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, dh, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, H, dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, KV, dh)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, dh)), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    B, S, H, KV, dh = 1, 512, 4, 2, 64
    q = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, KV, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, dh)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=128, block_k=128, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("E,C,d,f", [
    (4, 128, 64, 128),
    (2, 256, 128, 256),
    (8, 128, 128, 384),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_sweep(E, C, d, f, dtype):
    buf = jnp.asarray(RNG.normal(size=(E, C, d)) * 0.5, dtype)
    w1 = jnp.asarray(RNG.normal(size=(E, d, f)) * 0.1, dtype)
    w3 = jnp.asarray(RNG.normal(size=(E, d, f)) * 0.1, dtype)
    w2 = jnp.asarray(RNG.normal(size=(E, f, d)) * 0.1, dtype)
    out = moe_gmm(buf, w1, w3, w2, interpret=True)
    ref = moe_gmm_ref(buf, w1, w3, w2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype) * 5, rtol=_tol(dtype) * 5)


@pytest.mark.parametrize("B,L,Di,N,chunk,block_d", [
    (2, 256, 64, 8, 64, 32),
    (1, 128, 128, 16, 128, 128),
    (3, 512, 32, 4, 128, 32),
])
def test_ssm_scan_sweep(B, L, Di, N, chunk, block_d):
    dA = jnp.asarray(RNG.uniform(0.5, 0.999, size=(B, L, Di, N)), jnp.float32)
    dBx = jnp.asarray(RNG.normal(size=(B, L, Di, N)) * 0.1, jnp.float32)
    C = jnp.asarray(RNG.normal(size=(B, L, N)), jnp.float32)
    out = ssm_scan(dA, dBx, C, chunk=chunk, block_d=block_d, interpret=True)
    ref = ssm_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


def test_xla_chunked_attention_matches_kernel():
    """The model's XLA attention path and the Pallas kernel agree."""
    from repro.models.layers import chunked_attention

    B, S, H, KV, dh = 1, 256, 4, 2, 64
    q = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, KV, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, dh)), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, q_chunk=128, k_chunk=128)
    b = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_xla_tri_schedule_matches_masked():
    from repro.models.layers import chunked_attention

    B, S, H, KV, dh = 1, 512, 4, 2, 64
    q = jnp.asarray(RNG.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, KV, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, dh)), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, q_chunk=128, k_chunk=128,
                          schedule="masked")
    b = chunked_attention(q, k, v, causal=True, q_chunk=128, k_chunk=128,
                          schedule="tri")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_ssm_model_scan_matches_kernel():
    """models/ssm.py chunked associative scan ≡ the Pallas recurrence."""
    from repro.configs import get_config
    from repro.models.ssm import _ssm_params, ssm_scan_chunked, ssm_init
    import jax

    cfg = get_config("falcon-mamba-7b", smoke=True)
    p = ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, L = 2, 64
    x = jnp.asarray(RNG.normal(size=(B, L, cfg.d_inner)) * 0.3, jnp.float32)
    dA, dBx, Cc = _ssm_params(p, cfg, x)
    y_model = ssm_scan_chunked(p, cfg, x, chunk=16) - \
        x.astype(jnp.float32) * p["D"]
    y_kernel = ssm_scan(dA, dBx, Cc, chunk=16, block_d=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               atol=1e-4, rtol=1e-3)
